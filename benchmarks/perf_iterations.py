"""SSPerf hillclimb driver: hypothesis -> change -> re-lower -> validate.

Measures the three roofline terms for named configuration variants of one
(arch x shape) cell on the single-pod mesh, so each perf iteration is a
one-line variant spec.  Results feed EXPERIMENTS.md SSPerf.

Usage:
  PYTHONPATH=src python -m benchmarks.perf_iterations --cell llama3-8b:train_4k
  PYTHONPATH=src python -m benchmarks.perf_iterations --cell llama4-scout-17b-16e:train_4k --out results/perf_llama4.json
"""
import os
if not os.environ.get("XLA_FLAGS"):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time

from repro.configs import ARCHS, SHAPES
from repro.launch.dryrun import roofline_costs
from repro.launch.mesh import make_production_mesh
from repro.parallel.tp import ParallelCtx

PEAK_FLOPS, HBM_BW, LINK_BW = 197e12, 819e9, 50e9


def hillclimb_mesh(tp: int = 16, dp: int = 4):
    """Reduced-DP mesh for perf iterations: keeps the model axis (the INA
    dimension) at production width while shrinking the SPMD partition count
    so single-core compiles stay tractable.  Model-axis collective terms are
    representative; data-axis (FSDP/DP) terms scale with DP and are reported
    as-is with the mesh recorded."""
    import jax
    from jax.sharding import AxisType
    return jax.make_mesh((dp, tp), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def measure(arch: str, shape_name: str, mesh, cfg_over: dict | None = None,
            pctx_over: dict | None = None, fast: bool = False) -> dict:
    cfg = ARCHS[arch]
    moe_over = (cfg_over or {}).pop("__moe__", None)
    ssm_over = (cfg_over or {}).pop("__ssm__", None)
    if moe_over and cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                               **moe_over))
    if ssm_over and cfg.ssm:
        cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                               **ssm_over))
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    pctx = ParallelCtx(mesh=mesh, **(pctx_over or {}))
    t0 = time.time()
    r = roofline_costs(cfg, SHAPES[shape_name], mesh, pctx, fast=fast)
    r["wall_s"] = round(time.time() - t0, 1)
    r["compute_s"] = r["flops"] / PEAK_FLOPS
    r["memory_s"] = r["bytes"] / HBM_BW
    r["collective_s"] = r["coll"] / LINK_BW
    terms = {k: r[f"{k}_s"] for k in ("compute", "memory", "collective")}
    r["dominant"] = max(terms, key=terms.get)
    r["step_s"] = max(terms.values())     # roofline-limited step estimate
    return r


# Variant presets per hillclimbed cell: (name, cfg_overrides, pctx_overrides)
VARIANTS = {
    "default": [
        ("baseline_xla", {}, {"psum_mode": "xla_spmd"}),
        ("paper_eject_inject", {}, {"psum_mode": "eject_inject"}),
        ("paper_ina_ring", {}, {"psum_mode": "ina_ring"}),
        ("ina_xla_rs", {}, {"psum_mode": "ina"}),
    ],
}


def run_cell(cell: str, variants=None) -> list[dict]:
    arch, shape = cell.split(":")
    mesh = make_production_mesh(multi_pod=False)
    out = []
    for name, cfg_over, pctx_over in (variants or VARIANTS["default"]):
        r = measure(arch, shape, mesh, dict(cfg_over), dict(pctx_over))
        row = {"cell": cell, "variant": name,
               "compute_s": r["compute_s"], "memory_s": r["memory_s"],
               "collective_s": r["collective_s"], "dominant": r["dominant"],
               "step_s": r["step_s"], "wall_s": r["wall_s"]}
        out.append(row)
        print(f"[perf] {cell} {name}: compute={r['compute_s']:.3e} "
              f"memory={r['memory_s']:.3e} coll={r['collective_s']:.3e} "
              f"dom={r['dominant']} step~{r['step_s']:.3e}s "
              f"({r['wall_s']}s to measure)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run_cell(args.cell)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
