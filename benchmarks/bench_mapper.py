"""Mapper search: paper-fixed vs auto-searched mapping ratios.

Thin wrapper over :func:`repro.experiments.sweeps.mapper_csv_lines` (quick
search space, short windows) kept for the ``benchmarks/run.py`` CSV
contract; use ``python -m repro.experiments --section mapper`` for the full
Pareto artifact.
"""
from repro.experiments.sweeps import QUICK_SWEEP, mapper_csv_lines


def run() -> list[str]:
    return mapper_csv_lines(QUICK_SWEEP)


if __name__ == "__main__":
    print("\n".join(run()))
