"""Mapper search: paper-fixed vs auto-searched mapping ratios.

``run()`` stays the thin ``benchmarks/run.py`` CSV wrapper over
:func:`repro.experiments.sweeps.mapper_csv_lines`; ``run_full_perf()`` is
the PR-4 perf-trajectory probe: it times the **full** (non-quick) mapper
space — AlexNet + VGG-16 + ResNet-50 + both transformer GEMM sets — under
three execution modes and cross-checks that every ratio is bit-identical:

* ``reference``  — the legacy serial path (heap engine, no compiled
  windows, no layer memo, cold cache): the PR-3 execution model;
* ``cold_compiled`` — PR-4 execution model: compiled windows + layer
  memo, vectorized kernels off, empty caches;
* ``cold``       — vectorized window kernels + batched prefetch + memos,
  empty caches;
* ``warm``       — same, caches warm (what a persistent-store run sees).

Use ``python -m repro.experiments --section mapper`` for the full Pareto
artifact.
"""
import dataclasses
import time

from repro.experiments.sweeps import (DEFAULT_SWEEP, QUICK_SWEEP,
                                      mapper_csv_lines)


def run(jobs: int = 1, quick: bool = True) -> list[str]:
    base = QUICK_SWEEP if quick else DEFAULT_SWEEP
    return mapper_csv_lines(dataclasses.replace(base, jobs=jobs))


def run_full_perf(jobs: int = 1) -> tuple[list[str], dict]:
    """Time the full-space search; returns (csv lines, perf dict).

    "Cold" means cold: the recorded program/plan/route memos are cleared
    before the reference and cold phases, so earlier sections (or prior
    runs in this process) cannot subsidize the measurement.
    """
    from repro.core.noc.compiled import compiled_disabled
    from repro.core.noc.simcache import fresh_sim_cache
    from repro.core.noc.traffic import clear_compiled_caches
    from repro.core.noc.vectorized import vectorized_disabled
    from repro.experiments.sweeps import run_mapper

    sweep = dataclasses.replace(DEFAULT_SWEEP, jobs=jobs)
    serial = DEFAULT_SWEEP                      # jobs=1

    with fresh_sim_cache(), compiled_disabled():
        clear_compiled_caches()
        t0 = time.time()
        ref_out = run_mapper(serial)
        reference_s = time.time() - t0
    with fresh_sim_cache(), vectorized_disabled():
        clear_compiled_caches()
        t0 = time.time()
        cold_compiled_out = run_mapper(sweep)
        cold_compiled_s = time.time() - t0
    with fresh_sim_cache():
        clear_compiled_caches()
        t0 = time.time()
        cold_out = run_mapper(sweep)
        cold_s = time.time() - t0
        t0 = time.time()
        warm_out = run_mapper(sweep)
        warm_s = time.time() - t0
        if jobs == 1:                           # identical config: reuse
            warm_serial_out, warm_serial_s = warm_out, warm_s
        else:
            t0 = time.time()
            warm_serial_out = run_mapper(serial)
            warm_serial_s = time.time() - t0

    def sig(out):
        return [(r["workload"], r["latency_x"], r["energy_x"], r["hardware"])
                for r in out["rows"]]

    identical = sig(ref_out) == sig(cold_compiled_out) == sig(cold_out) \
        == sig(warm_out) == sig(warm_serial_out)
    if not identical:                            # must never ship silently
        raise AssertionError(
            "mapper ratios differ across execution modes: "
            f"ref={sig(ref_out)} compiled={sig(cold_compiled_out)} "
            f"cold={sig(cold_out)} warm={sig(warm_out)}")
    perf = {
        "space": "full",
        "jobs": jobs,
        "workloads": [r["workload"] for r in ref_out["rows"]],
        "reference_serial_s": reference_s,
        "optimized_cold_compiled_s": cold_compiled_s,
        "optimized_cold_s": cold_s,
        "optimized_warm_s": warm_s,
        "optimized_warm_serial_s": warm_serial_s,
        "speedup_cold": reference_s / cold_s,
        "speedup_warm": reference_s / warm_s,
        "speedup_warm_serial": reference_s / warm_serial_s,
        "speedup_vs_compiled_cold": cold_compiled_s / cold_s,
        "bit_identical": identical,
        "pinned_ratios": {r["workload"]: r["latency_x"]
                          for r in ref_out["rows"]},
    }
    lines = [
        f"mapper_full_reference,{reference_s * 1e6:.0f},engine=heap;jobs=1;cache=cold",
        f"mapper_full_cold_compiled,{cold_compiled_s * 1e6:.0f},engine=compiled;jobs={jobs};cache=cold",
        f"mapper_full_cold,{cold_s * 1e6:.0f},engine=vectorized;jobs={jobs};cache=cold",
        f"mapper_full_warm,{warm_s * 1e6:.0f},engine=vectorized;jobs={jobs};cache=warm",
        f"mapper_full_warm_serial,{warm_serial_s * 1e6:.0f},engine=vectorized;jobs=1;cache=warm",
        (f"mapper_full_speedup,0,cold={perf['speedup_cold']:.2f}x;"
         f"warm={perf['speedup_warm_serial']:.2f}x;"
         f"vs_compiled_cold={perf['speedup_vs_compiled_cold']:.2f}x;"
         f"bit_identical={identical}"),
    ]
    return lines, perf


if __name__ == "__main__":
    print("\n".join(run()))
