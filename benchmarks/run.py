# One function per paper table / subsystem section.  Prints the
# ``name,us_per_call,derived`` CSV; section failures become an attributable
# ``<section>_error`` row *and* a nonzero exit code (CI must not mistake a
# broken section for a clean sweep).
import argparse
import os
import sys

# Direct-script invocation (`python benchmarks/run.py`) puts benchmarks/ at
# sys.path[0]; the repo root (benchmarks package) and src/ (repro package)
# must both be importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


def _tables():
    from benchmarks import bench_tables
    return bench_tables.run()


def _ws_ina():
    from benchmarks import bench_ws_ina
    return bench_ws_ina.run()


def _ws_vs_os():
    from benchmarks import bench_ws_vs_os
    return bench_ws_vs_os.run()


def _kernels():
    from benchmarks import bench_kernels
    return bench_kernels.run()


def _collectives():
    from benchmarks import bench_collectives
    return bench_collectives.run()


def _mapper():
    from benchmarks import bench_mapper
    return bench_mapper.run()


def _roofline():
    if not os.path.exists("results/dryrun_singlepod.json"):
        return ["roofline_skipped,0,run_launch/dryrun_first"]
    from benchmarks import roofline
    return roofline.run()


SECTIONS = {
    "tables": _tables,
    "ws_ina": _ws_ina,
    "ws_vs_os": _ws_vs_os,
    "kernels": _kernels,
    "collectives": _collectives,
    "mapper": _mapper,
    "roofline": _roofline,
}


def _error_row(section: str, exc: Exception) -> str:
    # Keep the CSV parseable: no commas/newlines in the derived column.
    msg = f"{type(exc).__name__}: {exc}".replace(",", ";")
    msg = " ".join(msg.split())[:160]
    return f"{section}_error,0,{msg}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Run benchmark sections; print name,us_per_call,derived "
                    "CSV rows.")
    ap.add_argument("--sections", "--section", dest="sections",
                    default=",".join(SECTIONS),
                    help=f"comma-separated subset of {tuple(SECTIONS)}")
    args = ap.parse_args(argv)
    sections = [s for s in args.sections.split(",") if s]
    unknown = [s for s in sections if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown sections {unknown}; pick from {tuple(SECTIONS)}")

    lines = ["name,us_per_call,derived"]
    failed = []
    for section in sections:
        try:
            lines += SECTIONS[section]()
        except Exception as e:                              # noqa: BLE001
            failed.append(section)
            lines.append(_error_row(section, e))
    print("\n".join(lines))
    if failed:
        print(f"benchmark sections failed: {', '.join(failed)}",
              file=sys.stderr)
    return 1 if failed else 0


if __name__ == '__main__':
    sys.exit(main())
