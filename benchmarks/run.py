# One function per paper table / subsystem section.  Prints the
# ``name,us_per_call,derived`` CSV; section failures become an attributable
# ``<section>_error`` row *and* a nonzero exit code (CI must not mistake a
# broken section for a clean sweep).
#
# Every run also writes a machine-readable perf-trajectory snapshot
# ``BENCH_<n>.json`` at the repo root (per-section wall time + CSV rows,
# window-cache + vector-kernel stats, jobs, git rev) — the trajectory the
# roadmap's "fast as the hardware allows" goal is tracked against.
# ``--jobs`` fans the simulation sections over a process pool; ``--quick``
# selects the CI smoke shapes; the persistent window cache warms repeated
# runs (``--cache-dir`` / ``--no-persist``, see EXPERIMENTS.md).
#
# The trajectory is numbered by the PR that recorded each point, so it has
# gaps: there is no BENCH_6.json because PR 6 (the serving engine) landed
# no trajectory-grade full-space run.  Numbers are PR labels, not a dense
# sequence — ``_default_bench_path`` therefore always proposes a *fresh*
# number and never reuses an existing one.
import argparse
import json
import os
import re
import subprocess
import sys
import time

# Direct-script invocation (`python benchmarks/run.py`) puts benchmarks/ at
# sys.path[0]; the repo root (benchmarks package) and src/ (repro package)
# must both be importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

#: Sections whose perf dicts land under ``perf`` in the snapshot.
_PERF: dict = {}


def _tables(args):
    from benchmarks import bench_tables
    return bench_tables.run()


def _ws_ina(args):
    from benchmarks import bench_ws_ina
    return bench_ws_ina.run(jobs=args.jobs, quick=args.quick)


def _ws_vs_os(args):
    from benchmarks import bench_ws_vs_os
    return bench_ws_vs_os.run(jobs=args.jobs, quick=args.quick)


def _kernels(args):
    from benchmarks import bench_kernels
    return bench_kernels.run()


def _collectives(args):
    from benchmarks import bench_collectives
    return bench_collectives.run()


def _mapper(args):
    from benchmarks import bench_mapper
    return bench_mapper.run(jobs=args.jobs, quick=args.quick)


def _mapper_full(args):
    from benchmarks import bench_mapper
    lines, perf = bench_mapper.run_full_perf(jobs=args.jobs)
    _PERF["mapper_full"] = perf
    return lines


def _plan(args):
    from benchmarks import bench_plan
    lines, perf = bench_plan.run(quick=args.quick)
    _PERF["plan"] = perf
    return lines


def _serve(args):
    from benchmarks import bench_serve
    lines, perf = bench_serve.run(quick=args.quick)
    _PERF["serve"] = perf
    return lines


def _hierarchy(args):
    from benchmarks import bench_hierarchy
    lines, perf = bench_hierarchy.run(quick=args.quick)
    _PERF["hierarchy"] = perf
    return lines


def _faults(args):
    from benchmarks import bench_faults
    lines, perf = bench_faults.run(quick=args.quick)
    _PERF["faults"] = perf
    return lines


def _roofline(args):
    if not os.path.exists("results/dryrun_singlepod.json"):
        return ["roofline_skipped,0,run_launch/dryrun_first"]
    from benchmarks import roofline
    return roofline.run()


def _analysis(args):
    from benchmarks import bench_analysis
    lines, perf = bench_analysis.run(quick=args.quick)
    _PERF["analysis"] = perf
    return lines


SECTIONS = {
    "tables": _tables,
    "ws_ina": _ws_ina,
    "ws_vs_os": _ws_vs_os,
    "kernels": _kernels,
    "collectives": _collectives,
    "mapper": _mapper,
    "mapper_full": _mapper_full,
    "plan": _plan,
    "serve": _serve,
    "analysis": _analysis,
    "hierarchy": _hierarchy,
    "faults": _faults,
    "roofline": _roofline,
}

#: Default section list: everything except the (slow) full-space perf probe
#: under --quick.
def _default_sections(quick: bool) -> str:
    names = [s for s in SECTIONS if not (quick and s == "mapper_full")]
    return ",".join(names)


def _error_row(section: str, exc: Exception) -> str:
    # Keep the CSV parseable: no commas/newlines in the derived column.
    msg = f"{type(exc).__name__}: {exc}".replace(",", ";")
    msg = " ".join(msg.split())[:160]
    return f"{section}_error,0,{msg}"


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_ROOT,
            capture_output=True, text=True, timeout=10).stdout.strip() or "?"
    except (OSError, subprocess.SubprocessError):
        return "?"


def _default_bench_path(args, sections, root: str = None) -> str:
    """Where a snapshot goes when ``--bench-out`` is not given.

    The repo-root ``BENCH_<n>.json`` trajectory holds one
    *trajectory-grade* data point per PR (full shapes, mapper_full perf
    probe).  The default is always the **next free** number
    (``max(taken) + 1``): the trajectory is append-only, and because its
    numbers are PR labels with gaps (no BENCH_6.json — see the file
    docstring) "one past the highest" is the only default that can never
    land on an existing file and silently overwrite a recorded point.  A
    PR that wants a specific label states it with ``--bench-out
    BENCH_<n>.json``.  Quick or partial runs must not enter the record at
    all — they land in ``results/bench_snapshot.json`` instead.
    """
    root = root or _ROOT
    if args.quick or "mapper_full" not in sections:
        return os.path.join(root, "results", "bench_snapshot.json")
    taken = [int(m.group(1)) for f in os.listdir(root)
             if (m := re.fullmatch(r"BENCH_(\d+)\.json", f))]
    return os.path.join(root, f"BENCH_{max(taken) + 1 if taken else 4}.json")


def _write_snapshot(path, args, sections, section_stats, failed) -> None:
    from repro.core.noc.simcache import SIM_CACHE
    from repro.core.noc.vectorized import vector_stats
    snap = {
        "schema": 1,
        "git_rev": _git_rev(),
        "created_unix": time.time(),
        "argv": sys.argv[1:],
        "jobs": args.jobs,
        "quick": args.quick,
        "sections": section_stats,
        "failed": failed,
        "cache": SIM_CACHE.stats(),
        "vector": vector_stats(),
        "perf": _PERF,
    }
    with open(path, "w") as fh:
        json.dump(snap, fh, indent=2)
        fh.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Run benchmark sections; print name,us_per_call,derived "
                    "CSV rows and write a BENCH_<n>.json perf snapshot.")
    ap.add_argument("--sections", "--section", dest="sections", default=None,
                    help=f"comma-separated subset of {tuple(SECTIONS)}")
    ap.add_argument("--jobs", type=int, default=1, metavar="N",
                    help="process-pool width for simulation sections "
                         "(0 = all cores; default 1)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke shapes (quick sweep/mapper spaces)")
    ap.add_argument("--bench-out", default=None, metavar="PATH",
                    help="perf-snapshot path (default: next BENCH_<n>.json "
                         "at the repo root; 'none' disables)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persistent window-cache directory (default "
                         "$REPRO_SIMCACHE_DIR or results/.simcache)")
    ap.add_argument("--no-persist", action="store_true",
                    help="in-memory window cache only (no on-disk store)")
    args = ap.parse_args(argv)
    if args.jobs < 0:
        ap.error("--jobs must be >= 0 (0 = all cores)")
    if args.jobs == 0:
        from repro.exec import default_jobs
        args.jobs = default_jobs(None)
    sections = [s for s in
                (args.sections or _default_sections(args.quick)).split(",")
                if s]
    unknown = [s for s in sections if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown sections {unknown}; pick from {tuple(SECTIONS)}")

    if not args.no_persist:
        from repro.core.noc.simcache import SIM_CACHE
        SIM_CACHE.persist(args.cache_dir or SIM_CACHE.persist_default_dir())

    lines = ["name,us_per_call,derived"]
    failed = []
    section_stats = {}
    for section in sections:
        t0 = time.time()
        try:
            rows = SECTIONS[section](args)
            lines += rows
            section_stats[section] = {
                "status": "ok",
                "elapsed_us": (time.time() - t0) * 1e6,
                "rows": rows,
            }
        except Exception as e:                              # noqa: BLE001
            failed.append(section)
            row = _error_row(section, e)
            lines.append(row)
            section_stats[section] = {
                "status": "error",
                "elapsed_us": (time.time() - t0) * 1e6,
                "rows": [row],
            }
    print("\n".join(lines))

    bench_path = args.bench_out or _default_bench_path(args, sections)
    if bench_path.lower() != "none":
        try:
            os.makedirs(os.path.dirname(bench_path) or ".", exist_ok=True)
            _write_snapshot(bench_path, args, sections, section_stats, failed)
            print(f"perf snapshot: {bench_path}", file=sys.stderr)
        except OSError as e:
            print(f"could not write perf snapshot: {e}", file=sys.stderr)

    if failed:
        print(f"benchmark sections failed: {', '.join(failed)}",
              file=sys.stderr)
    return 1 if failed else 0


if __name__ == '__main__':
    sys.exit(main())
