# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import os
import sys


def main() -> None:
    from benchmarks import (bench_collectives, bench_kernels, bench_tables,
                            bench_ws_ina, bench_ws_vs_os)
    lines = ["name,us_per_call,derived"]
    lines += bench_tables.run()
    lines += bench_ws_ina.run()
    lines += bench_ws_vs_os.run()
    lines += bench_kernels.run()
    lines += bench_collectives.run()
    try:
        from benchmarks import roofline
        if os.path.exists("results/dryrun_singlepod.json"):
            lines += roofline.run()
        else:
            lines.append("roofline_skipped,0,run_launch/dryrun_first")
    except Exception as e:                                  # noqa: BLE001
        lines.append(f"roofline_error,0,{type(e).__name__}")
    print("\n".join(lines))


if __name__ == '__main__':
    main()
