"""ExecutionPlan layer: cold-vs-warm planning time over the full config set.

``run()`` builds one plan per (registry config, phase) twice through a
throwaway :class:`repro.plan.PlanStore`:

* **cold** — empty plan store (the sim cache keeps whatever the process
  already holds; the per-pass collective engine-run delta is reported so
  the snapshot separates trace time from simulation time);
* **warm** — second pass over the same store: every plan must load
  (0 builds) with **zero** collective engine runs — the acceptance
  criterion of DESIGN.md S11.

Returns ``(csv lines, perf dict)``; ``benchmarks/run.py --sections plan``
lands the perf dict in the ``BENCH_<n>.json`` trajectory snapshot.
"""
import shutil
import tempfile
import time


def run(quick: bool = False) -> tuple[list[str], dict]:
    # No jobs parameter on purpose: plan building is jax-trace-bound and
    # cannot fork (see sweeps.run_plan); the sweep is strictly serial.
    from repro.configs import ARCHS
    from repro.core.noc.collective.cost import COST_STATS
    from repro.plan import PlanStore

    phases = ("decode",) if quick else ("train", "prefill", "decode")
    mesh = (("data", 16), ("model", 16))
    space = "quick" if quick else "full"
    tmp = tempfile.mkdtemp(prefix="bench_plan_")
    try:
        store = PlanStore(tmp)

        def sweep() -> tuple[float, int, int]:
            runs0 = COST_STATS["engine_runs"]
            builds = 0
            t0 = time.time()
            for cfg in ARCHS.values():
                for phase in phases:
                    _, built = store.get_or_build(cfg, mesh, phase,
                                                  mapper_space=space)
                    builds += built
            return (time.time() - t0, builds,
                    COST_STATS["engine_runs"] - runs0)

        cold_s, cold_builds, cold_runs = sweep()
        warm_s, warm_builds, warm_runs = sweep()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    n = len(ARCHS) * len(phases)
    assert cold_builds == n, (cold_builds, n)
    assert warm_builds == 0 and warm_runs == 0, \
        f"warm store not warm: {warm_builds} builds, {warm_runs} sims"
    perf = {"configs": len(ARCHS), "phases": list(phases), "plans": n,
            "space": space, "jobs": 1, "cold_s": cold_s, "warm_s": warm_s,
            "speedup_x": cold_s / max(warm_s, 1e-9),
            "engine_runs_cold": cold_runs, "engine_runs_warm": warm_runs}
    lines = [
        f"plan_cold,{cold_s * 1e6 / n:.0f},plans={n};space={space};"
        f"engine_runs={cold_runs}",
        f"plan_warm,{warm_s * 1e6 / n:.0f},plans={n};"
        f"speedup_x={perf['speedup_x']:.1f};engine_runs=0",
    ]
    return lines, perf
