"""Paper Figs 10-12: WS+INA vs OS-with-gather latency/power improvement."""
import time

from repro.core.noc.power import ws_vs_os_improvement
from repro.core.workloads import WORKLOADS


def run(sim_rounds: int = 16) -> list[str]:
    lines = []
    for name, layers in WORKLOADS.items():
        for e in (1, 2, 4, 8):
            t0 = time.time()
            imp = ws_vs_os_improvement(name, layers, e, sim_rounds=sim_rounds)
            us = (time.time() - t0) * 1e6
            lines.append(f"fig10_12_{name}_E{e},{us:.0f},"
                         f"latency_x={imp.latency_x:.3f};"
                         f"energy_x={imp.energy_x:.3f};"
                         f"power_x={imp.power_x:.3f}")
    lines.append("fig10_12_note,0,paper=up_to_1.19x_latency_2.16x_power")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
