"""Serving subsystem: engine throughput, cluster-sim event rate, warm plans.

``run()`` produces three evidence groups for the BENCH trajectory:

* ``serve_engine`` — a reduced-config :class:`repro.serve.ServingEngine`
  executes a seeded workload end-to-end (continuous batching + paged KV +
  paged==monolithic checks): requests/s and tokens/s of the real jax path;
* ``serve_cluster`` — the request-level cluster simulator on a synthetic
  cost model: simulated events/s over a four-instance fleet;
* ``serve_plans_cold`` / ``serve_plans_warm`` — per-phase serving plans
  built twice through a throwaway :class:`repro.plan.PlanStore`: the warm
  pass must answer from the store with **zero** collective engine runs
  (the DESIGN.md S12 acceptance evidence — violations raise, which
  ``benchmarks/run.py`` turns into a ``serve_error`` row + nonzero exit).

Returns ``(csv lines, perf dict)``; ``benchmarks/run.py --sections serve``
lands the perf dict in the ``BENCH_<n>.json`` snapshot.
"""
import shutil
import tempfile
import time

_ARCH = "qwen2-1.5b"


def _engine_perf(quick: bool) -> dict:
    from repro.configs import ARCHS
    from repro.serve import ServingEngine, make_workload

    cfg = ARCHS[_ARCH].reduced()
    n = 4 if quick else 8
    reqs = make_workload(n, qps=0.0, prompt_dist="uniform:4:12",
                         gen_dist="uniform:2:6", seed=0, vocab=cfg.vocab,
                         prefix="b")
    eng = ServingEngine(cfg, slots=2, max_seq=cfg.max_seq, block_size=8,
                        prefill_chunk=4, check=True)
    t0 = time.time()
    report = eng.run(reqs)
    wall = time.time() - t0
    tokens = sum(len(r["tokens"]) for r in report.requests)
    return {"arch": f"{_ARCH} (reduced)", "requests": n, "tokens": tokens,
            "iterations": report.iterations, "checks": report.checks,
            "wall_s": wall, "requests_per_s": n / wall,
            "tok_per_s": tokens / wall}


def _cluster_perf(quick: bool) -> dict:
    from repro.serve import ClusterSimulator, SyntheticCostModel, make_workload

    n = 250 if quick else 1000
    reqs = make_workload(n, qps=5.0, prompt_dist="lognormal:128:0.5:512",
                         gen_dist="uniform:32:128", seed=0)
    sim = ClusterSimulator(4, slots=8, block_size=16, max_seq=1024,
                           prefill_chunk=64, cost=SyntheticCostModel())
    t0 = time.time()
    m = sim.run(reqs)
    wall = time.time() - t0
    return {"requests": n, "fleet": 4, "events": m["events"],
            "iterations": m["iterations"], "wall_s": wall,
            "events_per_s": m["events"] / wall,
            "p99_e2e_s": m["e2e_s"]["p99"]}


def _plans_perf() -> dict:
    from repro.configs import ARCHS
    from repro.serve import serve_plans

    tmp = tempfile.mkdtemp(prefix="bench_serve_")
    try:
        def sweep() -> tuple[float, int, int]:
            t0 = time.time()
            plans = serve_plans(ARCHS[_ARCH], (("data", 16), ("model", 16)),
                                plan_dir=tmp, verbose=False)
            sims = sum(info["collective_sims"] for _, info in plans.values())
            stored = sum(info["from_store"] for _, info in plans.values())
            return time.time() - t0, sims, stored

        cold_s, cold_sims, cold_stored = sweep()
        warm_s, warm_sims, warm_stored = sweep()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    assert cold_stored == 0, f"cold store answered {cold_stored} plans"
    assert warm_stored == 2 and warm_sims == 0, \
        f"warm store not warm: {warm_stored} from store, {warm_sims} sims"
    return {"arch": _ARCH, "phases": ["prefill", "decode"],
            "cold_s": cold_s, "warm_s": warm_s,
            "speedup_x": cold_s / max(warm_s, 1e-9),
            "collective_sims_cold": cold_sims,
            "collective_sims_warm": warm_sims}


def run(quick: bool = False) -> tuple[list[str], dict]:
    eng = _engine_perf(quick)
    clu = _cluster_perf(quick)
    pl = _plans_perf()
    perf = {"engine": eng, "cluster": clu, "plans": pl}
    lines = [
        f"serve_engine,{eng['wall_s'] * 1e6 / eng['requests']:.0f},"
        f"requests={eng['requests']};tok_s={eng['tok_per_s']:.1f};"
        f"iters={eng['iterations']};checks={eng['checks']}",
        f"serve_cluster,{clu['wall_s'] * 1e6 / max(clu['events'], 1):.2f},"
        f"events={clu['events']};events_per_s={clu['events_per_s']:.0f};"
        f"requests={clu['requests']};fleet={clu['fleet']}",
        f"serve_plans_cold,{pl['cold_s'] * 1e6 / 2:.0f},"
        f"plans=2;sims={pl['collective_sims_cold']}",
        f"serve_plans_warm,{pl['warm_s'] * 1e6 / 2:.0f},"
        f"plans=2;sims=0;speedup_x={pl['speedup_x']:.1f}",
    ]
    return lines, perf
