"""Chip-level INA: the K-blocked matmul's HBM-traffic contrast.

Wall-clock on CPU is meaningless for TPU kernels; the derived metric is the
compiled bytes-accessed difference between the eject/inject formulation
(per-K-block partials through HBM) and the fused single-pass matmul — the
traffic the VMEM-resident accumulator removes.  Correctness of the Pallas
kernel itself is covered by tests/test_kernels.py (interpret mode).
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def run() -> list[str]:
    lines = []
    m, k, n = 512, 4096, 512
    x = jnp.zeros((m, k), jnp.float32)
    w = jnp.zeros((k, n), jnp.float32)

    fused = jax.jit(lambda a, b: a @ b)
    eject = jax.jit(lambda a, b: ref.matmul_eject_inject(a, b, bk=512))

    from repro.compat import compiled_cost_analysis
    cf = compiled_cost_analysis(fused.lower(x, w).compile())
    ce = compiled_cost_analysis(eject.lower(x, w).compile())
    extra = ce.get("bytes accessed", 0) - cf.get("bytes accessed", 0)
    model_extra = (k // 512) * m * n * 4 * 2      # write+read per partial

    t0 = time.time()
    fused(x, w).block_until_ready()
    us = (time.time() - t0) * 1e6
    lines.append(f"kernel_matmul_fused,{us:.0f},"
                 f"bytes={cf.get('bytes accessed', 0):.3e}")
    t0 = time.time()
    eject(x, w).block_until_ready()
    us = (time.time() - t0) * 1e6
    lines.append(f"kernel_matmul_eject_inject,{us:.0f},"
                 f"bytes={ce.get('bytes accessed', 0):.3e};"
                 f"extra_vs_fused={extra:.3e};model_extra={model_extra:.3e}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
