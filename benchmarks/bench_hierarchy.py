"""Mesh-of-meshes layer: what does the hierarchy sweep cost, and how much
of it do the caches absorb?

Three measurements (DESIGN.md S14):

* **cost-facade sweep** — ``repro.experiments.run_hierarchy`` (the
  ``--section hierarchy`` CLI body) timed twice in-process: the second
  pass answers from the lru/SIM_CACHE layers the facade rides, so the
  ratio is the warm-sweep speedup a persistent store delivers across
  processes too;
* **engine replay** — every schedule of the shared hierarchy corpus
  (``repro.analysis.corpus.hier_schedules``) planned and replayed through
  ``run_hier_schedule`` on both engines (the ground truth the facade's
  numbers are pinned against in tests);
* **static verify** — ``verify_hier_schedule`` over the same corpus (the
  ``verify --sections hierarchy`` CI path).

Returns ``(csv lines, perf dict)``; ``benchmarks/run.py --sections
hierarchy`` lands the perf dict in the ``BENCH_<n>.json`` snapshot.
"""
import time


def run(quick: bool = False) -> tuple[list[str], dict]:
    from repro.analysis.corpus import hier_schedules
    from repro.analysis.verify import verify_hier_schedule
    from repro.core.noc.hierarchy import run_hier_schedule
    from repro.experiments.sweeps import (DEFAULT_SWEEP, QUICK_SWEEP,
                                          run_hierarchy)

    sweep = QUICK_SWEEP if quick else DEFAULT_SWEEP

    t0 = time.time()
    fig = run_hierarchy(sweep)
    sweep_s = time.time() - t0
    rows = len(fig["rows"])

    t0 = time.time()
    refig = run_hierarchy(sweep)
    resweep_s = time.time() - t0
    strip = lambda r: {k: v for k, v in r.items() if k != "elapsed_us"}  # noqa: E731
    assert [strip(r) for r in fig["rows"]] == \
           [strip(r) for r in refig["rows"]], "warm re-sweep changed rows"

    corpus = list(hier_schedules(quick=quick))
    t0 = time.time()
    for _case, sched in corpus:
        fast = run_hier_schedule(sched)
        slow = run_hier_schedule(sched, engine="heap")
        assert fast.latency_cycles == slow.latency_cycles
    engine_s = time.time() - t0

    t0 = time.time()
    findings = 0
    for _case, sched in corpus:
        findings += len(verify_hier_schedule(sched))
    verify_s = time.time() - t0
    assert findings == 0, f"{findings} finding(s) on the valid corpus"

    n = len(corpus)
    perf = {
        "rows": rows, "quick": quick,
        "sweep_s": sweep_s, "resweep_s": resweep_s,
        "resweep_x": sweep_s / max(resweep_s, 1e-9),
        "schedules": n, "engine_s": engine_s, "verify_s": verify_s,
        "headline": fig["headline"],
    }
    lines = [
        f"hier_sweep,{sweep_s * 1e6 / max(rows, 1):.0f},rows={rows}",
        f"hier_resweep,{resweep_s * 1e6 / max(rows, 1):.0f},rows={rows};"
        f"x_cold={perf['resweep_x']:.1f}",
        f"hier_engine,{engine_s * 1e6 / max(n, 1):.0f},schedules={n};"
        f"both_engines=1",
        f"hier_verify,{verify_s * 1e6 / max(n, 1):.0f},schedules={n};"
        f"findings=0",
    ]
    return lines, perf
