"""Roofline analysis: three terms per (arch x shape) from the dry-run JSON.

  compute    = HLO_FLOPs / peak_FLOP/s          (per device)
  memory     = HLO_bytes / HBM_bw               (per device)
  collective = collective_bytes / link_bw       (per device; DESIGN.md S6)

HLO_FLOPs/bytes come from the unrolled-marginal extrapolation recorded by
launch/dryrun.py (XLA's cost_analysis counts scan bodies once, so the raw
full-depth numbers are NOT usable).  MODEL_FLOPS = 6*N*D (train) or 2*N*D
(inference forward), N = non-embedding (activated) params.

Usage: PYTHONPATH=src python -m benchmarks.roofline results/dryrun_singlepod.json
"""
from __future__ import annotations

import json
import sys

import jax

from repro.configs import ARCHS, SHAPES
from repro.models.api import get_model

# TPU v5e-class hardware constants (per prompt)
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # B/s per chip
LINK_BW = 50e9            # B/s per ICI link


def activated_params(arch: str) -> tuple[int, int]:
    """(N_total_nonembed, N_activated_nonembed) from the real param tree."""
    cfg = ARCHS[arch]
    model = get_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = act = 0
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        n = 1
        for d in leaf.shape:
            n *= d
        if "embed" in name or "lm_head" in name or "pos_dec" in name:
            continue
        total += n
        if cfg.moe and ("w_gate" in name or "w_up" in name
                        or "w_down" in name) and len(leaf.shape) >= 3 \
                and "shared" not in name:
            act += n * cfg.moe.top_k / cfg.moe.num_experts
        else:
            act += n
    return int(total), int(act)


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D (train) / 2*N*D (inference fwd), D = tokens, per device."""
    cfg, shape = ARCHS[arch], SHAPES[shape_name]
    _, n_act = activated_params(arch)
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill") else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_act * tokens


def analyze(cell: dict) -> dict:
    r = cell.get("roofline")
    if not r:
        return {}
    devices = cell["devices"]
    compute_s = max(r["flops"], 0.0) / PEAK_FLOPS
    memory_s = max(r["bytes"], 0.0) / HBM_BW
    # tiny cells can show negative extrapolated marginals (compile noise
    # between the two unrolled costing points); clamp at zero
    collective_s = max(r["coll"], 0.0) / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell["arch"], cell["shape"]) / devices
    return {
        "arch": cell["arch"], "shape": cell["shape"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / r["flops"] if r["flops"] else 0.0,
        "roofline_fraction": terms[dominant] and compute_s / terms[dominant],
        "peak_hbm_bytes": cell["memory"]["temp_bytes"]
        + cell["memory"]["argument_bytes"],
    }


def render(results: list[dict]) -> str:
    rows = [analyze(c) for c in results if c.get("roofline")]
    rows = [r for r in rows if r]
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} |")
    return "\n".join(out)


def run(path: str = "results/dryrun_singlepod.json") -> list[str]:
    with open(path) as f:
        data = json.load(f)
    lines = []
    for cell in data["results"]:
        a = analyze(cell)
        if not a:
            continue
        lines.append(
            f"roofline_{a['arch']}_{a['shape']},0,"
            f"compute={a['compute_s']:.3e};memory={a['memory_s']:.3e};"
            f"collective={a['collective_s']:.3e};dominant={a['dominant']};"
            f"useful={a['useful_ratio']:.3f};frac={a['roofline_fraction']:.3f}")
    return lines


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_singlepod.json"
    with open(path) as f:
        data = json.load(f)
    print(render(data["results"]))
