"""Paper Figs 7-9: WS+INA vs WS-without-INA latency/power improvement."""
import time

from repro.core.noc.power import ws_ina_improvement
from repro.core.workloads import WORKLOADS


def run(sim_rounds: int = 16) -> list[str]:
    lines = []
    lat_all, enr_all = [], []
    for name, layers in WORKLOADS.items():
        for e in (1, 2, 4, 8):
            t0 = time.time()
            imp = ws_ina_improvement(name, layers, e, sim_rounds=sim_rounds)
            us = (time.time() - t0) * 1e6
            lat_all.append(imp.latency_x)
            enr_all.append(imp.energy_x)
            lines.append(f"fig7_9_{name}_E{e},{us:.0f},"
                         f"latency_x={imp.latency_x:.3f};"
                         f"energy_x={imp.energy_x:.3f};"
                         f"power_x={imp.power_x:.3f}")
    lines.append(f"fig7_9_average,0,latency_x={sum(lat_all)/len(lat_all):.3f};"
                 f"energy_x={sum(enr_all)/len(enr_all):.3f};"
                 f"paper=1.22x_latency_2.16x_power")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
