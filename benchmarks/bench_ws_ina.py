"""Paper Figs 7-9: WS+INA vs WS-without-INA latency/power improvement.

Thin wrapper over :mod:`repro.experiments` (the sweep subsystem); kept for
the ``benchmarks/run.py`` CSV contract.
"""
import dataclasses

from repro.experiments.sweeps import (DEFAULT_SWEEP, QUICK_SWEEP,
                                      fig7_9_csv_lines)


def run(sim_rounds: int = 16, jobs: int = 1, quick: bool = False) -> list[str]:
    base = QUICK_SWEEP if quick else DEFAULT_SWEEP
    sweep = dataclasses.replace(
        base, jobs=jobs,
        **({} if quick else {"sim_rounds": sim_rounds}))
    return fig7_9_csv_lines(sweep)


if __name__ == "__main__":
    print("\n".join(run()))
