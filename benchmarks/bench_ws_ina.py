"""Paper Figs 7-9: WS+INA vs WS-without-INA latency/power improvement.

Thin wrapper over :mod:`repro.experiments` (the sweep subsystem); kept for
the ``benchmarks/run.py`` CSV contract.
"""
import dataclasses

from repro.experiments.sweeps import DEFAULT_SWEEP, fig7_9_csv_lines


def run(sim_rounds: int = 16) -> list[str]:
    sweep = dataclasses.replace(DEFAULT_SWEEP, sim_rounds=sim_rounds)
    return fig7_9_csv_lines(sweep)


if __name__ == "__main__":
    print("\n".join(run()))
