"""Paper Tables I & II: INA round counts per CONV layer."""
from repro.core.ina_model import ina_table
from repro.core.workloads import ALEXNET, VGG16


def run() -> list[str]:
    lines = []
    for name, layers, n_list in (("alexnet", ALEXNET, (8, 16)),
                                 ("vgg16", VGG16, (8, 16))):
        for n in n_list:
            for row in ina_table(layers, n=n):
                ina = row["INA#"] if row["INA#"] is not None else "NA"
                lines.append(
                    f"table_{name}_N{n},{row['layer']},P#={row['P#']},"
                    f"INA#={ina}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
