"""Paper Tables I & II: INA round counts per CONV layer.

Thin wrapper over :mod:`repro.experiments` (the sweep subsystem); kept for
the ``benchmarks/run.py`` CSV contract.
"""
from repro.experiments.sweeps import tables_csv_lines


def run() -> list[str]:
    return tables_csv_lines()


if __name__ == "__main__":
    print("\n".join(run()))
