"""Pod-scale INA: per-link traffic + measured wall time of the psum modes
on 8 host devices (subprocess; the beyond-paper datacenter experiment),
plus the mesh-collective sweep over the NoC collective subsystem
(mesh size x collective x algorithm x router semantics x E PEs/router).

Run:  PYTHONPATH=src python benchmarks/bench_collectives.py [--mesh-only]
"""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core.collectives import per_link_bytes, psum_with_mode

mesh = Mesh(np.array(jax.devices()), ("model",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 256, 1024), jnp.float32)

for mode in ("eject_inject", "ina_ring", "ina"):
    f = jax.jit(shard_map(
        lambda xs, m=mode: psum_with_mode(xs[0], "model", m)[None],
        mesh=mesh, in_specs=P("model"), out_specs=P("model"),
        check_vma=False))
    f(x).block_until_ready()
    t0 = time.time()
    for _ in range(20):
        out = f(x)
    out.block_until_ready()
    us = (time.time() - t0) / 20 * 1e6
    bpl = per_link_bytes(mode, 8, x[0].nbytes)
    print(f"collective_{mode},{us:.0f},per_link_bytes={bpl:.0f}")
"""


def run() -> list[str]:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        return [f"collective_error,0,{proc.stderr[-200:]!r}"]
    return [l for l in proc.stdout.splitlines() if l.startswith("collective_")]


def mesh_sweep(mesh_sizes=(4, 8), e_pes=(1, 4),
               payload_bits_per_pe=1024) -> list[str]:
    """Simulated-mesh collective sweep: one row per (n, collective,
    algorithm, semantics, E) with latency cycles and network energy (pJ).
    ``E`` PEs per router scale the per-node payload, as in the paper's
    Figs. 7-9 sweep."""
    import dataclasses

    from repro.core.noc import NocConfig
    from repro.core.noc.collective import collective_cost, full_mesh

    variants = [
        ("reduce", "-", "ina"),
        ("reduce", "-", "eject_inject"),
        ("broadcast", "-", "ina"),
        ("broadcast", "-", "eject_inject"),
        ("gather", "-", "ina"),
        ("gather", "-", "eject_inject"),
        ("allreduce", "reduce_bcast", "ina"),
        ("allreduce", "reduce_bcast", "eject_inject"),
        ("allreduce", "rs_ag", "ina"),
        ("allreduce", "rs_ag", "eject_inject"),
    ]
    rows = ["mesh_collective,n,op,algorithm,semantics,e_pes,"
            "latency_cycles,energy_pj,packets"]
    for n in mesh_sizes:
        cfg = dataclasses.replace(NocConfig(), n=n)
        parts = full_mesh(n)
        for e in e_pes:
            payload = payload_bits_per_pe * e
            for op, algo, sem in variants:
                c = collective_cost(op, payload, cfg, participants=parts,
                                    algorithm=algo if algo != "-"
                                    else "reduce_bcast", semantics=sem)
                rows.append(
                    f"mesh_collective,{n},{op},{algo},{sem},{e},"
                    f"{c.latency_cycles},{c.energy_pj:.1f},{c.packets}")
    return rows


if __name__ == "__main__":
    print("\n".join(mesh_sweep()))
    if "--mesh-only" not in sys.argv:
        print("\n".join(run()))
