"""Pod-scale INA: per-link traffic + measured wall time of the psum modes
on 8 host devices (subprocess; the beyond-paper datacenter experiment)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp, numpy as np
from jax import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.collectives import per_link_bytes, psum_with_mode

mesh = Mesh(np.array(jax.devices()), ("model",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 256, 1024), jnp.float32)

for mode in ("eject_inject", "ina_ring", "ina"):
    f = jax.jit(shard_map(
        lambda xs, m=mode: psum_with_mode(xs[0], "model", m)[None],
        mesh=mesh, in_specs=P("model"), out_specs=P("model"),
        check_vma=False))
    f(x).block_until_ready()
    t0 = time.time()
    for _ in range(20):
        out = f(x)
    out.block_until_ready()
    us = (time.time() - t0) / 20 * 1e6
    bpl = per_link_bytes(mode, 8, x[0].nbytes)
    print(f"collective_{mode},{us:.0f},per_link_bytes={bpl:.0f}")
"""


def run() -> list[str]:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        return [f"collective_error,0,{proc.stderr[-200:]!r}"]
    return [l for l in proc.stdout.splitlines() if l.startswith("collective_")]


if __name__ == "__main__":
    print("\n".join(run()))
