"""Static-analysis layer: what does verification cost vs actually running?

Two comparisons over the shared corpora (``repro.analysis.corpus``):

* **static vs replay** — per fig7-12 WS program, the full static pass
  (``verify_program`` + compile + ``verify_compiled`` ledger conservation)
  against the bit-exact double replay the test suite would otherwise lean
  on (compiled run + heap run + equality check).  The static pass proves
  route/DAG/CDG/ledger facts the replay can only witness, and the ratio is
  the cost argument for running it in CI on every artifact;
* **plan verification** — ``verify_plan(check_layers=True)`` over every
  plan persisted in the default store (the 30-cell (config x phase) sweep
  when warm), i.e. the ``verify --sections plans`` CI path.

Plus the determinism lint over ``src/`` (one full AST pass per module).

Returns ``(csv lines, perf dict)``; ``benchmarks/run.py --sections
analysis`` lands the perf dict in the ``BENCH_<n>.json`` snapshot.
"""
import time


def run(quick: bool = False) -> tuple[list[str], dict]:
    from repro.analysis.corpus import ws_programs
    from repro.analysis.lint import lint_paths
    from repro.analysis.verify import (verify_compiled, verify_plan,
                                       verify_program)
    from repro.core.noc.collective.engine import run_program
    from repro.core.noc.compiled import compile_program
    from repro.plan.store import PlanStore

    corpus = list(ws_programs(quick=quick, window=2))

    t0 = time.time()
    findings = 0
    for shape, cfg, prog in corpus:
        findings += len(verify_program(prog, cfg))
        cp = compile_program(prog, cfg)
        findings += len(verify_compiled(cp, prog, cfg))
    static_s = time.time() - t0
    assert findings == 0, f"{findings} finding(s) on the valid corpus"

    t0 = time.time()
    for shape, cfg, prog in corpus:
        fast = run_program(prog, cfg)                      # compiled replay
        slow = run_program(prog, cfg, engine="heap")       # ground truth
        assert fast.latency_cycles == slow.latency_cycles
        assert fast.ledger == slow.ledger
    replay_s = time.time() - t0

    store = PlanStore()
    t0 = time.time()
    plans = 0
    for path in sorted(store.dir.glob("*.json")) if store.dir.exists() else []:
        plan = store.load(path.stem)
        if plan is None:
            continue
        plans += 1
        assert verify_plan(plan, check_layers=True) == [], path.stem
    plan_s = time.time() - t0

    t0 = time.time()
    lint = lint_paths(["src"])
    lint_s = time.time() - t0
    assert lint == [], f"{len(lint)} lint finding(s) in src/"

    n = len(corpus)
    perf = {
        "programs": n, "quick": quick,
        "static_s": static_s, "replay_s": replay_s,
        "replay_over_static_x": replay_s / max(static_s, 1e-9),
        "plans_verified": plans, "plan_verify_s": plan_s,
        "lint_s": lint_s,
    }
    lines = [
        f"analysis_static,{static_s * 1e6 / max(n, 1):.0f},programs={n}",
        f"analysis_replay,{replay_s * 1e6 / max(n, 1):.0f},programs={n};"
        f"x_static={perf['replay_over_static_x']:.1f}",
        f"analysis_plans,{plan_s * 1e6 / max(plans, 1):.0f},plans={plans};"
        f"check_layers=1",
        f"analysis_lint,{lint_s * 1e6:.0f},findings=0",
    ]
    return lines, perf
