"""Fault-tolerance subsystem: detour routing, tree repair, degraded sim.

``run()`` produces four evidence groups for the BENCH trajectory:

* ``faults_route`` — deadlock-safe detour derivation (west-first + up*/
  down*) over every routable (src, dst) pair of a seeded faulted mesh:
  routes/s and the routability fraction under each rule;
* ``faults_plan`` — :func:`plan_collective` with tree repair over the
  faulted corpus densities: repaired programs/s;
* ``faults_verify`` — :func:`repro.analysis.verify.verify_faulted` over
  the same corpus (fault-route / fault-turn / fault-remap classes + the
  CDG pass on actual detour paths): artifacts/s, zero findings required;
* ``faults_cluster`` — the request-level cluster simulator under a
  seeded replica-failure trace and a fault-priced
  :class:`~repro.serve.costs.DegradedCostModel`: events/s + goodput.

Returns ``(csv lines, perf dict)``; ``benchmarks/run.py --sections
faults`` lands the perf dict in the ``BENCH_<n>.json`` snapshot.
"""
import time

_MESH_N = 8


def _route_perf(quick: bool) -> dict:
    from repro.core.noc.faults import (DETOUR_RULES, UnroutableError,
                                       detour_route, seeded_faults)

    n = 6 if quick else _MESH_N
    faults = seeded_faults(n, n, link_rate=0.08, router_rate=0.02, seed=3)
    nodes = [(x, y) for x in range(n) for y in range(n)
             if faults.router_ok((x, y))]
    pairs = [(s, d) for s in nodes for d in nodes if s != d]
    out = {"mesh_n": n, "pairs": len(pairs)}
    for rule in DETOUR_RULES:
        t0 = time.time()
        routed = 0
        for s, d in pairs:
            try:
                detour_route(s, d, faults, n, n, rule=rule)
                routed += 1
            except UnroutableError:
                pass
        wall = time.time() - t0
        out[rule] = {"routed": routed, "wall_s": wall,
                     "routes_per_s": len(pairs) / max(wall, 1e-9),
                     "routable_frac": routed / len(pairs)}
    return out


def _plan_perf(quick: bool) -> dict:
    from repro.analysis.corpus import faulted_collective_programs

    t0 = time.time()
    programs = ops = 0
    for _case, _cfg, _faults, prog in faulted_collective_programs(quick):
        programs += 1
        ops += len(prog)
    wall = time.time() - t0
    return {"programs": programs, "ops": ops, "wall_s": wall,
            "programs_per_s": programs / max(wall, 1e-9)}


def _verify_perf(quick: bool) -> dict:
    from repro.analysis.corpus import faulted_collective_programs
    from repro.analysis.verify import verify_faulted

    t0 = time.time()
    checked = findings = 0
    for case, cfg, faults, prog in faulted_collective_programs(quick):
        checked += 1
        findings += len(verify_faulted(
            prog, faults, cfg, op=case["op"],
            participants=case["participants"],
            algorithm=case["algorithm"], semantics=case["semantics"]))
    wall = time.time() - t0
    assert findings == 0, f"faulted corpus has {findings} finding(s)"
    return {"artifacts": checked, "findings": findings, "wall_s": wall,
            "artifacts_per_s": checked / max(wall, 1e-9)}


def _cluster_perf(quick: bool) -> dict:
    from repro.core.noc.faults import seeded_faults
    from repro.core.noc.router import NocConfig
    from repro.serve.cluster import ClusterSimulator, replica_failure_trace
    from repro.serve.costs import (DegradedCostModel, SyntheticCostModel,
                                   fault_slowdown)
    from repro.serve.traffic import make_workload

    n = 100 if quick else 400
    reqs = make_workload(n, qps=2.0, prompt_dist="lognormal:128:0.5:512",
                         gen_dist="uniform:32:128", seed=0)
    horizon = max(r.arrival for r in reqs)
    faults = seeded_faults(_MESH_N, _MESH_N, link_rate=0.08,
                           router_rate=0.02, seed=3)
    slowdown = fault_slowdown(faults, NocConfig(n=_MESH_N))
    trace = replica_failure_trace(4, horizon, mtbf_s=horizon * 0.3,
                                  mttr_s=horizon * 0.08, seed=0)
    sim = ClusterSimulator(4, slots=8, block_size=16, max_seq=1024,
                           prefill_chunk=64,
                           cost=DegradedCostModel(SyntheticCostModel(),
                                                  slowdown),
                           failures=trace)
    t0 = time.time()
    m = sim.run(reqs)
    wall = time.time() - t0
    return {"requests": n, "fleet": 4, "failure_events": len(trace),
            "slowdown": slowdown, "events": m["events"], "wall_s": wall,
            "events_per_s": m["events"] / max(wall, 1e-9),
            "goodput": m["goodput"], "retries": m["retries"],
            "p99_e2e_s": m["e2e_s"]["p99"]}


def run(quick: bool = False) -> tuple[list[str], dict]:
    rt = _route_perf(quick)
    pl = _plan_perf(quick)
    vf = _verify_perf(quick)
    cl = _cluster_perf(quick)
    perf = {"route": rt, "plan": pl, "verify": vf, "cluster": cl}
    wf, ud = rt["west_first"], rt["updown"]
    lines = [
        f"faults_route,{wf['wall_s'] * 1e6 / max(rt['pairs'], 1):.2f},"
        f"pairs={rt['pairs']};wf_frac={wf['routable_frac']:.3f};"
        f"ud_frac={ud['routable_frac']:.3f};"
        f"routes_per_s={wf['routes_per_s']:.0f}",
        f"faults_plan,{pl['wall_s'] * 1e6 / max(pl['programs'], 1):.0f},"
        f"programs={pl['programs']};ops={pl['ops']};"
        f"programs_per_s={pl['programs_per_s']:.1f}",
        f"faults_verify,{vf['wall_s'] * 1e6 / max(vf['artifacts'], 1):.0f},"
        f"artifacts={vf['artifacts']};findings={vf['findings']};"
        f"artifacts_per_s={vf['artifacts_per_s']:.1f}",
        f"faults_cluster,{cl['wall_s'] * 1e6 / max(cl['events'], 1):.2f},"
        f"events={cl['events']};goodput={cl['goodput']:.3f};"
        f"retries={cl['retries']};slowdown={cl['slowdown']:.3f}",
    ]
    return lines, perf
