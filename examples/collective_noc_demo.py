"""Collective-capable NoC demo: trees, schedules, simulated costs.

Walks the whole subsystem end-to-end:

1. build a reduction tree for an arbitrary participant set and show its
   structure,
2. lower an allreduce under both algorithms and both router semantics and
   simulate latency/energy,
3. show the paper's WS+INA gather as the degenerate single-column schedule,
4. let the cost facade pick the best PsumMode for a JAX-side tensor the way
   ``psum_with_mode(..., mode="auto")`` does at trace time.

Run:  PYTHONPATH=src python examples/collective_noc_demo.py
"""
from repro.core.noc import NocConfig
from repro.core.noc.collective import (
    choose_psum_mode, collective_cost, full_mesh, mesh_column,
    plan_collective, psum_mode_costs, reduction_tree, run_program, segments)

CFG = NocConfig()

if __name__ == "__main__":
    # --- 1. a reduction tree over an arbitrary subset --------------------- #
    parts = [(1, 1), (6, 6), (0, 3), (5, 2), (7, 0), (3, 7)]
    tree = reduction_tree((0, 3), parts)
    print("=== reduction tree over an arbitrary 6-node subset ===")
    print(f"root {tree.root}, {len(tree.nodes)} tree nodes "
          f"({len(tree.nodes) - len(parts)} pure forwarders), "
          f"{len(segments(tree))} segments")
    for seg in segments(tree):
        print(f"  segment {seg[0]} -> {seg[-1]}  ({len(seg) - 1} hops)")

    # --- 2. allreduce: algorithm x semantics ------------------------------ #
    print("\n=== full-mesh allreduce (8x8, 1 Kbit/operand) ===")
    print(f"{'algorithm':<14} {'semantics':<13} {'latency':>8} {'energy pJ':>12}")
    for algo in ("reduce_bcast", "rs_ag"):
        for sem in ("ina", "eject_inject"):
            c = collective_cost("allreduce", 1024, CFG,
                                participants=full_mesh(CFG.n),
                                algorithm=algo, semantics=sem)
            print(f"{algo:<14} {sem:<13} {c.latency_cycles:>8} "
                  f"{c.energy_pj:>12.1f}")

    # --- 3. the paper's WS gather as a one-column schedule ---------------- #
    print("\n=== the paper's WS+INA column gather, planner-emitted ===")
    col = mesh_column(CFG.n, 2)
    for sem in ("ina", "eject_inject"):
        prog = plan_collective("reduce", col[:-1], 32, CFG,
                               root=col[-1], semantics=sem)
        res = run_program(prog, CFG)
        print(f"  {sem:<13} {len(prog)} packet(s), "
              f"{res.latency_cycles} cycles, "
              f"{res.ledger.network_energy_pj(CFG):.1f} pJ")
    print("  (single column + INA = the Fig. 4(b) gather chain; "
          "eject_inject = Fig. 4(a))")

    # --- 4. simulated-mesh PsumMode selection ----------------------------- #
    print("\n=== PsumMode selection from simulated mesh numbers ===")
    for nbytes in (1 << 10, 1 << 16, 1 << 22):
        costs = psum_mode_costs(8, nbytes)
        pick = choose_psum_mode(8, nbytes)
        line = "  ".join(f"{m}={c.latency_cycles}cyc"
                         for m, c in costs.items() if m != "xla")
        print(f"  {nbytes:>8} B: {line}  -> auto picks {pick!r}")
