"""Quickstart: the paper's INA in 60 lines.

1) the analytical model (Tables I/II),
2) the NoC simulation headline (Fig. 7: WS+INA vs WS-without),
3) the pod-scale collective analogue on 8 host devices.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
if not os.environ.get("XLA_FLAGS"):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.core.ina_model import ina_table
from repro.core.noc.power import ws_ina_improvement
from repro.core.collectives import (per_link_bytes, psum_ina,
                                    ring_psum_eject_inject)
from repro.core.workloads import ALEXNET

# --- 1. the paper's Eq. (1)-(3): which layers need INA, how many rounds ----
print("AlexNet INA rounds (paper Table I):")
for row in ina_table(ALEXNET, n=8):
    print(f"  {row['layer']}: P#={row['P#']}  INA#={row['INA#']}")

# --- 2. NoC simulation: the headline improvement ---------------------------
imp = ws_ina_improvement("alexnet", ALEXNET, e_pes=1, sim_rounds=16)
print(f"\nWS+INA vs WS-without-INA (8x8 mesh, 1 PE/router):")
print(f"  latency improvement {imp.latency_x:.2f}x   "
      f"network-energy improvement {imp.energy_x:.2f}x")
print("  (paper: up to 1.17x latency / 2.1x power for AlexNet)")

# --- 3. the same idea at pod scale: accumulate-while-routing ----------------
mesh = Mesh(np.array(jax.devices()), ("model",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 32, 64))
ref = x.sum(0)

ej = jax.jit(shard_map(lambda xs: ring_psum_eject_inject(xs[0], "model")[None],
                       mesh=mesh, in_specs=P("model"), out_specs=P("model")))
ina = jax.jit(shard_map(lambda xs: psum_ina(xs[0], "model")[None],
                        mesh=mesh, in_specs=P("model"), out_specs=P("model")))
np.testing.assert_allclose(np.asarray(ej(x)[0]), np.asarray(ref), rtol=1e-4)
np.testing.assert_allclose(np.asarray(ina(x)[0]), np.asarray(ref), rtol=1e-4)

nbytes = x[0].nbytes
print(f"\npod-scale psum of a {nbytes/1024:.0f} KiB partial over 8 devices:")
print(f"  eject/inject moves {per_link_bytes('eject_inject', 8, nbytes)/1024:.0f}"
      f" KiB per link; INA moves {per_link_bytes('ina', 8, nbytes)/1024:.0f} KiB"
      f" ({per_link_bytes('eject_inject', 8, nbytes)/per_link_bytes('ina', 8, nbytes):.1f}x less)")
print("quickstart OK")
