"""End-to-end driver: train a ~100M-param dense model for a few hundred
steps with INA psum accumulation on the host mesh.

This is the deliverable (b) end-to-end example: real data pipeline, real
AdamW, checkpointing, INA-mode tensor parallelism over the model axis of an
8-device host mesh.

Run:  PYTHONPATH=src python examples/train_ws_ina.py [--steps 200]
(CPU: ~100M params trains slowly; --small switches to a 10M config.)
"""
import os
if not os.environ.get("XLA_FLAGS"):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.api import get_model
from repro.optim.adamw import adamw_init
from repro.parallel.steps import build_train_step
from repro.parallel.tp import ParallelCtx
from repro.runtime.fault_tolerance import FTConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--psum-mode", default="ina",
                    choices=["xla_spmd", "ina", "ina_ring", "eject_inject"])
    ap.add_argument("--ckpt-dir", default="/tmp/ws_ina_ckpt")
    args = ap.parse_args()

    if args.small:
        cfg = ModelConfig(name="demo-10m", family="dense", n_layers=4,
                          d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                          vocab=8192, attn_chunk=256, dtype="float32")
        batch, seq = 8, 128
    else:
        # ~100M params: 12L x 768 x GQA + 32k vocab
        cfg = ModelConfig(name="demo-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                          vocab=32768, attn_chunk=512, dtype="float32")
        batch, seq = 8, 512

    model = get_model(cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    pctx = ParallelCtx(mesh=mesh, psum_mode=args.psum_mode)
    shape = ShapeConfig("train", seq, batch, "train")
    ts = build_train_step(model, mesh, shape, pctx, base_lr=3e-4,
                          warmup=20, total_steps=args.steps, donate=False)

    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            ts.param_sharding)
    opt = jax.device_put(adamw_init(params), ts.opt_sharding)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"[ws_ina] {cfg.name}: {n/1e6:.1f}M params, mesh {dict(mesh.shape)}, "
          f"psum={args.psum_mode}")

    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                    global_batch=batch))

    def step_fn(state, batch_host):
        p, o = state
        b = {k: jax.device_put(v, ts.batch_sharding[k])
             for k, v in batch_host.items()}
        p, o, stats = ts.fn(p, o, b)
        return (p, o), stats

    losses = []

    def on_metrics(step, m, dt):
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"  step {step:4d} loss {losses[-1]:.4f} ({dt*1e3:.0f} ms)")

    state, last, _ = run_training(
        step_fn, (params, opt), pipe.batch,
        ft=FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50),
        num_steps=args.steps, on_metrics=on_metrics)
    print(f"[ws_ina] loss {losses[0]:.4f} -> {losses[-1]:.4f} over {last} steps")
    assert losses[-1] < losses[0]
    print("train_ws_ina OK")


if __name__ == "__main__":
    main()
