"""Reproduce the paper's Figs 7-12 from the NoC simulator.

Run:  PYTHONPATH=src python examples/noc_sim_demo.py
"""
from repro.core.noc.power import ws_ina_improvement, ws_vs_os_improvement
from repro.core.workloads import WORKLOADS

if __name__ == "__main__":
    print("=== WS+INA vs WS-without-INA (paper Figs 7-9) ===")
    print(f"{'workload':<10} {'E':>2} {'latency x':>10} {'energy x':>10}")
    for name, layers in WORKLOADS.items():
        for e in (1, 2, 4, 8):
            imp = ws_ina_improvement(name, layers, e, sim_rounds=16)
            print(f"{name:<10} {e:>2} {imp.latency_x:>10.3f} "
                  f"{imp.energy_x:>10.3f}")

    print("\n=== WS+INA vs OS-with-gather (paper Figs 10-12) ===")
    print(f"{'workload':<10} {'E':>2} {'latency x':>10} {'energy x':>10}")
    for name, layers in WORKLOADS.items():
        for e in (1, 2, 4, 8):
            imp = ws_vs_os_improvement(name, layers, e, sim_rounds=16)
            print(f"{name:<10} {e:>2} {imp.latency_x:>10.3f} "
                  f"{imp.energy_x:>10.3f}")
    print("\npaper headlines: 1.22x latency / 2.16x power (WS+INA vs WS);"
          "\n                 up to 1.19x latency, 2.16x power vs OS")
