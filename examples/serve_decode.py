"""Serve a small model with batched greedy decoding (INA-mode TP).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import os
if not os.environ.get("XLA_FLAGS"):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import subprocess
import sys

if __name__ == "__main__":
    # The serving driver is the public entry point; this example invokes it
    # the way a deployment would, on a 2x4 host mesh with INA enabled.
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "qwen2-1.5b", "--reduced", "--batch", "4",
           "--prompt-len", "12", "--gen", "20", "--model-parallel", "4",
           "--psum-mode", "ina"]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    sys.exit(subprocess.call(cmd, env=env))
