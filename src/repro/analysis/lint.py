"""Determinism lint: AST rules over ``src/`` (DESIGN.md S13).

The repo's artifacts are byte-deterministic by contract — simcache keys,
plan JSON, serve capacity reports, the seeded cluster sim.  This pass
checks the source-level habits that break that contract, with a small
registry of named rules:

``unseeded-random``
    Module-level ``random.*`` / ``numpy.random.*`` stream use (or a
    zero-argument ``Random()``/``default_rng()``) in sim/cost/plan/serve
    modules.  Seeded generator objects (``random.Random(seed)``) pass.
``wall-clock``
    ``time.time()``-family or ``datetime.now()``-family reads in the same
    modules; durations belong in ``repro.exec.timing.Stopwatch``
    (reporting modules like ``experiments/`` are out of scope — timing
    *is* their output).
``set-iteration``
    Iteration over a known-``set``-typed expression in an order-sensitive
    position (a ``for`` loop, a list/dict/generator comprehension,
    ``list()``/``tuple()``/``join()``) — set order varies with PYTHONHASHSEED
    for str/bytes keys and with insertion history otherwise.  Wrapping in
    ``sorted()`` (or folding through ``len``/``sum``/``min``/``max``/
    ``any``/``all``/``set``/``frozenset``) is the fix and is recognised.
    Known-set expressions are inferred per module: ``set``/``frozenset``
    constructors and literals, set operators, and any name or attribute
    annotated ``set``/``frozenset`` anywhere in the module.
``mutable-default``
    A ``list``/``dict``/``set`` literal or constructor as a parameter
    default (shared across calls).
``non-atomic-write``
    ``open(path, "w")`` / ``Path.write_text`` in persistence-bearing
    modules — artifacts must go through ``simcache.atomic_write_text`` so
    a crashed writer never leaves a torn file for the next reader.

Suppress a justified finding with a pragma on the offending line or the
line above::

    with open(lock_path, "w"):   # lint: allow(non-atomic-write)

``lint_paths()`` returns machine-readable :class:`~.findings.Finding`s;
``python -m repro.analysis lint src`` is the CLI (blocking in CI).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from pathlib import Path
from typing import Callable, Optional, Sequence

from .findings import Finding

#: Modules bound to the determinism contract: simulation/cost (the heap,
#: compiled, and vectorized engines — ``core/noc/`` is a prefix, so
#: ``core/noc/vectorized.py`` is in scope like the rest), planning,
#: serving, mapper search, the fault-tolerant runtime.  experiments/,
#: launch/, exec/ stay out — they report wall time and write logs by
#: design (duration reporting routes through ``exec.timing.Stopwatch``).
_DETERMINISM_SCOPE = ("repro/core/noc/", "repro/plan/", "repro/serve/",
                      "repro/mapper/", "repro/runtime/")

PRAGMA = "lint: allow"
_PRAGMA_RE = re.compile(r"#\s*lint:\s*allow\(([a-z-]+(?:\s*,\s*[a-z-]+)*)\)")


@dataclasses.dataclass(frozen=True)
class LintRule:
    """One registered rule: a pure function over a module's AST."""

    name: str
    description: str
    #: Path fragments the rule applies to; empty tuple = every file.
    scope: tuple[str, ...]
    #: (tree, source) -> [(lineno, message), ...]
    check: Callable[[ast.Module, str], list]


# --------------------------------------------------------------------------- #
# Shared AST helpers
# --------------------------------------------------------------------------- #
def _module_aliases(tree: ast.Module) -> dict[str, str]:
    """Local name -> dotted origin for imports (``np`` -> ``numpy``,
    ``from time import time`` -> ``time`` -> ``time.time``)."""
    alias: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                alias[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                alias[a.asname or a.name] = f"{node.module}.{a.name}"
    return alias


def _dotted(node: ast.expr, alias: dict[str, str]) -> Optional[str]:
    """Resolve an attribute chain / name to its dotted import origin."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(alias.get(node.id, node.id))
    return ".".join(reversed(parts))


# --------------------------------------------------------------------------- #
# unseeded-random
# --------------------------------------------------------------------------- #
_RANDOM_CTORS = {"random.Random", "numpy.random.default_rng",
                 "numpy.random.RandomState", "numpy.random.Generator"}


def _check_unseeded_random(tree: ast.Module, src: str) -> list:
    alias = _module_aliases(tree)
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func, alias)
        if dotted is None:
            continue
        if dotted in _RANDOM_CTORS:
            if not node.args and not node.keywords:
                hits.append((node.lineno,
                             f"{dotted}() without a seed is entropy-seeded; "
                             f"pass an explicit seed"))
            continue
        if dotted.startswith("random.") or dotted.startswith("numpy.random."):
            hits.append((node.lineno,
                         f"{dotted}() draws from the global stream; use a "
                         f"seeded Random/Generator object instead"))
    return hits


# --------------------------------------------------------------------------- #
# wall-clock
# --------------------------------------------------------------------------- #
_WALL_CLOCK = {"time.time", "time.time_ns", "time.monotonic",
               "time.monotonic_ns", "time.perf_counter",
               "time.perf_counter_ns", "time.process_time",
               "datetime.datetime.now", "datetime.datetime.utcnow",
               "datetime.date.today"}


def _check_wall_clock(tree: ast.Module, src: str) -> list:
    alias = _module_aliases(tree)
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func, alias)
            if dotted in _WALL_CLOCK:
                hits.append((node.lineno,
                             f"{dotted}() reads the wall clock; route "
                             f"timing through repro.exec.timing.Stopwatch "
                             f"(keeps artifacts time-free)"))
    return hits


# --------------------------------------------------------------------------- #
# mutable-default
# --------------------------------------------------------------------------- #
def _is_mutable_literal(node: ast.expr) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set,
                         ast.ListComp, ast.DictComp, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("list", "dict", "set", "defaultdict",
                                 "Counter", "OrderedDict", "deque"))


def _check_mutable_default(tree: ast.Module, src: str) -> list:
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            defaults = list(node.args.defaults) + \
                [d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                if _is_mutable_literal(d):
                    hits.append((d.lineno,
                                 "mutable default argument is shared "
                                 "across calls; default to None"))
    return hits


# --------------------------------------------------------------------------- #
# non-atomic-write
# --------------------------------------------------------------------------- #
def _check_non_atomic_write(tree: ast.Module, src: str) -> list:
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Name) and node.func.id == "open":
            mode = None
            if len(node.args) >= 2:
                mode = node.args[1]
            for kw in node.keywords:
                if kw.arg == "mode":
                    mode = kw.value
            if isinstance(mode, ast.Constant) and isinstance(mode.value, str) \
                    and ("w" in mode.value or "a" in mode.value):
                hits.append((node.lineno,
                             "direct open() write can leave a torn file; "
                             "use simcache.atomic_write_text"))
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "write_text":
            hits.append((node.lineno,
                         "Path.write_text is not atomic; use "
                         "simcache.atomic_write_text"))
    return hits


# --------------------------------------------------------------------------- #
# set-iteration
# --------------------------------------------------------------------------- #
_SET_ANN_RE = re.compile(r"\b(?:frozenset|set|Set|FrozenSet|AbstractSet)\b")
_SET_METHODS = ("union", "intersection", "difference",
                "symmetric_difference", "copy")
#: Order-insensitive consumers: iterating a set *inside* these is fine.
_UNORDERED_SINKS = ("sorted", "min", "max", "sum", "len", "any", "all",
                    "set", "frozenset")


def _annotated_set_names(tree: ast.Module) -> set:
    """Names/attributes annotated ``set``/``frozenset`` anywhere in the
    module (incl. function return annotations, so properties count)."""
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.AnnAssign) and node.annotation is not None:
            if _SET_ANN_RE.search(ast.unparse(node.annotation)):
                target = node.target
                if isinstance(target, ast.Name):
                    names.add(target.id)
                elif isinstance(target, ast.Attribute):
                    names.add(target.attr)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.returns is not None \
                    and _SET_ANN_RE.search(ast.unparse(node.returns)):
                names.add(node.name)
        elif isinstance(node, ast.arg) and node.annotation is not None:
            if _SET_ANN_RE.search(ast.unparse(node.annotation)):
                names.add(node.arg)
    return names


class _SetIterationVisitor(ast.NodeVisitor):
    _MSG = ("iteration order of a set depends on hashing; wrap in "
            "sorted() or fold through an order-insensitive reducer")

    def __init__(self, set_names, exempt):
        self.set_names = set_names
        self.exempt = exempt          # node ids under an unordered sink
        self.local_sets: set = set()
        self.hits: list = []

    # -- known-set expression inference -------------------------------- #
    def _is_set(self, node) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name):
                return f.id in ("set", "frozenset") or f.id in self.set_names
            if isinstance(f, ast.Attribute):
                if f.attr in _SET_METHODS and self._is_set(f.value):
                    return True
                return f.attr in self.set_names
            return False
        if isinstance(node, ast.Attribute):
            return node.attr in self.set_names
        if isinstance(node, ast.Name):
            return node.id in self.local_sets
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            return self._is_set(node.left) or self._is_set(node.right)
        if isinstance(node, ast.IfExp):
            return self._is_set(node.body) or self._is_set(node.orelse)
        return False

    # -- local tracking (in source order; one flat namespace is enough
    #    for lint purposes — shadowing across scopes over-approximates) - #
    def visit_Assign(self, node):
        self.generic_visit(node)
        for target in node.targets:
            if isinstance(target, ast.Name):
                if self._is_set(node.value):
                    self.local_sets.add(target.id)
                else:
                    self.local_sets.discard(target.id)

    def visit_AugAssign(self, node):
        self.generic_visit(node)
        if isinstance(node.target, ast.Name) and self._is_set(node.value):
            self.local_sets.add(node.target.id)

    # -- order-sensitive positions -------------------------------------- #
    def visit_For(self, node):
        if self._is_set(node.iter):
            self.hits.append((node.iter.lineno, self._MSG))
        self.generic_visit(node)

    def _visit_comp(self, node):
        if id(node) not in self.exempt:
            for gen in node.generators:
                if self._is_set(gen.iter):
                    self.hits.append((gen.iter.lineno, self._MSG))
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_GeneratorExp = _visit_comp
    visit_DictComp = _visit_comp
    # SetComp deliberately not order-sensitive: a set in, a set out.

    def visit_Call(self, node):
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("list", "tuple") \
                and len(node.args) == 1 and self._is_set(node.args[0]):
            self.hits.append((node.lineno, self._MSG))
        elif isinstance(f, ast.Attribute) and f.attr == "join" \
                and node.args and self._is_set(node.args[0]):
            self.hits.append((node.lineno, self._MSG))
        self.generic_visit(node)


def _check_set_iteration(tree: ast.Module, src: str) -> list:
    set_names = _annotated_set_names(tree)
    exempt: set = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        sinkish = (isinstance(f, ast.Name) and f.id in _UNORDERED_SINKS) or \
            (isinstance(f, ast.Attribute) and f.attr in _SET_METHODS)
        if sinkish:
            for a in node.args:
                exempt.add(id(a))
    visitor = _SetIterationVisitor(set_names, exempt)
    visitor.visit(tree)
    return visitor.hits


# --------------------------------------------------------------------------- #
# Registry and driver
# --------------------------------------------------------------------------- #
LINT_RULES: dict[str, LintRule] = {
    r.name: r for r in (
        LintRule("unseeded-random",
                 "global random stream / unseeded generator in "
                 "determinism-scoped modules",
                 _DETERMINISM_SCOPE, _check_unseeded_random),
        LintRule("wall-clock",
                 "wall-clock read in determinism-scoped modules",
                 _DETERMINISM_SCOPE, _check_wall_clock),
        LintRule("set-iteration",
                 "order-sensitive iteration over a set-typed expression",
                 (), _check_set_iteration),
        LintRule("mutable-default",
                 "mutable default argument",
                 (), _check_mutable_default),
        LintRule("non-atomic-write",
                 "persisted write bypassing atomic_write_text",
                 _DETERMINISM_SCOPE, _check_non_atomic_write),
    )
}


def _pragma_allows(lines: list, lineno: int, rule: str) -> bool:
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = _PRAGMA_RE.search(lines[ln - 1])
            if m and rule in [s.strip() for s in m.group(1).split(",")]:
                return True
    return False


def count_pragmas(paths: Sequence) -> int:
    """Total ``# lint: allow`` pragmas under ``paths`` (budget metric)."""
    total = 0
    for f in _py_files(paths):
        total += len(_PRAGMA_RE.findall(f.read_text()))
    return total


def _py_files(paths: Sequence) -> list:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_file(path, rules: Optional[Sequence[LintRule]] = None
              ) -> list[Finding]:
    path = Path(path)
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as exc:
        return [Finding("parse-error", f"{path}:{exc.lineno}", str(exc))]
    lines = src.splitlines()
    try:
        display = os.path.relpath(path)
    except ValueError:
        display = str(path)
    posix = "/" + path.resolve().as_posix().lstrip("/")
    out: list = []
    for rule in (rules if rules is not None else LINT_RULES.values()):
        if rule.scope and not any(f"/{frag}" in posix
                                  for frag in rule.scope):
            continue
        for lineno, message in rule.check(tree, src):
            if _pragma_allows(lines, lineno, rule.name):
                continue
            out.append((lineno, Finding(rule.name, f"{display}:{lineno}",
                                        message)))
    return [f for _, f in sorted(out, key=lambda x: (x[0], x[1].check))]


def lint_paths(paths: Sequence,
               rules: Optional[Sequence[LintRule]] = None) -> list[Finding]:
    """Run the registry (or ``rules``) over every ``*.py`` under
    ``paths``; returns pragma-filtered findings in (file, line) order."""
    findings: list[Finding] = []
    for f in _py_files(paths):
        findings.extend(lint_file(f, rules))
    return findings
