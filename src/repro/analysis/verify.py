"""Static artifact verification — every check runs without the event loop.

The checks (ids are the ``Finding.check`` vocabulary):

``dep-dag``
    Dependency indices are prior-op indices (program order is topological,
    so dangling/forward deps and cycles are impossible when this holds);
    duplicates flagged; CompiledProgram ``children``/``dep_count`` agree
    with the dep edges.
``route``
    Every non-virtual op's route is a unit-step path inside the mesh
    (path overrides must start at ``src`` and end at ``dst``), the VC is
    within ``effective_vcs``, and every ``delivers`` target is reachable
    (the destination or a link head of the route) — a deliver target off
    the route would silently never fire in either engine.
``cdg-deadlock``
    The per-VC channel dependency graph (edges between consecutive links
    of each op's route, gem5-style) is acyclic.  Dimension-ordered XY
    routes only turn X->Y so they can never cycle; tree-embedding path
    overrides are sub-paths of XY routes and inherit that — a cyclic
    override (e.g. a ring of turning paths on one VC) is flagged.
``collective-fold`` / ``collective-deliver``
    Algebraic collective correctness from ``contribs``/``delivers``
    metadata: per reduce op the merged dependency contributions are
    pairwise disjoint and preserved, every participant's operand enters
    exactly once per chunk; reduce phases deliver only the chunk root,
    multicast phases deliver every destination exactly once; the union of
    delivered contributions matches the op's semantics end to end.
``ledger``
    Static-ledger conservation for a CompiledProgram: each op's energy
    tuple equals the path-determined counts recomputed from its route
    (flits x links, hops, NI crossings, adds), against the source
    PacketOps when available.
``plan-schema`` / ``plan-mode`` / ``plan-tile`` / ``plan-gemm``
    ExecutionPlan invariants: schema hash current; psum modes in
    ``AUTO_CANDIDATES`` and equal to the argmin of their recorded costs
    under the plan's objective; tile blocks divide their GEMM dims and fit
    the VMEM budget (priced by the same ``tile_working_set`` the planner
    uses), covering every distinct GEMM shape; gemm verdicts reference the
    model's real layers at the plan's token count.
``kvcache``
    Paged-KV free-list invariants: no block both free and mapped, no
    aliasing across tables, free + live == total, per-request lengths
    covered by their block tables.
``fault-route`` / ``fault-turn`` / ``fault-remap``
    Fault-repaired programs (DESIGN.md S15): no route crosses a failed
    link or router; every detour path is legal under a *single* turn
    rule for the whole program (west-first or up*/down* — mixing rules
    voids the per-rule deadlock argument); no dead or fabric-stranded PE
    appears in the contribution algebra, and the repaired collective
    folds/delivers exactly once over the usable participant set.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterable, Optional, Sequence

from repro.core.noc.router import NocConfig
from repro.core.noc.simulator import (effective_vcs, path_link_ids,
                                      route_link_ids)

from .findings import Finding, VerificationError

Coord = tuple

__all__ = [
    "verify_program", "verify_collective", "verify_compiled",
    "verify_schedule", "verify_hier_schedule", "verify_plan",
    "verify_allocator", "verify_kvcache", "verify_faulted",
    "check_program",
]


# --------------------------------------------------------------------------- #
# Packet programs: DAG shape, route legality, CDG deadlock freedom
# --------------------------------------------------------------------------- #
def _op_route(op, width: int, height: int):
    """``(strict_link_ids, links)`` for an op's route; strict ids are None
    when any hop is not an in-mesh unit step."""
    if op.path is not None:
        strict, _, links = path_link_ids(width, height, tuple(op.path))
    else:
        strict, _, links = route_link_ids(width, height, op.src, op.dst)
    return strict, links


def _is_virtual(op) -> bool:
    return op.flits == 0 and not op.inject and not op.eject


def verify_program(prog: Sequence, cfg: Optional[NocConfig] = None
                   ) -> list[Finding]:
    """Statically check one PacketOp program (no simulation)."""
    cfg = NocConfig() if cfg is None else cfg
    width, height = cfg.width, cfg.height
    vcs = effective_vcs(cfg)
    out: list[Finding] = []
    chains: list[tuple[int, tuple]] = []      # (vc, link ids) per routed op
    for i, op in enumerate(prog):
        where = f"op {i}" + (f" [{op.tag}]" if op.tag else "")
        seen_deps = set()
        for d in op.deps:
            if not (isinstance(d, int) and 0 <= d < i):
                out.append(Finding(
                    "dep-dag", where,
                    f"dep {d!r} is not a prior op index (program order "
                    f"must be topological)"))
            elif d in seen_deps:
                out.append(Finding("dep-dag", where, f"duplicate dep {d}"))
            seen_deps.add(d)
        if op.flits < 0:
            out.append(Finding("route", where,
                               f"negative flit count {op.flits}"))
        if _is_virtual(op):
            continue                           # no network resources touched
        if not 0 <= op.vc < vcs:
            out.append(Finding(
                "route", where,
                f"vc {op.vc} outside the config's 0..{vcs - 1}"))
        if op.path is not None:
            p = tuple(op.path)
            if not p or p[0] != tuple(op.src) or p[-1] != tuple(op.dst):
                out.append(Finding(
                    "route", where,
                    f"path override runs {p[0] if p else None}->"
                    f"{p[-1] if p else None}, op says {op.src}->{op.dst}"))
                continue
        strict, links = _op_route(op, width, height)
        if strict is None:
            out.append(Finding(
                "route", where,
                f"route {op.src}->{op.dst} takes a non-unit step or "
                f"leaves the {width}x{height} mesh"))
            continue
        reachable = {op.dst} | {b for _, b in links}
        if op.flits == 0:                      # completion delivers everything
            reachable |= set(op.delivers)
        for node in op.delivers:
            if node not in reachable:
                out.append(Finding(
                    "route", where,
                    f"delivers to {node}, which is neither the destination "
                    f"nor on the route {op.src}->{op.dst} (the engines "
                    f"would silently never deliver it)"))
        chains.append((op.vc, strict))
    out.extend(_cdg_findings(chains))
    return out


def _cdg_findings(chains: list) -> list[Finding]:
    """Channel-dependency-graph deadlock check: one channel per (vc, link);
    each op's route adds edges between its consecutive links; any cycle is
    a potential wormhole deadlock (Dally/Seitz condition)."""
    adj: dict = {}
    for vc, link_ids in chains:
        for a, b in zip(link_ids, link_ids[1:]):
            adj.setdefault((vc, a), set()).add((vc, b))
    adj = {k: sorted(v) for k, v in sorted(adj.items())}
    color: dict = {}                 # 1 = on stack, 2 = finished
    out: list[Finding] = []
    seen_msgs = set()
    for start in adj:
        if color.get(start):
            continue
        stack = [(start, iter(adj[start]))]
        path = [start]
        color[start] = 1
        while stack:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                c = color.get(nxt, 0)
                if c == 1:           # back edge: reconstruct the cycle
                    cyc = path[path.index(nxt):]
                    msg = (f"channel dependency cycle on vc {nxt[0]}: links "
                           + " -> ".join(str(l) for _, l in cyc + [nxt]))
                    if msg not in seen_msgs:
                        seen_msgs.add(msg)
                        out.append(Finding("cdg-deadlock",
                                           f"vc {nxt[0]}", msg))
                elif c == 0 and nxt in adj:
                    color[nxt] = 1
                    stack.append((nxt, iter(adj[nxt])))
                    path.append(nxt)
                    advanced = True
                    break
                elif c == 0:
                    color[nxt] = 2   # sink channel, no out-edges
            if not advanced:
                color[node] = 2
                stack.pop()
                path.pop()
    return out


# --------------------------------------------------------------------------- #
# Collective algebra from contribs/delivers metadata
# --------------------------------------------------------------------------- #
def _phase_of_tag(tag: str) -> Optional[str]:
    t = tag
    for suffix in (":self", ":eject", ":root"):
        if t.endswith(suffix):
            t = t[: -len(suffix)]
    if t in ("reduce", "ar:reduce", "gather") or t.startswith("rs["):
        return "reduce"
    if t in ("bcast", "ar:bcast") or t.startswith("ag["):
        return "multicast"
    return None


def verify_collective(prog: Sequence, *, op: str,
                      participants: Iterable, root=None,
                      algorithm: str = "reduce_bcast",
                      semantics: str = "ina") -> list[Finding]:
    """Check a ``plan_collective`` program's algebra without running it:
    fold-exactly-once per reduce chunk, deliver-exactly-once per multicast
    destination, and end-to-end delivered-contribution completeness."""
    parts = sorted(set(tuple(p) for p in participants))
    pset = frozenset(parts)
    root = parts[0] if root is None else tuple(root)
    rs_ag = op == "allreduce" and algorithm == "rs_ag"
    chunks = tuple(range(len(parts))) if rs_ag else (0,)
    chunk_root = {c: (parts[c] if rs_ag else root) for c in chunks}
    out: list[Finding] = []

    groups: dict[tuple[str, int], list[int]] = {}
    for i, o in enumerate(prog):
        phase = _phase_of_tag(o.tag)
        if phase is None:
            out.append(Finding("collective-fold", f"op {i}",
                               f"unrecognised collective tag {o.tag!r}"))
            continue
        groups.setdefault((phase, o.chunk), []).append(i)

    # -- reduce phases: every participant's operand folded exactly once -- #
    if op != "broadcast":
        for c in chunks:
            where = f"chunk {c}"
            idxs = groups.get(("reduce", c), [])
            if not idxs:
                out.append(Finding("collective-fold", where,
                                   "no reduce-phase ops for this chunk"))
                continue
            in_group = set(idxs)
            first = Counter()
            for i in idxs:
                o = prog[i]
                dep_sets = [prog[d].contribs for d in o.deps
                            if d in in_group]
                union = frozenset().union(*dep_sets) if dep_sets \
                    else frozenset()
                if sum(len(s) for s in dep_sets) != len(union):
                    out.append(Finding(
                        "collective-fold", f"op {i}",
                        "merged dependency contributions overlap — an "
                        "operand would be folded twice"))
                if not union <= o.contribs:
                    lost = sorted(union - o.contribs)
                    out.append(Finding(
                        "collective-fold", f"op {i}",
                        f"contributions {lost} arriving via deps are "
                        f"dropped by the merge"))
                for p in sorted(o.contribs - union):
                    first[p] += 1
            for p in parts:
                k = first.get(p, 0)
                if k != 1:
                    out.append(Finding(
                        "collective-fold", where,
                        f"participant {p} operand folded {k} times "
                        f"(expected exactly once)"))
            for p in sorted(set(first) - pset):
                out.append(Finding("collective-fold", where,
                                   f"non-participant {p} contributes"))
            deliv = Counter()
            for i in idxs:
                for node in prog[i].delivers:
                    deliv[node] += 1
            r = chunk_root[c]
            for node in sorted(set(deliv) - {r}):
                out.append(Finding(
                    "collective-deliver", where,
                    f"reduce phase delivers to {node}; only the chunk "
                    f"root {r} may receive it"))
            got = deliv.get(r, 0)
            # The gather-unicast lowering delivers the root one packet per
            # participant by design; everything else is exactly-once.
            if (got != 1 if op != "gather" else got < 1):
                out.append(Finding(
                    "collective-deliver", where,
                    f"root {r} receives the reduced value {got} times"))

    # -- multicast phases: every destination delivered exactly once ------ #
    if op in ("broadcast", "allreduce"):
        expected = frozenset({root}) if op == "broadcast" else pset
        for c in chunks:
            where = f"chunk {c}"
            idxs = groups.get(("multicast", c), [])
            if not idxs:
                out.append(Finding("collective-deliver", where,
                                   "no multicast-phase ops for this chunk"))
                continue
            deliv = Counter()
            for i in idxs:
                o = prog[i]
                if o.contribs != expected:
                    out.append(Finding(
                        "collective-fold", f"op {i}",
                        f"multicast payload carries contributions "
                        f"{sorted(o.contribs)}, expected "
                        f"{sorted(expected)}"))
                for node in o.delivers:
                    deliv[node] += 1
            receivers = (pset - {chunk_root[c]}) or {chunk_root[c]}
            for node in sorted(receivers):
                k = deliv.get(node, 0)
                if k != 1:
                    out.append(Finding(
                        "collective-deliver", where,
                        f"destination {node} delivered {k} times "
                        f"(expected exactly once)"))
            for node in sorted(set(deliv) - set(receivers)):
                out.append(Finding(
                    "collective-deliver", where,
                    f"unexpected multicast delivery to {node}"))

    # -- end-to-end completeness ---------------------------------------- #
    from repro.core.noc.collective.schedule import delivered_contribs
    got = delivered_contribs(prog)

    def want(node, chunk, contribs, role):
        have = got.get(node, {}).get(chunk, frozenset())
        if have != contribs:
            out.append(Finding(
                "collective-deliver", f"chunk {chunk}",
                f"{role} {node} ends with contributions "
                f"{sorted(have)}, expected {sorted(contribs)}"))

    if op in ("reduce", "gather"):
        want(root, 0, pset, "root")
    elif op == "broadcast":
        for p in parts:
            if p != root or len(parts) == 1:
                want(p, 0, frozenset({root}), "destination")
    else:                                       # allreduce
        for c in chunks:
            for p in parts:
                want(p, c, pset, "participant")
    return out


# --------------------------------------------------------------------------- #
# Compiled programs: static-ledger conservation
# --------------------------------------------------------------------------- #
def verify_compiled(cp, prog: Optional[Sequence] = None,
                    cfg: Optional[NocConfig] = None) -> list[Finding]:
    """Check a CompiledProgram's flat encoding against itself and, when
    the source PacketOps are given, against a fresh route derivation."""
    out: list[Finding] = []
    n = cp.n
    if not (len(cp.ops) == len(cp.children) == len(cp.dep_count) == n):
        out.append(Finding(
            "ledger", "compiled",
            f"array lengths disagree: n={n}, ops={len(cp.ops)}, "
            f"children={len(cp.children)}, dep_count={len(cp.dep_count)}"))
        return out
    derived_children: list[list[int]] = [[] for _ in range(n)]
    for i, top in enumerate(cp.ops):
        deps = top[2]
        if cp.dep_count[i] != len(deps):
            out.append(Finding("dep-dag", f"op {i}",
                               f"dep_count {cp.dep_count[i]} != "
                               f"{len(deps)} encoded deps"))
        for d in deps:
            if not (isinstance(d, int) and 0 <= d < i):
                out.append(Finding("dep-dag", f"op {i}",
                                   f"dep {d!r} is not a prior op index"))
            else:
                derived_children[d].append(i)
    for i in range(n):
        if tuple(derived_children[i]) != tuple(cp.children[i]):
            out.append(Finding(
                "dep-dag", f"op {i}",
                f"children {tuple(cp.children[i])} != "
                f"{tuple(derived_children[i])} derived from dep edges"))
    for i, top in enumerate(cp.ops):
        flits, inject, eject = top[4], top[5], top[6]
        n_links = len(top[7])
        e = tuple(top[12])
        # energy = (pe_adds, ni_flits, flit_routers, flit_links,
        #           packet_hops, router_adds, packets_built)
        shape = (e[0], e[1], flits * (n_links + 1), flits * n_links,
                 n_links, e[5], int(inject) + int(eject))
        if e != shape:
            out.append(Finding(
                "ledger", f"op {i}",
                f"energy tuple {e} inconsistent with its own route "
                f"({n_links} links, {flits} flits): expected {shape}"))
        if e[1] < flits * (int(inject) + int(eject)) - 1e-9:
            out.append(Finding(
                "ledger", f"op {i}",
                f"NI flits {e[1]} below the inject/eject floor "
                f"{flits * (int(inject) + int(eject))}"))
    if prog is None:
        return out
    if len(prog) != n:
        out.append(Finding("ledger", "compiled",
                           f"{n} compiled ops for {len(prog)} source ops"))
        return out
    cfg = NocConfig() if cfg is None else cfg
    for i, (op, top) in enumerate(zip(prog, cp.ops)):
        where = f"op {i}" + (f" [{op.tag}]" if op.tag else "")
        if tuple(top[2]) != tuple(op.deps):
            out.append(Finding("dep-dag", where,
                               f"compiled deps {top[2]} != source "
                               f"{tuple(op.deps)}"))
        virtual = _is_virtual(op)
        if top[3] != virtual:
            out.append(Finding("ledger", where,
                               f"virtual flag {top[3]} != {virtual}"))
        want_links: tuple = ()
        if not virtual:
            strict, _ = _op_route(op, cfg.width, cfg.height)
            if strict is None:
                out.append(Finding(
                    "route", where,
                    f"source route {op.src}->{op.dst} is not encodable in "
                    f"the {cfg.width}x{cfg.height} mesh, yet it compiled"))
                continue
            want_links = strict
        if tuple(top[7]) != tuple(want_links):
            out.append(Finding(
                "ledger", where,
                f"compiled link ids {top[7]} != {tuple(want_links)} "
                f"re-derived from the route"))
        nl = len(want_links)
        want_e = (op.pe_adds,
                  op.extra_ni_flits
                  + op.flits * (int(op.inject) + int(op.eject)),
                  op.flits * (nl + 1) if not virtual else 0,
                  op.flits * nl,
                  nl,
                  op.reduce_words,
                  int(op.inject) + int(op.eject))
        if tuple(top[12]) != want_e:
            out.append(Finding(
                "ledger", where,
                f"energy tuple {tuple(top[12])} != {want_e} recomputed "
                f"from the source op's path-determined counts"))
    return out


# --------------------------------------------------------------------------- #
# Mapper schedules
# --------------------------------------------------------------------------- #
def verify_schedule(sched, layers: Sequence,
                    base_cfg: Optional[NocConfig] = None) -> list[Finding]:
    """Re-emit every layer's packet program from a NetworkSchedule and
    verify each one (routes, DAG, CDG) under its own NocConfig."""
    base_cfg = NocConfig() if base_cfg is None else base_cfg
    by_name = {l.name: l for l in layers}
    out: list[Finding] = []
    missing = [a.layer for a in sched.assignments if a.layer not in by_name]
    for name in missing:
        out.append(Finding("plan-gemm", f"schedule:{name}",
                           "assignment references a layer not in the "
                           "workload"))
    if missing:
        return out
    for layer_name, cfg, prog in sched.programs(layers, base_cfg):
        for f in verify_program(prog, cfg):
            out.append(Finding(f.check, f"{layer_name}: {f.where}",
                               f.message))
    return out


# --------------------------------------------------------------------------- #
# Hierarchical schedules (mesh-of-meshes, DESIGN.md S14)
# --------------------------------------------------------------------------- #
#: Level name -> the collective op its chip lanes run.
_HIER_LEVEL_OPS = {"intra-reduce": "reduce", "intra-bcast": "broadcast"}


def _hier_lane_meta(prog: Sequence, op: str):
    """Derive ``(participants, root)`` from a lane program's metadata.

    Participants come from the contribution algebra the planners stamp on
    every op; the root is whoever the reduce phase delivers (broadcast
    lanes: whoever the payload's single contribution names)."""
    contrib_union: frozenset = frozenset()
    deliver_union: frozenset = frozenset()
    reduce_delivers: list = []
    for o in prog:
        contrib_union |= frozenset(o.contribs)
        deliver_union |= frozenset(o.delivers)
        if _phase_of_tag(o.tag) == "reduce":
            reduce_delivers.extend(o.delivers)
    if op == "broadcast":
        parts = sorted(deliver_union | contrib_union)
        root = sorted(contrib_union)[0] if contrib_union else \
            (parts[0] if parts else None)
    else:
        parts = sorted(contrib_union)
        root = reduce_delivers[0] if reduce_delivers else \
            (parts[0] if parts else None)
    return parts, root


def _verify_express_lane(lane, hmesh) -> tuple[list[Finding], list]:
    """Route legality of an express package lane + its CDG chains.

    Express channels are dedicated 2-node chip-root links: every routed op
    must carry a ``[src, dst]`` path override between valid chip-grid
    coordinates (that is what the heap engine resolves to per-channel
    overflow resources; anything else would alias on-die links)."""
    out: list[Finding] = []
    chains: list = []
    cx, cy = hmesh.chips_x, hmesh.chips_y
    width, height = lane.cfg.width, lane.cfg.height
    for i, o in enumerate(lane.prog):
        where = f"op {i}" + (f" [{o.tag}]" if o.tag else "")
        for d in o.deps:
            if not (isinstance(d, int) and 0 <= d < i):
                out.append(Finding(
                    "dep-dag", where,
                    f"dep {d!r} is not a prior op index"))
        if _is_virtual(o):
            continue
        for node in (tuple(o.src), tuple(o.dst)):
            if not (0 <= node[0] < cx and 0 <= node[1] < cy):
                out.append(Finding(
                    "hier-route", where,
                    f"{node} is not a chip coordinate of the "
                    f"{cx}x{cy} package grid"))
        if tuple(o.src) == tuple(o.dst):
            continue                     # root-local fold/eject, no channel
        p = tuple(tuple(n) for n in o.path) if o.path is not None else None
        if p is None or len(p) != 2 or p[0] != tuple(o.src) \
                or p[-1] != tuple(o.dst):
            out.append(Finding(
                "hier-route", where,
                f"express package op {o.src}->{o.dst} must ride a "
                f"dedicated 2-node channel (path override [src, dst]), "
                f"got {p}"))
            continue
        _, mixed, _ = path_link_ids(width, height, p)
        chains.append((("package", None, o.vc), mixed))
    return out, chains


def verify_hier_schedule(sched) -> list[Finding]:
    """Hierarchy invariants for a ``HierarchicalSchedule`` (DESIGN.md S14).

    ``hier-route``
        Chip-boundary legality: intra-chip lanes route strictly inside
        their chip's W x H mesh, mesh-package lanes inside the CX x CY
        chip grid, and express package lanes only over dedicated 2-node
        chip-root channels with valid chip-grid endpoints.
    ``hier-fold``
        Per-level fold-exactly-once: each chip lane folds its own
        participants exactly once into the chip root, the package level
        folds exactly the set of chips that produced partials (and
        broadcast levels deliver exactly the chips that continue
        intra-chip) — a dropped or duplicated chip lane is an algebra
        error, not a performance detail.
    ``cdg-deadlock``
        Deadlock freedom over the two-level channel graph: channels are
        namespaced per (scope, chip), so concurrent chip lanes cannot
        alias each other's links and package channels never alias on-die
        wires.
    """
    out: list[Finding] = []
    hmesh = sched.hmesh
    chains: list = []
    lane_meta: dict = {}                 # (level, label) -> (parts, root)
    for level, lane in sched.all_lanes():
        where = f"{level.name}/{lane.label}"
        express_pkg = lane.scope == "package" and hmesh.package == "express"
        if express_pkg:
            fs, lane_chains = _verify_express_lane(lane, hmesh)
            chains.extend(lane_chains)
        else:
            # A lane is an ordinary flat program under its own config;
            # out-of-mesh coords ARE chip-boundary violations here.  CDG
            # findings are dropped — the namespaced two-level pass below
            # covers them without double reporting.
            fs = [Finding("hier-route" if f.check == "route" else f.check,
                          f.where, f.message)
                  for f in verify_program(lane.prog, lane.cfg)
                  if f.check != "cdg-deadlock"]
            ns = (lane.scope, lane.chip)
            for o in lane.prog:
                if _is_virtual(o):
                    continue
                strict, _ = _op_route(o, lane.cfg.width, lane.cfg.height)
                if strict is not None:
                    chains.append(((*ns, o.vc), strict))
        out.extend(Finding(f.check, f"{where}: {f.where}", f.message)
                   for f in fs)

        # per-lane fold/deliver algebra
        lane_op = sched.op if level.name in ("flat", "package") \
            else _HIER_LEVEL_OPS.get(level.name)
        if lane_op not in ("reduce", "broadcast", "allreduce", "gather"):
            continue
        parts, root = _hier_lane_meta(lane.prog, lane_op)
        lane_meta[(level.name, lane.label)] = (parts, root, lane.chip)
        if not parts:
            out.append(Finding("hier-fold", where,
                               "lane carries no contribution metadata"))
            continue
        algorithm = sched.algorithm
        if express_pkg:
            algorithm = "reduce_bcast"   # the star degenerates rs_ag
        fs = verify_collective(lane.prog, op=lane_op, participants=parts,
                               root=root, algorithm=algorithm,
                               semantics=sched.semantics)
        out.extend(Finding("hier-fold", f"{where}: {f.where}", f.message)
                   for f in fs)

    # cross-level consistency: the package level must fold/deliver exactly
    # the chips whose lanes produced partials / continue the broadcast.
    if len(sched.levels) > 1:
        pkg = next((m for (lv, _), m in lane_meta.items()
                    if lv == "package"), None)
        if pkg is not None:
            pkg_chips = sorted(tuple(p) for p in pkg[0])
            for lv_name in ("intra-reduce", "intra-bcast"):
                lanes = [(label, m) for (lv, label), m in lane_meta.items()
                         if lv == lv_name]
                if not lanes:
                    continue
                intra = sorted(hmesh.chip_coord(m[2]) for _, m in lanes)
                if intra != pkg_chips:
                    out.append(Finding(
                        "hier-fold", f"{lv_name}<->package",
                        f"intra level covers chips {intra} but the "
                        f"package level names {pkg_chips} — a chip's "
                        f"partial would be dropped or double-counted"))
                for label, (parts, root, chip) in lanes:
                    if root != hmesh.chip_root_xy:
                        out.append(Finding(
                            "hier-fold", f"{lv_name}/{label}",
                            f"chip lane root {root} is not the chip root "
                            f"{hmesh.chip_root_xy} fronting the package "
                            f"link"))
    out.extend(_cdg_findings(chains))
    return out


# --------------------------------------------------------------------------- #
# Execution plans
# --------------------------------------------------------------------------- #
def verify_plan(plan, *, check_layers: bool = False) -> list[Finding]:
    """ExecutionPlan invariants (structural; ``check_layers=True`` also
    re-derives the model's GEMM layers, which imports jax)."""
    from repro.core.noc.collective.cost import AUTO_CANDIDATES
    from repro.plan.plan import plan_schema_hash
    from repro.plan.tiles import VMEM_BUDGET_BYTES, tile_working_set
    out: list[Finding] = []
    where = f"plan {plan.key}"
    current = plan_schema_hash()
    if plan.schema != current:
        out.append(Finding("plan-schema", where,
                           f"schema hash {plan.schema} is stale "
                           f"(current {current})"))
    if plan.objective not in ("latency", "energy"):
        out.append(Finding("plan-mode", where,
                           f"unknown objective {plan.objective!r}"))
    rank = {m: j for j, m in enumerate(AUTO_CANDIDATES)}
    for d in plan.psum:
        dwhere = f"{where} psum(p={d.p}, nbytes={d.nbytes})"
        if d.mode not in AUTO_CANDIDATES:
            out.append(Finding(
                "plan-mode", dwhere,
                f"resolved mode {d.mode!r} not in AUTO_CANDIDATES "
                f"{AUTO_CANDIDATES}"))
            continue
        if d.p < 1 or d.nbytes < 0 or d.count < 1:
            out.append(Finding("plan-mode", dwhere,
                               "non-positive span/payload/count"))
        if not d.costs:
            continue
        modes = tuple(m for m, _, _ in d.costs)
        if modes != AUTO_CANDIDATES:
            out.append(Finding(
                "plan-mode", dwhere,
                f"recorded cost candidates {modes} != AUTO_CANDIDATES"))
            continue
        col = 1 if plan.objective == "latency" else 2
        best = min(d.costs, key=lambda row: (row[col], rank[row[0]]))[0]
        if best != d.mode:
            out.append(Finding(
                "plan-mode", dwhere,
                f"stored mode {d.mode!r} is not the {plan.objective} "
                f"argmin of its recorded costs (that is {best!r})"))
    for t in plan.tiles:
        twhere = f"{where} tile({t.m}x{t.k}x{t.n}, {t.dtype})"
        if min(t.bm, t.bn, t.bk) < 1:
            out.append(Finding("plan-tile", twhere,
                               f"non-positive block ({t.bm},{t.bn},{t.bk})"))
            continue
        if t.m % t.bm or t.n % t.bn or t.k % t.bk:
            out.append(Finding(
                "plan-tile", twhere,
                f"blocks ({t.bm},{t.bn},{t.bk}) do not divide the GEMM "
                f"dims (the kernel asserts exact divisibility)"))
        ws = tile_working_set(t.bm, t.bn, t.bk, t.dtype)
        if ws > VMEM_BUDGET_BYTES:
            out.append(Finding(
                "plan-tile", twhere,
                f"working set {ws} bytes exceeds the VMEM budget "
                f"{VMEM_BUDGET_BYTES}"))
    if check_layers:
        out.extend(_plan_layer_findings(plan))
    return out


def _plan_layer_findings(plan) -> list[Finding]:
    from repro.configs import ARCHS
    from repro.models.api import get_model
    from repro.plan.plan import config_digest
    where = f"plan {plan.key}"
    cfg = ARCHS.get(plan.model)
    if cfg is None:
        return [Finding("plan-gemm", where,
                        f"model {plan.model!r} not in the config registry")]
    out: list[Finding] = []
    if plan.config and plan.config != config_digest(cfg):
        out.append(Finding(
            "plan-schema", where,
            "recorded config digest differs from the registry config "
            "(plan was built from different model contents)"))
        return out
    layers = get_model(cfg).gemm_layers(plan.tokens)
    by_name = {l.name: l for l in layers}
    for g in plan.gemms:
        gwhere = f"{where} gemm {g.layer}"
        layer = by_name.get(g.layer)
        if layer is None:
            out.append(Finding("plan-gemm", gwhere,
                               "verdict references a layer the model "
                               "does not produce"))
        elif (g.M, g.K, g.N) != (layer.M, layer.K, layer.N):
            out.append(Finding(
                "plan-gemm", gwhere,
                f"verdict shape {(g.M, g.K, g.N)} != model layer shape "
                f"{(layer.M, layer.K, layer.N)}"))
    covered = {(t.m, t.k, t.n) for t in plan.tiles
               if t.dtype == plan.dtype}
    for layer in layers:
        if (layer.M, layer.K, layer.N) not in covered:
            out.append(Finding(
                "plan-tile", f"{where} gemm {layer.name}",
                f"no tile choice covers GEMM shape "
                f"{(layer.M, layer.K, layer.N)} at dtype {plan.dtype}"))
    return out


# --------------------------------------------------------------------------- #
# Fault-repaired programs (DESIGN.md S15)
# --------------------------------------------------------------------------- #
def verify_faulted(prog: Sequence, faults, cfg: Optional[NocConfig] = None,
                   *, op: Optional[str] = None,
                   participants: Optional[Iterable] = None,
                   root=None, algorithm: str = "reduce_bcast",
                   semantics: str = "ina") -> list[Finding]:
    """Check a fault-repaired program against its FaultModel.

    Runs the structural pass (:func:`verify_program`, including the CDG
    deadlock check over the actual detour paths) and adds the fault
    classes: ``fault-route`` (no failed link/router on any route — an op
    without a path override is checked on the XY route the engines would
    derive), ``fault-turn`` (one turn rule covers every path), and, when
    collective metadata is supplied, ``fault-remap`` (the algebra closes
    over the usable participant set: dead or stranded PEs appear nowhere)
    plus the full fold/deliver-exactly-once pass over that set.
    """
    from repro.core.noc.faults import (path_is_updown, path_is_west_first,
                                       remap_participants, remap_root)
    from repro.core.noc.topology import xy_route_tuple
    cfg = NocConfig() if cfg is None else cfg
    width, height = cfg.width, cfg.height
    out = verify_program(prog, cfg)
    if faults.transient:
        out.append(Finding(
            "fault-route", "model",
            "FaultModel still carries transient faults — resolve a window "
            "with at_window() before planning/verifying"))
    routed: list[tuple[str, tuple]] = []
    for i, o in enumerate(prog):
        if _is_virtual(o):
            continue
        where = f"op {i}" + (f" [{o.tag}]" if o.tag else "")
        if o.path is not None:
            path = tuple(tuple(n) for n in o.path)
        else:
            path = xy_route_tuple(tuple(o.src), tuple(o.dst))
        for node in path:
            if not faults.router_ok(node):
                out.append(Finding("fault-route", where,
                                   f"route visits failed router {node}"))
        for a, b in zip(path, path[1:]):
            if not faults.link_ok(a, b):
                out.append(Finding("fault-route", where,
                                   f"route crosses failed link {a}<->{b}"))
        if len(path) > 2:            # 1-hop paths are legal under any rule
            routed.append((where, path))
    wf = {w for w, p in routed if path_is_west_first(p)}
    ud = {w for w, p in routed
          if path_is_updown(p, faults, width, height)}
    every = {w for w, _ in routed}
    if not (wf >= every or ud >= every):
        for where, _ in routed:
            if where not in wf and where not in ud:
                out.append(Finding(
                    "fault-turn", where,
                    "detour path is legal under neither the west-first "
                    "nor the up*/down* turn rule"))
        if every - wf and every - ud and not (every - wf - ud):
            out.append(Finding(
                "fault-turn", "program",
                "paths mix west-first-only and updown-only detours — no "
                "single turn rule covers the program, so the per-rule "
                "deadlock argument does not apply"))
    if op is None or participants is None:
        return out
    healthy, _ = remap_participants(participants, faults, width, height)
    usable = frozenset(healthy)
    for i, o in enumerate(prog):
        where = f"op {i}" + (f" [{o.tag}]" if o.tag else "")
        for p in sorted(frozenset(o.contribs) - usable):
            out.append(Finding(
                "fault-remap", where,
                f"dead/stranded PE {p} still contributes — its operand "
                f"was not remapped to a healthy neighbor"))
        for p in sorted(frozenset(o.delivers) - usable):
            out.append(Finding(
                "fault-remap", where,
                f"delivery targets dead/stranded PE {p}"))
    parts0 = sorted(set(tuple(p) for p in participants))
    r = remap_root(parts0[0] if root is None else tuple(root),
                   healthy, faults)
    out.extend(verify_collective(prog, op=op, participants=healthy,
                                 root=r, algorithm=algorithm,
                                 semantics=semantics))
    return out


# --------------------------------------------------------------------------- #
# Paged-KV free list
# --------------------------------------------------------------------------- #
def verify_allocator(alloc) -> list[Finding]:
    """BlockAllocator free-list invariants (static, host-only)."""
    out: list[Finding] = []
    nb = alloc.num_blocks
    free = list(alloc._free)
    for b in free:
        if not (isinstance(b, int) and 0 <= b < nb):
            out.append(Finding("kvcache", "free-list",
                               f"free block id {b!r} out of range 0..{nb - 1}"))
    dup_free = [b for b, k in Counter(free).items() if k > 1]
    for b in sorted(dup_free):
        out.append(Finding("kvcache", "free-list",
                           f"block {b} appears {free.count(b)} times in "
                           f"the free list"))
    owner: dict[int, object] = {}
    n_live = 0
    for rid in sorted(alloc.tables, key=repr):
        for b in alloc.tables[rid]:
            n_live += 1
            if not (isinstance(b, int) and 0 <= b < nb):
                out.append(Finding("kvcache", f"table {rid!r}",
                                   f"block id {b!r} out of range"))
                continue
            if b in owner:
                out.append(Finding(
                    "kvcache", f"table {rid!r}",
                    f"block {b} aliased (also owned by {owner[b]!r})"))
            owner[b] = rid
    for b in sorted(set(free) & set(owner)):
        out.append(Finding("kvcache", "free-list",
                           f"block {b} is both free and mapped to "
                           f"{owner[b]!r}"))
    if n_live + len(free) != nb:
        out.append(Finding(
            "kvcache", "free-list",
            f"leak: {n_live} live + {len(free)} free != {nb} total"))
    return out


def verify_kvcache(kv) -> list[Finding]:
    """PagedKVCache bookkeeping on top of the allocator invariants."""
    out = verify_allocator(kv.allocator)
    tables = set(kv.allocator.tables)
    for name, keys in (("state", set(kv._state)),
                       ("length", set(kv._length))):
        if keys != tables:
            only = sorted(keys ^ tables, key=repr)
            out.append(Finding(
                "kvcache", name,
                f"{name} keys disagree with block tables (difference: "
                f"{only})"))
    for rid in sorted(kv._length, key=repr):
        length = kv._length[rid]
        if length < 0 or length > kv.max_seq:
            out.append(Finding("kvcache", f"request {rid!r}",
                               f"length {length} outside 0..{kv.max_seq}"))
            continue
        table = kv.allocator.tables.get(rid, ())
        need = kv.blocks_for(length)
        if need > len(table):
            out.append(Finding(
                "kvcache", f"request {rid!r}",
                f"length {length} needs {need} blocks but the table "
                f"holds {len(table)}"))
    return out


# --------------------------------------------------------------------------- #
# Hook entry
# --------------------------------------------------------------------------- #
def check_program(prog: Sequence, cfg: Optional[NocConfig] = None,
                  **collective_kw) -> None:
    """Raise :class:`VerificationError` if ``prog`` has any finding.

    Used by the opt-in hooks (``engine.run_program(verify=True)``); pass
    collective metadata (``op=``, ``participants=``, ...) to also run the
    algebraic checks."""
    findings = verify_program(prog, cfg)
    if collective_kw:
        findings += verify_collective(prog, **collective_kw)
    if findings:
        raise VerificationError(findings)
