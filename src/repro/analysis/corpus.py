"""Artifact corpora the verifier CLI / bench / tests sweep over.

One place enumerates "every fig7-12 plan shape" and "all tree collectives
(both semantics x both allreduce algorithms)" so the CLI acceptance run,
``benchmarks/bench_analysis.py``, and ``tests/test_analysis.py`` cannot
drift apart on what *all* means.
"""
from __future__ import annotations

from typing import Iterator, Optional

from repro.core.noc.collective.schedule import (ALLREDUCE_ALGORITHMS,
                                                COLLECTIVE_OPS, SEMANTICS,
                                                plan_collective,
                                                ws_round_program)
from repro.core.noc.router import NocConfig

#: PE-per-router sweep of the paper's figures.
FIG_E_LIST = (1, 2, 4, 8)
FIG_E_LIST_QUICK = (1, 4)
#: fig7-9 compares ws_ina vs ws_noina; fig10-12 ws_ina vs os_gather.
FIG_MODES = ("ws_ina", "ws_noina", "os_gather")
FIG_WORKLOADS = ("alexnet", "vgg16", "resnet50")


def collective_cases(mesh_n: int = 4) -> Iterator[dict]:
    """Every (op, semantics[, algorithm]) over three participant shapes:
    the full mesh, one row, and a scattered non-convex set."""
    full = [(x, y) for x in range(mesh_n) for y in range(mesh_n)]
    row = [(x, 0) for x in range(mesh_n)]
    scattered = [(0, 0), (mesh_n - 1, 1), (1, mesh_n - 1),
                 (mesh_n - 2, mesh_n - 2)]
    for label, parts in (("full", full), ("row", row),
                         ("scattered", scattered)):
        for op in COLLECTIVE_OPS:
            for semantics in SEMANTICS:
                algorithms = ALLREDUCE_ALGORITHMS \
                    if op == "allreduce" else ("reduce_bcast",)
                for algorithm in algorithms:
                    yield {"label": label, "op": op,
                           "participants": parts,
                           "semantics": semantics,
                           "algorithm": algorithm}


def collective_programs(cfg: Optional[NocConfig] = None,
                        payload_bits: float = 512.0) -> Iterator[tuple]:
    """``(case, cfg, program)`` for every :func:`collective_cases` entry."""
    cfg = NocConfig(n=4) if cfg is None else cfg
    for case in collective_cases(min(cfg.width, cfg.height)):
        prog = plan_collective(
            case["op"], case["participants"], payload_bits, cfg,
            algorithm=case["algorithm"], semantics=case["semantics"])
        yield case, cfg, prog


#: package grids the hierarchy corpus sweeps (quick keeps the smallest).
HIER_GRIDS = ((2, 1), (2, 2))
HIER_GRIDS_QUICK = ((2, 1),)
PACKAGE_VARIANTS = ("mesh", "express")


def hier_cases(quick: bool = False) -> Iterator[dict]:
    """Every hierarchical collective the verifier must hold: package grid
    x package variant x op x semantics x (allreduce algorithm)."""
    from repro.core.noc.hierarchy import HIER_OPS
    grids = HIER_GRIDS_QUICK if quick else HIER_GRIDS
    for grid in grids:
        for package in PACKAGE_VARIANTS:
            for op in HIER_OPS:
                for semantics in SEMANTICS:
                    algorithms = ALLREDUCE_ALGORITHMS \
                        if op == "allreduce" else ("reduce_bcast",)
                    for algorithm in algorithms:
                        yield {"grid": grid, "package": package, "op": op,
                               "semantics": semantics,
                               "algorithm": algorithm}


def hier_schedules(quick: bool = False, cfg: Optional[NocConfig] = None,
                   payload_bits: float = 4096.0) -> Iterator[tuple]:
    """``(case, schedule)`` for every :func:`hier_cases` entry."""
    from repro.core.noc.hierarchy import (HierarchicalMesh,
                                          plan_hier_collective)
    cfg = NocConfig(n=4) if cfg is None else cfg
    for case in hier_cases(quick):
        hmesh = HierarchicalMesh(chips_x=case["grid"][0],
                                 chips_y=case["grid"][1],
                                 package=case["package"])
        sched = plan_hier_collective(
            case["op"], hmesh, payload_bits, cfg,
            algorithm=case["algorithm"], semantics=case["semantics"])
        yield case, sched


#: Seeded fault densities the faulted corpus sweeps (DESIGN.md S15).
#: Rates are per-link / per-router / per-PE Bernoulli draws from one
#: ``random.Random(seed)`` stream — the corpus is a pure function of
#: these literals.
FAULT_SPECS = (
    {"label": "light", "link_rate": 0.04, "router_rate": 0.0,
     "pe_rate": 0.0, "seed": 3},
    {"label": "medium", "link_rate": 0.08, "router_rate": 0.02,
     "pe_rate": 0.05, "seed": 11},
    {"label": "heavy", "link_rate": 0.15, "router_rate": 0.05,
     "pe_rate": 0.08, "seed": 23},
)
#: Faulted programs plan on a 6x6 chip so detours have room to exist.
FAULT_MESH_N = 6


def fault_models(quick: bool = False) -> Iterator[tuple]:
    """``(spec, FaultModel)`` for every :data:`FAULT_SPECS` density
    (quick keeps the lightest)."""
    from repro.core.noc.faults import seeded_faults
    for spec in FAULT_SPECS[:1] if quick else FAULT_SPECS:
        yield spec, seeded_faults(
            FAULT_MESH_N, FAULT_MESH_N, link_rate=spec["link_rate"],
            router_rate=spec["router_rate"], pe_rate=spec["pe_rate"],
            seed=spec["seed"])


def faulted_collective_programs(quick: bool = False,
                                payload_bits: float = 512.0
                                ) -> Iterator[tuple]:
    """``(case, cfg, faults, program)``: the full collective matrix
    (op x semantics x allreduce algorithm over the full mesh and a
    scattered set) repaired under every corpus fault density."""
    cfg = NocConfig(n=FAULT_MESH_N)
    n = FAULT_MESH_N
    full = [(x, y) for x in range(n) for y in range(n)]
    scattered = [(0, 0), (n - 1, 1), (1, n - 1), (n - 2, n - 2)]
    for spec, faults in fault_models(quick):
        for label, parts in (("full", full), ("scattered", scattered)):
            for op in COLLECTIVE_OPS:
                for semantics in SEMANTICS:
                    algorithms = ALLREDUCE_ALGORITHMS \
                        if op == "allreduce" else ("reduce_bcast",)
                    for algorithm in algorithms:
                        prog = plan_collective(
                            op, parts, payload_bits, cfg,
                            algorithm=algorithm, semantics=semantics,
                            faults=faults)
                        case = {"label": label, "op": op,
                                "participants": parts,
                                "semantics": semantics,
                                "algorithm": algorithm,
                                "fault": spec["label"]}
                        yield case, cfg, faults, prog


def faulted_hier_schedules(quick: bool = False,
                           payload_bits: float = 4096.0) -> Iterator[tuple]:
    """``(case, faults, schedule)``: hierarchical collectives with
    link-only on-die faults (chip roots stay alive, so the chip-root
    invariant of ``verify_hier_schedule`` still binds) and one failed
    chip on the larger grid."""
    from repro.core.noc.faults import seeded_faults
    from repro.core.noc.hierarchy import (HIER_OPS, HierarchicalMesh,
                                          plan_hier_collective)
    faults = seeded_faults(FAULT_MESH_N, FAULT_MESH_N, link_rate=0.08,
                           seed=5)
    grids = HIER_GRIDS_QUICK if quick else HIER_GRIDS
    for grid in grids:
        failed = (grid[0] * grid[1] - 1,) if grid[0] * grid[1] > 2 else ()
        hmesh = HierarchicalMesh(chip_w=FAULT_MESH_N, chip_h=FAULT_MESH_N,
                                 chips_x=grid[0], chips_y=grid[1])
        for op in HIER_OPS:
            for semantics in SEMANTICS:
                sched = plan_hier_collective(
                    op, hmesh, payload_bits, semantics=semantics,
                    faults=faults, failed_chips=failed)
                case = {"grid": grid, "op": op, "semantics": semantics,
                        "failed_chips": failed}
                yield case, faults, sched


def ws_plan_shapes(quick: bool = False,
                   cfg: Optional[NocConfig] = None) -> list[dict]:
    """Every distinct fig7-12 per-layer plan shape.

    Dedup key: (mode, g, p, gather_flits, unicast_flits, e_pes) — exactly
    the part of the plan that determines the emitted round program.
    """
    from repro.core.noc.traffic import layer_plan
    from repro.core.workloads import WORKLOADS
    cfg = NocConfig() if cfg is None else cfg
    e_list = FIG_E_LIST_QUICK if quick else FIG_E_LIST
    seen = set()
    shapes = []
    for workload in FIG_WORKLOADS:
        for layer in WORKLOADS[workload]:
            for e_pes in e_list:
                for mode in FIG_MODES:
                    plan = layer_plan(layer, cfg, e_pes, mode)
                    key = (mode, plan.g, plan.p, plan.gather_flits,
                           plan.unicast_flits, e_pes)
                    if key in seen:
                        continue
                    seen.add(key)
                    shapes.append({
                        "workload": workload, "layer": layer.name,
                        "mode": mode, "e_pes": e_pes, "g": plan.g,
                        "p": plan.p, "gather_flits": plan.gather_flits,
                        "unicast_flits": plan.unicast_flits,
                    })
    return shapes


def ws_programs(quick: bool = False, window: int = 2,
                cfg: Optional[NocConfig] = None) -> Iterator[tuple]:
    """``(shape, cfg, program)`` for every distinct fig7-12 plan shape."""
    cfg = NocConfig() if cfg is None else cfg
    for shape in ws_plan_shapes(quick, cfg):
        prog = ws_round_program(
            cfg, shape["mode"], window, g=shape["g"], p=shape["p"],
            gather_flits=shape["gather_flits"],
            unicast_flits=shape["unicast_flits"], e_pes=shape["e_pes"])
        yield shape, cfg, prog
