"""Static analysis over the repo's artifacts and its own source (DESIGN.md S13).

Two halves, one findings vocabulary (:class:`~.findings.Finding`):

* **Artifact verifier** (:mod:`.verify`) — checks PacketOp programs,
  CompiledPrograms, mapper NetworkSchedules, persisted ExecutionPlans, and
  the paged-KV free list *without running the event loop*: dependency-DAG
  shape, route legality, channel-dependency-graph deadlock freedom,
  algebraic collective correctness from ``contribs``/``delivers`` metadata,
  static-ledger conservation, and plan invariants.  This is the cheap
  oracle the vectorized backend (ROADMAP) will be validated against.
* **Determinism lint** (:mod:`.lint`) — an AST rule registry over ``src/``
  for the byte-determinism contract: unseeded randomness, wall-clock reads,
  set-iteration order hazards, mutable default arguments, and persisted
  writes bypassing ``atomic_write_text``.  ``# lint: allow(<rule>)``
  pragmas suppress justified sites.

CLI: ``python -m repro.analysis verify`` / ``python -m repro.analysis lint``
(see EXPERIMENTS.md).  Opt-in hooks: ``engine.run_program(verify=True)``,
``PlanStore(verify=True)``, ``mapper.search_network(debug=True)``.
"""
from .findings import Finding, VerificationError
from .lint import LINT_RULES, lint_paths
from .verify import (check_program, verify_allocator, verify_collective,
                     verify_compiled, verify_kvcache, verify_plan,
                     verify_program, verify_schedule)

__all__ = [
    "Finding", "VerificationError",
    "LINT_RULES", "lint_paths",
    "check_program", "verify_allocator", "verify_collective",
    "verify_compiled", "verify_kvcache", "verify_plan", "verify_program",
    "verify_schedule",
]
