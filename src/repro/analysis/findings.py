"""Machine-readable findings shared by the verifier and the linter."""
from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class Finding:
    """One defect located by a named check.

    ``check`` is the registry id ("dep-dag", "route", "cdg-deadlock",
    "collective-fold", ... or a lint rule name); ``where`` locates the
    defect (an op index, a ``file:line``, a plan key); ``message`` says
    what is wrong in one sentence.
    """

    check: str
    where: str
    message: str
    severity: str = "error"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"[{self.check}] {self.where}: {self.message}"


class VerificationError(Exception):
    """Raised by the opt-in hooks when static checks produce findings."""

    def __init__(self, findings) -> None:
        self.findings = list(findings)
        head = "; ".join(str(f) for f in self.findings[:4])
        extra = len(self.findings) - 4
        if extra > 0:
            head += f" (+{extra} more)"
        super().__init__(head or "verification failed")


def findings_doc(findings, **meta) -> dict:
    """A deterministic JSON-serializable findings artifact."""
    doc = dict(sorted(meta.items()))
    doc["count"] = len(findings)
    doc["findings"] = [f.to_dict() for f in findings]
    return doc


def dump_findings(path, findings, **meta) -> None:
    from pathlib import Path

    from repro.core.noc.simcache import atomic_write_text
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    atomic_write_text(
        p, json.dumps(findings_doc(findings, **meta), indent=1,
                      sort_keys=True) + "\n")
