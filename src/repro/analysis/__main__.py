"""``python -m repro.analysis`` — the static-analysis CLI.

Two subcommands (EXPERIMENTS.md has the full walkthrough):

``verify [--sections collectives,ws,hierarchy,schedules,plans,faults,kvcache]``
    Statically verify the repo's artifacts without running the event
    loop: every tree collective (both semantics x both allreduce
    algorithms over three participant shapes), every distinct fig7-12
    WS plan shape (source program + compiled lowering + ``replicate``),
    every hierarchical collective of the mesh-of-meshes corpus
    (chip-boundary routes, per-level fold-exactly-once, two-level CDG),
    quick-search mapper schedules, every persisted ExecutionPlan
    (``--plan-dir``; ``--build-plans`` populates the store for all
    (config x phase) cells first), and a deterministic paged-KV
    scenario.  Exit 1 on any finding; ``--json`` writes the findings
    artifact CI uploads.

``lint [paths ...]``
    The determinism lint (``repro.analysis.lint``) over ``src/`` (or the
    given paths).  Exit 1 on any finding; reports the pragma budget.
"""
from __future__ import annotations

import argparse
import sys

from .findings import Finding, dump_findings
from .lint import count_pragmas, lint_paths
from .verify import (verify_collective, verify_compiled,
                     verify_hier_schedule, verify_plan, verify_program,
                     verify_schedule)

#: All (config x phase) plan cells ``verify --build-plans`` covers.
PLAN_MESH = (("data", 16), ("model", 16))


def _print_findings(findings: list) -> None:
    for f in findings:
        print(f"  {f}")


# --------------------------------------------------------------------------- #
# verify sections
# --------------------------------------------------------------------------- #
def _section_collectives(args) -> tuple[int, list]:
    from repro.core.noc.compiled import compile_program
    from .corpus import collective_programs
    findings: list = []
    checked = 0
    for case, cfg, prog in collective_programs():
        checked += 1
        where = (f"collective {case['op']}/{case['semantics']}/"
                 f"{case['algorithm']}/{case['label']}")
        fs = verify_program(prog, cfg)
        fs += verify_collective(
            prog, op=case["op"], participants=case["participants"],
            algorithm=case["algorithm"], semantics=case["semantics"])
        cp = compile_program(prog, cfg)
        fs += verify_compiled(cp, prog, cfg)
        findings += [Finding(f.check, f"{where}: {f.where}", f.message)
                     for f in fs]
    return checked, findings


def _section_ws(args) -> tuple[int, list]:
    from repro.core.noc.compiled import compile_program
    from .corpus import ws_programs
    findings: list = []
    checked = 0
    for shape, cfg, prog in ws_programs(quick=args.quick, window=2):
        checked += 1
        where = (f"ws {shape['workload']}/{shape['layer']}/"
                 f"{shape['mode']}/E{shape['e_pes']}")
        fs = verify_program(prog, cfg)
        cp = compile_program(prog, cfg)
        fs += verify_compiled(cp, prog, cfg)
        # replicate() must preserve the encoding invariants (dep shifts).
        fs += verify_compiled(cp.replicate(3))
        findings += [Finding(f.check, f"{where}: {f.where}", f.message)
                     for f in fs]
    return checked, findings


def _section_hierarchy(args) -> tuple[int, list]:
    """Hierarchy invariants (DESIGN.md S14) over the mesh-of-meshes
    corpus: chip-boundary route legality, per-level fold-exactly-once,
    and CDG deadlock freedom over the two-level channel graph."""
    from .corpus import hier_schedules
    findings: list = []
    checked = 0
    for case, sched in hier_schedules(quick=args.quick):
        checked += 1
        cx, cy = case["grid"]
        where = (f"hier {cx}x{cy}/{case['package']}/{case['op']}/"
                 f"{case['semantics']}/{case['algorithm']}")
        findings += [Finding(f.check, f"{where}: {f.where}", f.message)
                     for f in verify_hier_schedule(sched)]
    return checked, findings


def _section_schedules(args) -> tuple[int, list]:
    from repro.core.workloads import mapper_workloads
    from repro.mapper.search import search_network
    from repro.mapper.space import QUICK_MAPPER
    findings: list = []
    checked = 0
    workloads = mapper_workloads(conv=("alexnet",),
                                 transformers=("qwen2-1.5b",))
    for name in sorted(workloads):
        layers = workloads[name]
        outcome = search_network(name, layers, QUICK_MAPPER)
        for label, sched in (("best", outcome.best),
                             ("baseline", outcome.baseline)):
            checked += 1
            fs = verify_schedule(sched, layers)
            findings += [Finding(f.check,
                                 f"schedule {name}/{label}: {f.where}",
                                 f.message) for f in fs]
    return checked, findings


def _section_plans(args) -> tuple[int, list]:
    from repro.plan.store import PlanStore
    store = PlanStore(args.plan_dir)
    findings: list = []
    if args.build_plans:
        from repro.configs import ARCHS
        from repro.plan.builder import PHASES
        phases = ("decode",) if args.quick else PHASES
        for name in sorted(ARCHS):
            for phase in phases:
                try:
                    store.get_or_build(ARCHS[name], PLAN_MESH, phase,
                                       mapper_space=args.mapper_space)
                except Exception as exc:   # a build crash is a finding
                    findings.append(Finding(
                        "plan-schema", f"build {name}/{phase}",
                        f"plan build failed: {exc}"))
    checked = 0
    store.dir.mkdir(parents=True, exist_ok=True)
    for path in sorted(store.dir.glob("*.json")):
        key = path.stem
        plan = store.load(key)
        if plan is None:
            findings.append(Finding(
                "plan-schema", f"plan {key}",
                "stored file is unreadable or stale-schema "
                "(would rebuild cold)"))
            continue
        checked += 1
        findings += verify_plan(plan, check_layers=True)
    return checked, findings


def _section_faults(args) -> tuple[int, list]:
    """Fault-repaired artifacts (DESIGN.md S15): every faulted corpus
    program passes the fault classes (clear routes, one turn rule, remap
    closure), the full fold/deliver algebra over the usable set, the CDG
    deadlock check on the actual detour paths, and the compiled-lowering
    conservation pass; faulted hierarchy schedules keep the S14
    invariants with a failed chip excluded end to end."""
    from repro.core.noc.compiled import compile_program
    from .corpus import faulted_collective_programs, faulted_hier_schedules
    from .verify import verify_faulted
    findings: list = []
    checked = 0
    for case, cfg, faults, prog in \
            faulted_collective_programs(quick=args.quick):
        checked += 1
        where = (f"faulted[{case['fault']}] {case['op']}/"
                 f"{case['semantics']}/{case['algorithm']}/{case['label']}")
        fs = verify_faulted(prog, faults, cfg, op=case["op"],
                            participants=case["participants"],
                            algorithm=case["algorithm"],
                            semantics=case["semantics"])
        cp = compile_program(prog, cfg)
        fs += verify_compiled(cp, prog, cfg)
        findings += [Finding(f.check, f"{where}: {f.where}", f.message)
                     for f in fs]
    for case, faults, sched in faulted_hier_schedules(quick=args.quick):
        checked += 1
        cx, cy = case["grid"]
        where = (f"faulted-hier {cx}x{cy}/{case['op']}/"
                 f"{case['semantics']}")
        findings += [Finding(f.check, f"{where}: {f.where}", f.message)
                     for f in verify_hier_schedule(sched)]
    return checked, findings


def _section_kvcache(args) -> tuple[int, list]:
    """A deterministic allocator scenario: interleaved alloc/extend/free
    with failure paths, verified after every step."""
    from repro.serve.kvcache import BlockAllocator
    from .verify import verify_allocator
    findings: list = []
    alloc = BlockAllocator(32)
    steps = 0

    def snap(stage: str) -> None:
        nonlocal steps
        steps += 1
        findings.extend(
            Finding(f.check, f"kvcache[{stage}]: {f.where}", f.message)
            for f in verify_allocator(alloc))

    alloc.alloc("a", 5)
    snap("alloc-a")
    alloc.alloc("b", 7)
    snap("alloc-b")
    alloc.extend("a", 3)
    snap("extend-a")
    alloc.free("b")
    snap("free-b")
    for exc_type, fn in (
            (KeyError, lambda: alloc.alloc("a", 1)),          # double table
            (KeyError, lambda: alloc.extend("ghost", 1)),     # no table
            (MemoryError, lambda: alloc.alloc("c", 99)),      # over budget
            (MemoryError, lambda: alloc.extend("a", -1)),     # negative
    ):
        try:
            fn()
            findings.append(Finding("kvcache", "scenario",
                                    f"expected {exc_type.__name__} "
                                    f"was not raised"))
        except exc_type:
            pass
        snap("failure-path")
    alloc.alloc("c", alloc.free_blocks)
    snap("alloc-to-capacity")
    alloc.free("a")
    alloc.free("c")
    snap("drained")
    if alloc.free_blocks != alloc.num_blocks:
        findings.append(Finding("kvcache", "scenario",
                                "blocks not fully recovered after drain"))
    return steps, findings


_SECTIONS = {
    "collectives": _section_collectives,
    "ws": _section_ws,
    "hierarchy": _section_hierarchy,
    "schedules": _section_schedules,
    "plans": _section_plans,
    "faults": _section_faults,
    "kvcache": _section_kvcache,
}


def cmd_verify(args) -> int:
    names = [s.strip() for s in args.sections.split(",") if s.strip()]
    unknown = [n for n in names if n not in _SECTIONS]
    if unknown:
        print(f"unknown sections: {unknown} "
              f"(have {sorted(_SECTIONS)})", file=sys.stderr)
        return 2
    all_findings: list = []
    for name in names:
        checked, findings = _SECTIONS[name](args)
        status = "ok" if not findings else f"{len(findings)} finding(s)"
        print(f"[analysis] verify {name}: {checked} artifact(s), {status}")
        _print_findings(findings)
        all_findings += findings
    if args.json:
        dump_findings(args.json, all_findings, command="verify",
                      sections=names)
        print(f"[analysis] wrote {args.json}")
    print(f"[analysis] verify: {len(all_findings)} finding(s) total")
    return 1 if all_findings else 0


def cmd_lint(args) -> int:
    paths = args.paths or ["src"]
    findings = lint_paths(paths)
    for f in findings:
        print(f"  {f}")
    pragmas = count_pragmas(paths)
    print(f"[analysis] lint: {len(findings)} finding(s), "
          f"{pragmas} pragma(s) in {', '.join(map(str, paths))}")
    if args.json:
        dump_findings(args.json, findings, command="lint",
                      pragmas=pragmas)
        print(f"[analysis] wrote {args.json}")
    return 1 if findings else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static artifact verifier + determinism lint")
    sub = ap.add_subparsers(dest="cmd", required=True)

    vp = sub.add_parser("verify", help="verify NoC/plan/kvcache artifacts")
    vp.add_argument("--sections", default=",".join(_SECTIONS),
                    help=f"comma list of {sorted(_SECTIONS)}")
    vp.add_argument("--plan-dir", default=None,
                    help="ExecutionPlan store to verify "
                         "(default: results/.plans)")
    vp.add_argument("--build-plans", action="store_true",
                    help="populate the store for every (config x phase) "
                         "cell before verifying")
    vp.add_argument("--mapper-space", default="quick",
                    choices=("quick", "full"),
                    help="gemm search space when building plans")
    vp.add_argument("--quick", action="store_true",
                    help="CI shape: E in {1,4}; --build-plans covers the "
                         "decode phase only")
    vp.add_argument("--json", default=None, metavar="PATH",
                    help="write the findings artifact here")
    vp.set_defaults(func=cmd_verify)

    lp = sub.add_parser("lint", help="determinism lint over source trees")
    lp.add_argument("paths", nargs="*", help="files/dirs (default: src)")
    lp.add_argument("--json", default=None, metavar="PATH",
                    help="write the findings artifact here")
    lp.set_defaults(func=cmd_lint)

    args = ap.parse_args(argv)
    return args.func(args)


if __name__ == "__main__":
    raise SystemExit(main())
