"""The paper's full evaluation as one cached, resumable sweep subsystem.

Every figure/table of the source paper is a function from a
:class:`SweepConfig` to a JSON-ready dict:

* :func:`run_tables`      — Tables I & II (P#, INA# per CONV layer, N=8/16)
* :func:`run_fig7_9`      — Figs 7-9: WS+INA vs WS-without-INA, E sweep
* :func:`run_fig10_12`    — Figs 10-12: WS+INA vs OS-with-gather, E sweep
* :func:`run_mesh_scaling`— beyond the paper: mesh-size N x E scaling

All simulation goes through :func:`repro.core.noc.traffic.simulate_network`
and therefore through the plan-keyed window cache
(:mod:`repro.core.noc.simcache`): a whole-network sweep replays each
distinct window program once, so ResNet-50's ~53 layers cost a handful of
event-driven runs.  :func:`run_all` writes per-figure JSON + a markdown
summary into ``results/`` (see EXPERIMENTS.md).

The ``*_csv_lines`` helpers emit the legacy ``name,us_per_call,derived``
benchmark rows; ``benchmarks/bench_*.py`` delegate here.
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Callable, Optional

from repro.core.ina_model import ina_table
from repro.core.noc import NocConfig, SIM_CACHE
from repro.core.noc.power import (Improvement, ws_ina_improvement,
                                  ws_vs_os_improvement)
from repro.core.workloads import ALEXNET, VGG16, WORKLOADS
from repro.exec import parallel_map

#: Paper-reported headline numbers, attached to every emitted figure.
PAPER_REFERENCE = {
    "tables": "Tables I & II: P#/INA# per CONV layer (M=32Kbit, q=32)",
    "fig7_9": "paper: up to 1.22x latency / 2.16x power, WS+INA vs WS",
    "fig10_12": "paper: up to 1.19x latency / 2.16x power, WS+INA vs OS",
    "mesh_scaling": "beyond the paper: N x E scaling of the WS+INA gain",
    "hierarchy": "beyond the paper: mesh-of-meshes — the INA advantage vs "
                 "chip count and package-link bandwidth (DESIGN.md S14)",
    "mapper": "beyond the paper: searched mappings vs the fixed "
              "Eq. (1)-(4) placement (DESIGN.md S9)",
    "plan": "beyond the paper: whole-model ExecutionPlans — NoC-costed "
            "psum strategy, mapper verdict, pallas tiles per "
            "(config, mesh, phase, dtype) (DESIGN.md S11)",
    "serve": "beyond the paper: request-level serving capacity — the INA "
             "advantage as meshes-per-SLO (DESIGN.md S12)",
    "faults": "beyond the paper: the INA advantage under seeded NoC faults "
              "— repaired collectives vs fault density, plus cluster "
              "degradation (DESIGN.md S15)",
}

SECTIONS = ("tables", "fig7_9", "fig10_12", "mesh_scaling", "hierarchy",
            "mapper", "plan", "serve", "faults")


@dataclass(frozen=True)
class SweepConfig:
    """Shape of one full-evaluation sweep (defaults match the paper)."""

    e_list: tuple[int, ...] = (1, 2, 4, 8)      # PEs per router (Eq. 4)
    n_list: tuple[int, ...] = (4, 8, 16)        # mesh sizes (scaling study)
    table_n_list: tuple[int, ...] = (8, 16)     # Tables I/II mesh sizes
    sim_rounds: int = 16                        # simulated window length
    workloads: tuple[str, ...] = ("alexnet", "vgg16", "resnet50")
    jobs: int = 1                               # process-pool width (--jobs)
    # ---- hierarchy section (DESIGN.md S14) -------------------------------
    #: (chip-mesh N, allreduce payload bits) points — large configs where
    #: the package level actually carries weight.
    hier_configs: tuple[tuple[int, int], ...] = (
        (8, 1 << 20), (16, 1 << 20), (16, 1 << 22))
    hier_chips: tuple[int, ...] = (1, 2, 4, 8)  # chips per package
    #: on-die/package link-width ratios (1 = same-width interposer wires,
    #: 4 = package links carry a quarter flit per beat) — the bandwidth
    #: axis; per-hop latency stays at the 4-cycle interposer default.
    hier_pkg_widths: tuple[int, ...] = (1, 2, 4)
    hier_packages: tuple[str, ...] = ("mesh", "express")
    # ---- mapper section (DESIGN.md S9) -----------------------------------
    mapper_space: str = "full"                  # "full" | "quick" MapperConfig
    mapper_transformers: tuple[str, ...] = ("llama3-8b", "qwen2-1.5b")
    mapper_tokens: int = 256                    # GEMM M tile per pass
    mapper_pe_budget: Optional[int] = None      # per-chip PE ceiling override
    mapper_chips: tuple[int, ...] = (1,)        # package axis (--chips)
    # ---- plan section (DESIGN.md S11) ------------------------------------
    plan_phases: tuple[str, ...] = ("train", "prefill", "decode")
    plan_mesh: tuple[tuple[str, int], ...] = (("data", 16), ("model", 16))
    plan_dir: Optional[str] = None              # None -> results/.plans
    # ---- serve section (DESIGN.md S12) -----------------------------------
    serve_archs: tuple[str, ...] = ("qwen2-1.5b", "llama3-8b",
                                    "deepseek-v2-lite-16b")
    serve_qps: tuple[float, ...] = (0.05, 0.1, 0.2)
    serve_fleets: tuple[int, ...] = (1, 2, 4, 8, 16)
    serve_requests: int = 200
    serve_seed: int = 0
    # The fleet answer is on p99 admission-queueing delay: the modeled
    # 1 GHz mesh is prefill-bound, so absolute TTFT/e2e floors differ per
    # collective semantics at *any* fleet size — queueing is the metric
    # fleet size actually buys down, and both semantics can meet it.
    serve_slo_metric: str = "queueing_s"
    serve_slo_ms: float = 30_000.0              # 30 s modeled queueing p99
    serve_slots: int = 8
    serve_max_seq: int = 1024
    serve_block: int = 16
    serve_chunk: int = 64                       # prefill chunk (tokens)
    serve_prompt_dist: str = "lognormal:128:0.5:512"
    serve_gen_dist: str = "uniform:32:128"
    # ---- faults section (DESIGN.md S15) ----------------------------------
    #: (label, link_rate, router_rate, pe_rate) fault densities; the
    #: zero-rate level is the clean baseline the degradation ratios use.
    fault_levels: tuple[tuple[str, float, float, float], ...] = (
        ("none", 0.0, 0.0, 0.0),
        ("light", 0.04, 0.0, 0.0),
        ("medium", 0.08, 0.02, 0.05),
        ("heavy", 0.15, 0.05, 0.08),
    )
    fault_mesh_n: int = 8                       # faulted-chip mesh size
    fault_seed: int = 3                         # FaultModel RNG seed
    fault_cluster_fleet: int = 2                # replicas in degraded sim

    def cfg(self, n: Optional[int] = None) -> NocConfig:
        return NocConfig() if n is None else NocConfig(n=n)


DEFAULT_SWEEP = SweepConfig()
#: CI smoke shape: small windows, two E points, no N=16 mesh.
QUICK_SWEEP = SweepConfig(e_list=(1, 4), n_list=(4, 8), sim_rounds=4,
                          workloads=("alexnet", "vgg16", "resnet50"),
                          hier_configs=((4, 1 << 14),), hier_chips=(1, 2),
                          hier_pkg_widths=(4,),
                          mapper_space="quick", plan_phases=("decode",),
                          serve_archs=("qwen2-1.5b",), serve_qps=(0.1,),
                          serve_fleets=(1, 2), serve_requests=60,
                          fault_levels=(("none", 0.0, 0.0, 0.0),
                                        ("medium", 0.08, 0.02, 0.05)),
                          fault_mesh_n=6)


def _imp_row(imp: Improvement, **extra) -> dict:
    row = {"workload": imp.workload, "e_pes": imp.e_pes,
           "latency_x": imp.latency_x, "power_x": imp.power_x,
           "energy_x": imp.energy_x}
    row.update(extra)
    return row


# --------------------------------------------------------------------------- #
# Sections
# --------------------------------------------------------------------------- #
def run_tables(sweep: SweepConfig = DEFAULT_SWEEP) -> dict:
    """Tables I & II: analytical P#/INA# rows per CONV layer and mesh size."""
    rows = []
    for name, layers in (("alexnet", ALEXNET), ("vgg16", VGG16)):
        for n in sweep.table_n_list:
            for r in ina_table(layers, n=n):
                rows.append({"network": name, "n": n, **r})
    return {"figure": "tables", "paper_reference": PAPER_REFERENCE["tables"],
            "rows": rows}


def _improvement_task(payload) -> dict:
    """One (workload, E, N) improvement row — the pool-fanout unit of the
    fig sweeps.  Top-level so :func:`repro.exec.parallel_map` can pickle it.
    """
    improve, name, e, cfg, sim_rounds, extra = payload
    t0 = time.time()
    imp = improve(name, WORKLOADS[name], e, cfg, sim_rounds)
    return _imp_row(imp, elapsed_us=(time.time() - t0) * 1e6, **extra)


def _run_fig(figure: str, sweep: SweepConfig,
             improve: Callable[..., Improvement]) -> dict:
    rows = parallel_map(
        _improvement_task,
        [(improve, name, e, sweep.cfg(), sweep.sim_rounds, {})
         for name in sweep.workloads for e in sweep.e_list],
        jobs=sweep.jobs)
    avg = {k: sum(r[k] for r in rows) / len(rows)
           for k in ("latency_x", "power_x", "energy_x")}
    return {"figure": figure, "paper_reference": PAPER_REFERENCE[figure],
            "sim_rounds": sweep.sim_rounds, "rows": rows, "average": avg}


def run_fig7_9(sweep: SweepConfig = DEFAULT_SWEEP) -> dict:
    """Figs 7-9: WS+INA improvement over WS-without-INA across workloads/E."""
    return _run_fig("fig7_9", sweep, ws_ina_improvement)


def run_fig10_12(sweep: SweepConfig = DEFAULT_SWEEP) -> dict:
    """Figs 10-12: WS+INA improvement over OS-with-gather across workloads/E."""
    return _run_fig("fig10_12", sweep, ws_vs_os_improvement)


def run_mesh_scaling(sweep: SweepConfig = DEFAULT_SWEEP) -> dict:
    """N x E scaling of the WS+INA gain (the paper only reports N=8)."""
    rows = parallel_map(
        _improvement_task,
        [(ws_ina_improvement, name, e, sweep.cfg(n), sweep.sim_rounds,
          {"n": n})
         for n in sweep.n_list for name in sweep.workloads
         for e in sweep.e_list],
        jobs=sweep.jobs)
    return {"figure": "mesh_scaling",
            "paper_reference": PAPER_REFERENCE["mesh_scaling"],
            "sim_rounds": sweep.sim_rounds, "rows": rows}


def run_hierarchy(sweep: SweepConfig = DEFAULT_SWEEP) -> dict:
    """Hierarchy section: the INA advantage on a mesh-of-meshes
    (DESIGN.md S14).

    For every ``(chip-mesh N, payload)`` point in ``sweep.hier_configs``,
    prices a whole-package allreduce over ``sweep.hier_chips`` chips,
    both package fabrics, and ``sweep.hier_pkg_widths`` package-link
    width ratios (the bandwidth axis: a ratio of 4 means cross-chip
    links carry a quarter of the on-die flit per beat) — under both
    collective semantics through
    :func:`~repro.core.noc.hierarchy.hier_collective_cost` (the same
    SIM_CACHE-riding facade the plan builder and mapper use).
    ``latency_x``/``energy_x`` are eject/inject over INA, so the rows read
    as *how much of the paper's advantage survives the package level* as
    chips multiply and the cross-chip links narrow.
    """
    import dataclasses as _dc

    from repro.core.noc.hierarchy import (hier_collective_cost,
                                          square_hier_mesh)

    rows = []
    for n, payload_bits in sweep.hier_configs:
        cfg = sweep.cfg(n)
        for chips in sweep.hier_chips:
            # chips == 1 is the flat paper mesh: no package level exists,
            # so the fabric/width axes would emit duplicate rows.
            variants = [("flat", 1)] if chips == 1 else \
                [(pkg, wr) for pkg in sweep.hier_packages
                 for wr in sweep.hier_pkg_widths]
            for package, width_ratio in variants:
                t0 = time.time()
                hmesh = square_hier_mesh(
                    chips, n, n,
                    package=package if chips > 1 else "mesh")
                hmesh = _dc.replace(
                    hmesh,
                    pkg_flit_bits=max(1, cfg.flit_bits // width_ratio))
                costs = {sem: hier_collective_cost(
                            "allreduce", hmesh, float(payload_bits), cfg,
                            semantics=sem)
                         for sem in ("ina", "eject_inject")}
                ina, ej = costs["ina"], costs["eject_inject"]
                rows.append({
                    "n": n, "payload_bits": payload_bits, "chips": chips,
                    "package": package, "pkg_width_ratio": width_ratio,
                    "pes": ina.participants,
                    "ina_latency_cycles": ina.latency_cycles,
                    "ej_latency_cycles": ej.latency_cycles,
                    "latency_x": ej.latency_cycles / ina.latency_cycles,
                    "ina_energy_pj": ina.energy_pj,
                    "ej_energy_pj": ej.energy_pj,
                    "energy_x": ej.energy_pj / ina.energy_pj,
                    "ina_level_latency": [list(l) for l
                                          in ina.level_latency],
                    "elapsed_us": (time.time() - t0) * 1e6,
                })
    # Headline per package fabric: the INA advantage at the largest swept
    # chip count and narrowest link (the "does it survive scale-out"
    # answer).
    headline = {}
    for package in ("flat",) + tuple(sweep.hier_packages):
        sub = [r for r in rows if r["package"] == package]
        if sub:
            worst = max(sub, key=lambda r: (r["chips"],
                                            r["pkg_width_ratio"], r["n"]))
            headline[package] = {k: worst[k] for k in
                                 ("n", "chips", "pkg_width_ratio",
                                  "latency_x", "energy_x")}
    return {"figure": "hierarchy",
            "paper_reference": PAPER_REFERENCE["hierarchy"],
            "rows": rows, "headline": headline}


def _search_one_workload(payload):
    """Pool-fanout unit for :func:`run_mapper`: one workload's search.

    Inside a worker the nested hardware-point fan-out serializes
    (``repro.exec.pool`` guards against nested pools), so each worker runs
    its search with the vectorized batched window prefetch and ships the
    outcome + wall time back; in the serial fallback the inner fan-out
    still applies.
    """
    name, layers, mcfg, jobs = payload
    from repro.mapper import search_network
    from repro.mapper.search import memo_export, memo_sizes

    sizes = memo_sizes()
    t0 = time.time()
    out = search_network(name, layers, mcfg, jobs=jobs)
    return out, (time.time() - t0) * 1e6, memo_export(sizes)


def run_mapper(sweep: SweepConfig = DEFAULT_SWEEP) -> dict:
    """Mapper section: paper-fixed vs auto-searched mapping, per workload.

    For every CNN in ``sweep.workloads`` (FC layers included) and every
    transformer config in ``sweep.mapper_transformers`` (one decoder block's
    GEMMs), runs :func:`repro.mapper.search_network` and reports the
    improvement of the searched :class:`~repro.mapper.NetworkSchedule` over
    the paper's fixed 8x8 WS+INA placement, plus the hardware-level
    latency/energy Pareto front.  Selection is baseline-dominating, so
    ``latency_x >= 1`` and ``energy_x >= 1`` by construction (equality when
    the paper mapping is already optimal).

    ``sweep.jobs > 1`` fans out at workload grain (one pool for the whole
    section): with the vectorized window kernels a single search is
    fast enough that the old per-hardware-point fan-out spent more wall
    time forking five pools than simulating.  Results are bit-identical
    whatever the grain (every score is a pure function of the plan shape).
    """
    import dataclasses as _dc

    from repro.core.workloads import mapper_workloads
    from repro.exec import parallel_map
    from repro.mapper import MapperConfig, QUICK_MAPPER

    base = QUICK_MAPPER if sweep.mapper_space == "quick" else MapperConfig()
    space_overrides = {"sim_rounds": sweep.sim_rounds,
                       "chips_list": sweep.mapper_chips}
    if sweep.mapper_pe_budget is not None:
        space_overrides["pe_budget"] = sweep.mapper_pe_budget
    mcfg = _dc.replace(base, **space_overrides)
    workloads = mapper_workloads(conv=sweep.workloads,
                                 transformers=sweep.mapper_transformers,
                                 tokens=sweep.mapper_tokens)
    outs = parallel_map(
        _search_one_workload,
        [(name, layers, mcfg, sweep.jobs)
         for name, layers in workloads.items()],
        jobs=sweep.jobs)
    from repro.mapper.search import memo_merge

    rows, pareto, schedules = [], {}, {}
    for (name, layers), (out, elapsed_us, memos) in zip(workloads.items(),
                                                        outs):
        memo_merge(memos)
        rows.append({
            "workload": name,
            "layers": len(layers),
            "hardware": "x".join(map(str, out.best.hardware)),
            "latency_x": out.latency_x,
            "energy_x": out.energy_x,
            "paper_latency_cycles": out.baseline.latency_cycles,
            "auto_latency_cycles": out.best.latency_cycles,
            "paper_energy_pj": out.baseline.total_energy_pj,
            "auto_energy_pj": out.best.total_energy_pj,
            "paper_utilization": out.baseline.pe_utilization,
            "auto_utilization": out.best.pe_utilization,
            "search": out.stats,
            "elapsed_us": elapsed_us,
        })
        pareto[name] = [{
            "hardware": "x".join(map(str, s.hardware)),
            "latency_cycles": s.latency_cycles,
            "total_energy_pj": s.total_energy_pj,
            "pe_utilization": s.pe_utilization,
        } for s in out.pareto]
        schedules[name] = out.best.to_dict()
    return {"figure": "mapper", "paper_reference": PAPER_REFERENCE["mapper"],
            "sim_rounds": sweep.sim_rounds, "space": sweep.mapper_space,
            "pe_budget": mcfg.pe_budget, "chips_list": list(mcfg.chips_list),
            "rows": rows, "pareto": pareto, "best_schedules": schedules}


def run_plan(sweep: SweepConfig = DEFAULT_SWEEP) -> dict:
    """Plan section: one ExecutionPlan per (config, phase) on the
    production mesh shape (DESIGN.md S11).

    Plans are produced through the persistent :class:`repro.plan.PlanStore`
    (``sweep.plan_dir``, default ``results/.plans``): a warm store answers
    with **zero collective engine runs** — the per-row
    ``collective_engine_runs`` delta is the evidence, and any failure
    becomes an attributable ``plan_error`` row (CI fails on those).  The
    returned dict embeds every plan verbatim, so ``plan.json`` is a
    self-contained, diffable artifact.

    The build is jax-trace-bound and plans ride the warm sim cache, so this
    section does not fan out over ``sweep.jobs`` (forking after jax
    initializes is not safe).
    """
    from repro.core.noc.collective.cost import COST_STATS
    from repro.configs import ARCHS
    from repro.plan import PlanStore

    store = PlanStore(sweep.plan_dir)
    rows, plans = [], {}
    for arch, cfg in ARCHS.items():
        for phase in sweep.plan_phases:
            t0 = time.time()
            runs0 = COST_STATS["engine_runs"]
            try:
                plan, built = store.get_or_build(
                    cfg, sweep.plan_mesh, phase,
                    mapper_space=sweep.mapper_space)
            except Exception as e:               # noqa: BLE001
                rows.append({"workload": arch, "phase": phase,
                             "plan_error": f"{type(e).__name__}: {e}",
                             "elapsed_us": (time.time() - t0) * 1e6})
                continue
            s = plan.psum_summary()
            base_lat = sum(g.baseline_latency_cycles for g in plan.gemms)
            best_lat = sum(g.latency_cycles for g in plan.gemms)
            base_en = sum(g.baseline_energy_pj for g in plan.gemms)
            best_en = sum(g.energy_pj for g in plan.gemms)
            rows.append({
                "workload": arch, "phase": phase, "key": plan.key,
                "warm": not built,
                "sites": s["sites"], "distinct_sites": s["distinct"],
                "modes": s["modes"],
                "psum_latency_x": s["latency_delta_x"],
                "psum_energy_x": s["energy_delta_x"],
                "mapper_latency_x": base_lat / best_lat if best_lat else 1.0,
                "mapper_energy_x": base_en / best_en if best_en else 1.0,
                "mapper_hardware": "x".join(map(str, plan.mapper_hardware))
                if plan.mapper_hardware else "NA",
                "tiles": len(plan.tiles),
                "collective_engine_runs":
                    COST_STATS["engine_runs"] - runs0,
                "elapsed_us": (time.time() - t0) * 1e6,
            })
            plans[plan.key] = plan.to_dict()
    return {"figure": "plan", "paper_reference": PAPER_REFERENCE["plan"],
            "phases": list(sweep.plan_phases),
            "mesh": [list(p) for p in sweep.plan_mesh],
            "store": str(store.dir), "rows": rows, "plans": plans}


def run_serve(sweep: SweepConfig = DEFAULT_SWEEP) -> dict:
    """Serve section: qps x fleet x collective-semantics capacity sweep
    (DESIGN.md S12).

    For each arch in ``sweep.serve_archs``, builds the per-phase serving
    plans once (warm :class:`~repro.plan.PlanStore`), then prices the same
    plan under both collective semantics — ``ina`` (in-network
    accumulation) and ``eject_inject`` (the software baseline) — and runs
    the request-level cluster simulator over every (qps, fleet) point.
    The headline per (arch, qps, semantics) is the smallest fleet meeting
    the ``sweep.serve_slo_metric`` p99 SLO (default: admission-queueing
    delay — the latency component fleet size actually buys down on the
    prefill-bound modeled mesh), so the INA advantage reads directly as
    *fewer meshes per SLO*.  Failures become attributable ``serve_error``
    rows (CI fails on those); everything is seeded, so rows are
    deterministic.
    """
    from repro.configs import ARCHS
    from repro.serve.cluster import ClusterSimulator
    from repro.serve.costs import PlanCostModel, SEMANTICS, serve_plans
    from repro.serve.traffic import make_workload

    slo_s = sweep.serve_slo_ms / 1e3
    rows, answers = [], []
    for arch in sweep.serve_archs:
        cfg = ARCHS[arch]
        t0 = time.time()
        try:
            plans = serve_plans(cfg, sweep.plan_mesh,
                                plan_dir=sweep.plan_dir, verbose=False)
        except Exception as e:                   # noqa: BLE001
            rows.append({"workload": arch,
                         "serve_error": f"{type(e).__name__}: {e}",
                         "elapsed_us": (time.time() - t0) * 1e6})
            continue
        plan_sims = sum(info["collective_sims"]
                        for _, info in plans.values())
        for sem in SEMANTICS:
            cost = PlanCostModel.from_plans(
                cfg, plans["prefill"][0], plans["decode"][0],
                prefill_chunk=sweep.serve_chunk, semantics=sem)
            for qps in sweep.serve_qps:
                reqs = make_workload(sweep.serve_requests, qps,
                                     sweep.serve_prompt_dist,
                                     sweep.serve_gen_dist, sweep.serve_seed)
                fleet_needed = None
                for fleet in sweep.serve_fleets:
                    t1 = time.time()
                    try:
                        m = ClusterSimulator(
                            fleet, slots=sweep.serve_slots,
                            block_size=sweep.serve_block,
                            max_seq=sweep.serve_max_seq,
                            prefill_chunk=sweep.serve_chunk,
                            cost=cost).run(reqs)
                    except Exception as e:       # noqa: BLE001
                        rows.append({
                            "workload": arch, "semantics": sem, "qps": qps,
                            "fleet": fleet,
                            "serve_error": f"{type(e).__name__}: {e}",
                            "elapsed_us": (time.time() - t1) * 1e6})
                        continue
                    p99 = m[sweep.serve_slo_metric]["p99"]
                    met = p99 <= slo_s
                    if met and fleet_needed is None:
                        fleet_needed = fleet
                    rows.append({
                        "workload": arch, "semantics": sem, "qps": qps,
                        "fleet": fleet,
                        "p99_slo_ms": p99 * 1e3,
                        "p99_queueing_ms": m["queueing_s"]["p99"] * 1e3,
                        "p99_ttft_ms": m["ttft_s"]["p99"] * 1e3,
                        "p99_e2e_ms": m["e2e_s"]["p99"] * 1e3,
                        "throughput_rps": m["throughput_rps"],
                        "throughput_tok_s": m["throughput_tok_s"],
                        "littles_law_ratio": m["littles_law_ratio"],
                        "slo_met": met,
                        "plan_sims": plan_sims,
                        "elapsed_us": (time.time() - t1) * 1e6,
                    })
                answers.append({"workload": arch, "semantics": sem,
                                "qps": qps, "fleet_needed": fleet_needed})
    return {"figure": "serve", "paper_reference": PAPER_REFERENCE["serve"],
            "slo_metric": sweep.serve_slo_metric,
            "slo_ms": sweep.serve_slo_ms,
            "mesh": [list(p) for p in sweep.plan_mesh],
            "requests": sweep.serve_requests, "seed": sweep.serve_seed,
            "rows": rows, "answers": answers}


def run_faults(sweep: SweepConfig = DEFAULT_SWEEP) -> dict:
    """Faults section: how much of the INA advantage survives a damaged
    chip (DESIGN.md S15).

    For every ``sweep.fault_levels`` density, seeds a
    :class:`~repro.core.noc.faults.FaultModel` on the
    ``sweep.fault_mesh_n`` mesh and prices each CNN workload's per-layer
    psum allreduces (payloads from the fig7-12 WS plan shapes) under
    both collective semantics over the **repaired** trees —
    ``latency_x``/``energy_x`` are eject/inject over INA on the same
    faulted fabric, and ``ina_degraded_x`` is faulted-INA over clean-INA
    (how much the detours cost).  The zero-rate level runs the exact
    clean code path, so its rows double as the degenerate-equivalence
    baseline.  A second row set runs the request-level cluster simulator
    with a seeded replica-failure trace and a
    :class:`~repro.serve.costs.DegradedCostModel` priced from the same
    faulted mesh — p99/goodput under degradation.  Failures become
    attributable ``faults_error`` rows (CI fails on those).
    """
    from repro.core.noc.collective.cost import collective_cost
    from repro.core.noc.faults import seeded_faults
    from repro.core.noc.traffic import layer_plan
    from repro.serve.cluster import ClusterSimulator, replica_failure_trace
    from repro.serve.costs import (DegradedCostModel, SyntheticCostModel,
                                   fault_slowdown)
    from repro.serve.traffic import make_workload

    n = sweep.fault_mesh_n
    cfg = sweep.cfg(n)
    rows = []
    clean: dict[str, tuple[float, float]] = {}   # workload -> (lat, en)
    for label, link_rate, router_rate, pe_rate in sweep.fault_levels:
        faults = seeded_faults(n, n, link_rate=link_rate,
                               router_rate=router_rate, pe_rate=pe_rate,
                               seed=sweep.fault_seed)
        fkw = {} if faults.empty else {"faults": faults}
        for name in sweep.workloads:
            t0 = time.time()
            try:
                tot = {sem: [0.0, 0.0] for sem in ("ina", "eject_inject")}
                for layer in WORKLOADS[name]:
                    plan = layer_plan(layer, cfg, 1, "ws_ina")
                    payload = float(plan.unicast_flits * cfg.flit_bits)
                    for sem in ("ina", "eject_inject"):
                        c = collective_cost("allreduce", payload, cfg,
                                            semantics=sem, **fkw)
                        tot[sem][0] += c.latency_cycles
                        tot[sem][1] += c.energy_pj
            except Exception as e:               # noqa: BLE001
                rows.append({"workload": name, "fault": label,
                             "faults_error": f"{type(e).__name__}: {e}",
                             "elapsed_us": (time.time() - t0) * 1e6})
                continue
            ina, ej = tot["ina"], tot["eject_inject"]
            if faults.empty:
                clean[name] = (ina[0], ina[1])
            base = clean.get(name)
            rows.append({
                "workload": name, "fault": label,
                "link_rate": link_rate, "router_rate": router_rate,
                "pe_rate": pe_rate,
                "failed_links": len(faults.links),
                "failed_routers": len(faults.routers),
                "failed_pes": len(faults.pes),
                "ina_latency_cycles": ina[0],
                "ej_latency_cycles": ej[0],
                "latency_x": ej[0] / ina[0] if ina[0] else 1.0,
                "ina_energy_pj": ina[1], "ej_energy_pj": ej[1],
                "energy_x": ej[1] / ina[1] if ina[1] else 1.0,
                "ina_degraded_x": ina[0] / base[0] if base else None,
                "ina_energy_degraded_x": ina[1] / base[1] if base else None,
                "elapsed_us": (time.time() - t0) * 1e6,
            })
    # Degraded serving: seeded replica failures + fault-priced slowdown.
    qps = sweep.serve_qps[-1]
    reqs = make_workload(sweep.serve_requests, qps,
                         sweep.serve_prompt_dist, sweep.serve_gen_dist,
                         sweep.serve_seed)
    horizon = max(r.arrival for r in reqs)
    cluster_rows = []
    for label, link_rate, router_rate, pe_rate in sweep.fault_levels:
        t0 = time.time()
        try:
            faults = seeded_faults(n, n, link_rate=link_rate,
                                   router_rate=router_rate,
                                   pe_rate=pe_rate, seed=sweep.fault_seed)
            slowdown = fault_slowdown(faults, cfg)
            cost = DegradedCostModel(SyntheticCostModel(), slowdown)
            trace = () if faults.empty else tuple(replica_failure_trace(
                sweep.fault_cluster_fleet, horizon,
                mtbf_s=horizon * 0.3, mttr_s=horizon * 0.08,
                seed=sweep.serve_seed))
            m = ClusterSimulator(
                sweep.fault_cluster_fleet, slots=sweep.serve_slots,
                block_size=sweep.serve_block, max_seq=sweep.serve_max_seq,
                prefill_chunk=sweep.serve_chunk, cost=cost,
                failures=list(trace)).run(reqs)
        except Exception as e:                   # noqa: BLE001
            cluster_rows.append({
                "fault": label,
                "faults_error": f"{type(e).__name__}: {e}",
                "elapsed_us": (time.time() - t0) * 1e6})
            continue
        cluster_rows.append({
            "fault": label, "slowdown": slowdown,
            "fleet": sweep.fault_cluster_fleet, "qps": qps,
            "failure_events": len(trace),
            "p99_e2e_ms": m["e2e_s"]["p99"] * 1e3,
            "p99_queueing_ms": m["queueing_s"]["p99"] * 1e3,
            "goodput": m["goodput"], "retries": m["retries"],
            "failed_requests": m["failed_requests"],
            "downtime_events": m["downtime_events"],
            "elapsed_us": (time.time() - t0) * 1e6,
        })
    return {"figure": "faults",
            "paper_reference": PAPER_REFERENCE["faults"],
            "mesh_n": n, "seed": sweep.fault_seed,
            "levels": [list(level) for level in sweep.fault_levels],
            "rows": rows, "cluster_rows": cluster_rows}


_RUNNERS: dict[str, Callable[[SweepConfig], dict]] = {
    "tables": run_tables, "fig7_9": run_fig7_9,
    "fig10_12": run_fig10_12, "mesh_scaling": run_mesh_scaling,
    "hierarchy": run_hierarchy, "mapper": run_mapper, "plan": run_plan,
    "serve": run_serve, "faults": run_faults,
}


# --------------------------------------------------------------------------- #
# Legacy benchmark CSV rows (``name,us_per_call,derived``)
# --------------------------------------------------------------------------- #
def _table_csv_row(r: dict) -> str:
    ina = r["INA#"] if r["INA#"] is not None else "NA"
    return (f"table_{r['network']}_N{r['n']},{r['layer']},"
            f"P#={r['P#']},INA#={ina}")


def tables_csv_lines(sweep: SweepConfig = DEFAULT_SWEEP) -> list[str]:
    return [_table_csv_row(r) for r in run_tables(sweep)["rows"]]


def _fig_section_csv(section: str, fig: dict) -> list[str]:
    """Legacy rows + tail line for one computed fig7_9/fig10_12 dict (the
    single emitter shared by the bench wrappers and ``run_all``)."""
    lines = [(f"{section}_{r['workload']}_E{r['e_pes']},"
              f"{r.get('elapsed_us', 0.0):.0f},"
              f"latency_x={r['latency_x']:.3f};"
              f"energy_x={r['energy_x']:.3f};"
              f"power_x={r['power_x']:.3f}") for r in fig["rows"]]
    if section == "fig7_9":
        avg = fig["average"]
        lines.append(f"fig7_9_average,0,latency_x={avg['latency_x']:.3f};"
                     f"energy_x={avg['energy_x']:.3f};"
                     f"paper=1.22x_latency_2.16x_power")
    else:
        lines.append("fig10_12_note,0,paper=up_to_1.19x_latency_2.16x_power")
    return lines


def fig7_9_csv_lines(sweep: SweepConfig = DEFAULT_SWEEP) -> list[str]:
    return _fig_section_csv("fig7_9", run_fig7_9(sweep))


def fig10_12_csv_lines(sweep: SweepConfig = DEFAULT_SWEEP) -> list[str]:
    return _fig_section_csv("fig10_12", run_fig10_12(sweep))


def _hierarchy_csv(fig: dict) -> list[str]:
    return [(f"hier_N{r['n']}_p{r['payload_bits']}_c{r['chips']}"
             f"_{r['package']}_w{r['pkg_width_ratio']},"
             f"{r.get('elapsed_us', 0.0):.0f},"
             f"latency_x={r['latency_x']:.3f};energy_x={r['energy_x']:.3f};"
             f"ina_cycles={r['ina_latency_cycles']}")
            for r in fig["rows"]]


def hierarchy_csv_lines(sweep: SweepConfig = DEFAULT_SWEEP) -> list[str]:
    return _hierarchy_csv(run_hierarchy(sweep))


def _mapper_csv(fig: dict) -> list[str]:
    return [(f"mapper_{r['workload']},{r.get('elapsed_us', 0.0):.0f},"
             f"latency_x={r['latency_x']:.3f};energy_x={r['energy_x']:.3f};"
             f"hw={r['hardware']}") for r in fig["rows"]]


def mapper_csv_lines(sweep: SweepConfig = DEFAULT_SWEEP) -> list[str]:
    return _mapper_csv(run_mapper(sweep))


def sanitize_error(msg, escape: str = ",") -> str:
    """One-line, metachar-free rendering of an exception message for CSV
    rows and markdown tables (shared with ``report._plan_table``)."""
    return " ".join(str(msg).split()).replace(escape, ";")[:160]


def _plan_csv(fig: dict) -> list[str]:
    """CSV rows for the plan section; failures keep the ``plan_error``
    prefix CI greps for."""
    lines = []
    for r in fig["rows"]:
        if "plan_error" in r:
            msg = sanitize_error(r["plan_error"], ",")
            lines.append(f"plan_error_{r['workload']}_{r['phase']},0,{msg}")
            continue
        modes = "+".join(f"{m}:{c}" for m, c in r["modes"].items())
        lines.append(
            f"plan_{r['workload']}_{r['phase']},{r['elapsed_us']:.0f},"
            f"sites={r['sites']};modes={modes};"
            f"psum_latency_x={r['psum_latency_x']:.3f};"
            f"mapper_latency_x={r['mapper_latency_x']:.3f};"
            f"warm={int(r['warm'])};sims={r['collective_engine_runs']}")
    return lines


def plan_csv_lines(sweep: SweepConfig = DEFAULT_SWEEP) -> list[str]:
    return _plan_csv(run_plan(sweep))


def _serve_csv(fig: dict) -> list[str]:
    """CSV rows for the serve section; failures keep the ``serve_error``
    prefix CI greps for, and per-(arch, qps, semantics) answer rows carry
    the fleet-sizing headline."""
    lines = []
    for r in fig["rows"]:
        if "serve_error" in r:
            msg = sanitize_error(r["serve_error"], ",")
            tag = "_".join(str(r[k]) for k in ("workload", "semantics",
                                               "qps", "fleet") if k in r)
            lines.append(f"serve_error_{tag},0,{msg}")
            continue
        lines.append(
            f"serve_{r['workload']}_{r['semantics']}"
            f"_q{r['qps']:g}_f{r['fleet']},{r['elapsed_us']:.0f},"
            f"p99_queueing_ms={r['p99_queueing_ms']:.3f};"
            f"p99_ttft_ms={r['p99_ttft_ms']:.3f};"
            f"tok_s={r['throughput_tok_s']:.1f};"
            f"slo_met={int(r['slo_met'])};sims={r['plan_sims']}")
    for a in fig["answers"]:
        fleet = a["fleet_needed"] if a["fleet_needed"] is not None else "NA"
        lines.append(
            f"serve_answer_{a['workload']}_{a['semantics']}_q{a['qps']:g},0,"
            f"fleet={fleet};slo_p99_{fig['slo_metric']}={fig['slo_ms']:g}ms")
    return lines


def serve_csv_lines(sweep: SweepConfig = DEFAULT_SWEEP) -> list[str]:
    return _serve_csv(run_serve(sweep))


def _faults_csv(fig: dict) -> list[str]:
    """CSV rows for the faults section; failures keep the ``faults_error``
    prefix CI greps for."""
    lines = []
    for r in fig["rows"]:
        if "faults_error" in r:
            msg = sanitize_error(r["faults_error"], ",")
            lines.append(
                f"faults_error_{r['workload']}_{r['fault']},0,{msg}")
            continue
        deg = (f"{r['ina_degraded_x']:.3f}"
               if r["ina_degraded_x"] is not None else "NA")
        lines.append(
            f"faults_{r['workload']}_{r['fault']},"
            f"{r['elapsed_us']:.0f},"
            f"latency_x={r['latency_x']:.3f};energy_x={r['energy_x']:.3f};"
            f"ina_degraded_x={deg};links_down={r['failed_links']}")
    for r in fig["cluster_rows"]:
        if "faults_error" in r:
            msg = sanitize_error(r["faults_error"], ",")
            lines.append(f"faults_error_cluster_{r['fault']},0,{msg}")
            continue
        lines.append(
            f"faults_cluster_{r['fault']},{r['elapsed_us']:.0f},"
            f"goodput={r['goodput']:.3f};p99_e2e_ms={r['p99_e2e_ms']:.1f};"
            f"retries={r['retries']};slowdown={r['slowdown']:.3f}")
    return lines


def faults_csv_lines(sweep: SweepConfig = DEFAULT_SWEEP) -> list[str]:
    return _faults_csv(run_faults(sweep))


# --------------------------------------------------------------------------- #
# Full run: JSON per figure + markdown summary + benchmark CSV
# --------------------------------------------------------------------------- #
def run_all(sweep: SweepConfig = DEFAULT_SWEEP,
            out_dir: str | Path = "results",
            sections: tuple[str, ...] = SECTIONS,
            write_csv: bool = True) -> dict:
    """Run ``sections`` of the evaluation; write artifacts into ``out_dir``.

    Returns ``{section: figure_dict}`` plus ``_meta`` (timings + cache
    stats).  Artifacts: ``<section>.json`` per section, ``summary.md``,
    and (``write_csv``) ``benchmarks.csv`` with the legacy fig7-12 rows.
    """
    from .report import summary_markdown

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    results: dict = {}
    timings: dict[str, float] = {}
    cache_before = SIM_CACHE.stats()
    for section in sections:
        if section not in _RUNNERS:
            raise ValueError(f"unknown section {section!r}; "
                             f"pick from {SECTIONS}")
        t0 = time.time()
        fig = _RUNNERS[section](sweep)
        timings[section] = time.time() - t0
        results[section] = fig
        (out / f"{section}.json").write_text(json.dumps(fig, indent=2))
    # Report cache activity as deltas so the artifact describes *this* run
    # even when earlier work in the process warmed the process-wide cache.
    cache_after = SIM_CACHE.stats()
    delta = {k: cache_after[k] - cache_before[k]
             for k in ("hits", "misses", "disk_hits")}
    looked = delta["hits"] + delta["misses"]
    cache = {"enabled": cache_after["enabled"],
             "entries": cache_after["entries"],
             "hit_rate": delta["hits"] / looked if looked else 0.0,
             "persist_dir": cache_after["persist_dir"], **delta}
    results["_meta"] = {"sweep": asdict(sweep), "elapsed_s": timings,
                        "cache": cache}
    (out / "summary.md").write_text(summary_markdown(results))
    if write_csv:
        # Derived from the rows computed above — nothing is re-simulated;
        # the timing column carries the per-section wall time instead of
        # per-call timings (use the bench_*.py scripts for those).
        csv = ["name,us_per_call,derived"]
        if "tables" in sections:
            csv += [_table_csv_row(r) for r in results["tables"]["rows"]]
        for section in ("fig7_9", "fig10_12"):
            if section in sections:
                csv += _fig_section_csv(section, results[section])
        if "hierarchy" in sections:
            csv += _hierarchy_csv(results["hierarchy"])
        if "mapper" in sections:
            csv += _mapper_csv(results["mapper"])
        if "plan" in sections:
            csv += _plan_csv(results["plan"])
        if "serve" in sections:
            csv += _serve_csv(results["serve"])
        if "faults" in sections:
            csv += _faults_csv(results["faults"])
        (out / "benchmarks.csv").write_text("\n".join(csv) + "\n")
    return results
