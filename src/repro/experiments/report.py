"""Markdown summary for a sweep run (written as ``results/summary.md``)."""
from __future__ import annotations


def _ratio_table(rows: list[dict], extra_cols: tuple[str, ...] = ()) -> str:
    cols = list(extra_cols) + ["workload", "e_pes",
                               "latency_x", "power_x", "energy_x"]
    head = "| " + " | ".join(cols) + " |"
    rule = "|" + "|".join("---" for _ in cols) + "|"
    body = []
    for r in rows:
        cells = [f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c])
                 for c in cols]
        body.append("| " + " | ".join(cells) + " |")
    return "\n".join([head, rule] + body)


def _hierarchy_table(rows: list[dict]) -> str:
    head = ("| N | payload (Kbit) | chips | package | width ratio | "
            "INA cycles | latency_x | energy_x |")
    rule = "|---|---|---|---|---|---|---|---|"
    body = [(f"| {r['n']} | {r['payload_bits'] / 1024:g} | {r['chips']} | "
             f"{r['package']} | {r['pkg_width_ratio']} | "
             f"{r['ina_latency_cycles']} | {r['latency_x']:.3f} | "
             f"{r['energy_x']:.3f} |") for r in rows]
    return "\n".join([head, rule] + body)


def _mapper_table(rows: list[dict]) -> str:
    head = ("| workload | layers | best hw (WxHxE) | latency_x | energy_x | "
            "util (paper -> auto) |")
    rule = "|---|---|---|---|---|---|"
    body = [(f"| {r['workload']} | {r['layers']} | {r['hardware']} | "
             f"{r['latency_x']:.3f} | {r['energy_x']:.3f} | "
             f"{r['paper_utilization']:.3f} -> {r['auto_utilization']:.3f} |")
            for r in rows]
    return "\n".join([head, rule] + body)


def _plan_table(rows: list[dict]) -> str:
    head = ("| workload | phase | sites (distinct) | modes | psum lat_x | "
            "mapper lat_x | hw | warm | sims |")
    rule = "|---|---|---|---|---|---|---|---|---|"
    body = []
    for r in rows:
        if "plan_error" in r:
            # Keep the table well-formed: exception text may carry
            # newlines/pipes (jax trace errors do).
            from .sweeps import sanitize_error
            msg = sanitize_error(r["plan_error"], "|")
            body.append(f"| {r['workload']} | {r['phase']} | "
                        f"ERROR: {msg} | | | | | | |")
            continue
        modes = ", ".join(f"{m}:{c}" for m, c in r["modes"].items())
        body.append(
            f"| {r['workload']} | {r['phase']} | {r['sites']} "
            f"({r['distinct_sites']}) | {modes} | "
            f"{r['psum_latency_x']:.3f} | {r['mapper_latency_x']:.3f} | "
            f"{r['mapper_hardware']} | {'yes' if r['warm'] else 'no'} | "
            f"{r['collective_engine_runs']} |")
    return "\n".join([head, rule] + body)


def _serve_table(fig: dict) -> str:
    head = ("| workload | semantics | qps | fleet | p99 queueing (s) | "
            "p99 ttft (s) | tok/s | SLO |")
    rule = "|---|---|---|---|---|---|---|---|"
    body = []
    for r in fig["rows"]:
        if "serve_error" in r:
            from .sweeps import sanitize_error
            msg = sanitize_error(r["serve_error"], "|")
            body.append(f"| {r['workload']} | {r.get('semantics', '')} | "
                        f"{r.get('qps', '')} | {r.get('fleet', '')} | "
                        f"ERROR: {msg} | | | |")
            continue
        body.append(
            f"| {r['workload']} | {r['semantics']} | {r['qps']:g} | "
            f"{r['fleet']} | {r['p99_queueing_ms'] / 1e3:.2f} | "
            f"{r['p99_ttft_ms'] / 1e3:.2f} | {r['throughput_tok_s']:.1f} | "
            f"{'met' if r['slo_met'] else 'miss'} |")
    lines = [head, rule] + body
    answers = fig.get("answers") or []
    if answers:
        lines += ["", f"**Fleet sizing (p99 {fig['slo_metric']} <= "
                      f"{fig['slo_ms'] / 1e3:g} s modeled):**"]
        for a in answers:
            fleet = (f"{a['fleet_needed']} instance(s)"
                     if a["fleet_needed"] is not None
                     else "not met at swept sizes")
            lines.append(f"- {a['workload']} @ {a['qps']:g} qps "
                         f"[{a['semantics']}]: {fleet}")
    return "\n".join(lines)


def _faults_table(fig: dict) -> str:
    head = ("| workload | fault | links/routers/PEs down | latency_x | "
            "energy_x | INA degraded_x |")
    rule = "|---|---|---|---|---|---|"
    body = []
    for r in fig["rows"]:
        if "faults_error" in r:
            from .sweeps import sanitize_error
            msg = sanitize_error(r["faults_error"], "|")
            body.append(f"| {r['workload']} | {r['fault']} | "
                        f"ERROR: {msg} | | | |")
            continue
        deg = (f"{r['ina_degraded_x']:.3f}"
               if r["ina_degraded_x"] is not None else "NA")
        body.append(
            f"| {r['workload']} | {r['fault']} | "
            f"{r['failed_links']}/{r['failed_routers']}/{r['failed_pes']} | "
            f"{r['latency_x']:.3f} | {r['energy_x']:.3f} | {deg} |")
    lines = [head, rule] + body
    cluster = fig.get("cluster_rows") or []
    if cluster:
        lines += ["", "**Cluster degradation (seeded replica-failure "
                      "trace + fault-priced slowdown):**"]
        for r in cluster:
            if "faults_error" in r:
                from .sweeps import sanitize_error
                lines.append(f"- {r['fault']}: ERROR "
                             f"{sanitize_error(r['faults_error'], '|')}")
                continue
            lines.append(
                f"- {r['fault']}: slowdown {r['slowdown']:.3f}x, "
                f"goodput {r['goodput']:.3f}, p99 e2e "
                f"{r['p99_e2e_ms'] / 1e3:.2f} s, {r['retries']} retries, "
                f"{r['failed_requests']} failed, "
                f"{r['downtime_events']} downtime event(s)")
    return "\n".join(lines)


def _tables_table(rows: list[dict]) -> str:
    head = "| network | N | layer | P# | INA# |"
    rule = "|---|---|---|---|---|"
    body = [f"| {r['network']} | {r['n']} | {r['layer']} | {r['P#']} | "
            f"{r['INA#'] if r['INA#'] is not None else 'NA'} |"
            for r in rows]
    return "\n".join([head, rule] + body)


def summary_markdown(results: dict) -> str:
    """Render the dict returned by :func:`~.sweeps.run_all` as markdown."""
    parts = ["# Paper-evaluation sweep summary", ""]
    meta = results.get("_meta", {})
    sweep = meta.get("sweep", {})
    if sweep:
        parts += [f"Sweep: `sim_rounds={sweep.get('sim_rounds')}`, "
                  f"E ∈ {sweep.get('e_list')}, N ∈ {sweep.get('n_list')}, "
                  f"workloads {sweep.get('workloads')}", ""]
    for section in ("fig7_9", "fig10_12"):
        fig = results.get(section)
        if not fig:
            continue
        parts += [f"## {section} — {fig['paper_reference']}", "",
                  _ratio_table(fig["rows"]), ""]
        avg = fig.get("average")
        if avg:
            parts += [f"**Simulated average:** latency_x="
                      f"{avg['latency_x']:.3f}, power_x={avg['power_x']:.3f},"
                      f" energy_x={avg['energy_x']:.3f}", ""]
    fig = results.get("mesh_scaling")
    if fig:
        parts += [f"## mesh_scaling — {fig['paper_reference']}", "",
                  _ratio_table(fig["rows"], extra_cols=("n",)), ""]
    fig = results.get("hierarchy")
    if fig:
        parts += [f"## hierarchy — {fig['paper_reference']}", "",
                  _hierarchy_table(fig["rows"]), "",
                  "Whole-package allreduce over every PE; ratios are "
                  "eject/inject over INA, so a row > 1 means the paper's "
                  "advantage survives that chip count and package-link "
                  "speed (`package=flat` rows are the single-chip paper "
                  "mesh; see DESIGN.md S14).", ""]
    fig = results.get("mapper")
    if fig:
        parts += [f"## mapper — {fig['paper_reference']}", "",
                  _mapper_table(fig["rows"]), "",
                  "Ratios are paper-fixed / auto-searched (>= 1 by the "
                  "baseline-dominating selection; see DESIGN.md S9). "
                  "Per-workload Pareto fronts and the winning "
                  "`NetworkSchedule`s are in `mapper.json`.", ""]
    fig = results.get("plan")
    if fig:
        parts += [f"## plan — {fig['paper_reference']}", "",
                  _plan_table(fig["rows"]), "",
                  "`psum lat_x` = predicted whole-model accumulation gain "
                  "of the planned strategies over all-eject/inject; "
                  "`warm`/`sims` show store behaviour (a warm store plans "
                  "with 0 collective simulations).  Full plans: "
                  "`plan.json` + the store dir (see EXPERIMENTS.md).", ""]
    fig = results.get("serve")
    if fig:
        parts += [f"## serve — {fig['paper_reference']}", "",
                  _serve_table(fig), "",
                  "Both semantics price the *same* per-phase ExecutionPlan; "
                  "`ina` uses planned collective latencies, `eject_inject` "
                  "the software-baseline ones, so a smaller fleet under "
                  "`ina` is the in-network-accumulation advantage stated "
                  "as capacity (see DESIGN.md S12).", ""]
    fig = results.get("faults")
    if fig:
        parts += [f"## faults — {fig['paper_reference']}", "",
                  _faults_table(fig), "",
                  "Collectives replan over repaired (turn-model-safe) "
                  "trees on the seeded faulted mesh; ratios are "
                  "eject/inject over INA on the *same* faulted fabric, "
                  "and `INA degraded_x` is faulted-INA over clean-INA "
                  "(see DESIGN.md S15).", ""]
    fig = results.get("tables")
    if fig:
        parts += [f"## Tables I & II — {fig['paper_reference']}", "",
                  _tables_table(fig["rows"]), ""]
    if meta:
        cache = meta.get("cache", {})
        timings = meta.get("elapsed_s", {})
        hit_rate = cache.get("hit_rate")
        rate = f", {hit_rate:.1%} hit rate" if hit_rate is not None else ""
        disk = cache.get("disk_hits")
        disk_s = f", {disk} from the persistent store" if disk else ""
        jobs = sweep.get("jobs")
        parts += ["## Run stats", "",
                  "Section timings: " + ", ".join(
                      f"{k} {v:.2f}s" for k, v in timings.items())
                  + (f" (jobs={jobs})" if jobs and jobs > 1 else ""),
                  f"Window cache: {cache.get('entries')} entries, "
                  f"{cache.get('hits')} hits / {cache.get('misses')} misses"
                  f"{rate}{disk_s} "
                  f"(see EXPERIMENTS.md)", ""]
    return "\n".join(parts)
