"""CLI: reproduce the paper's evaluation into ``results/``.

Usage (see EXPERIMENTS.md):

    PYTHONPATH=src python -m repro.experiments                 # full sweep
    PYTHONPATH=src python -m repro.experiments --quick         # CI smoke
    PYTHONPATH=src python -m repro.experiments --sections fig7_9,fig10_12
    PYTHONPATH=src python -m repro.experiments --section mapper  # mapping search
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

from repro.core.noc import simcache

from .sweeps import (DEFAULT_SWEEP, QUICK_SWEEP, SECTIONS, SweepConfig,
                     run_all)


def _int_tuple(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.split(",") if x)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments",
        description="Run the paper's evaluation sweeps (Tables I/II, "
                    "Figs 7-12, mesh scaling) and write JSON + markdown "
                    "artifacts.")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke shape: sim_rounds=4, E in {1,4}, "
                         "N in {4,8}")
    ap.add_argument("--out", default="results",
                    help="output directory (default: results/)")
    ap.add_argument("--sections", "--section", dest="sections",
                    default=",".join(SECTIONS),
                    help=f"comma-separated subset of {SECTIONS}")
    ap.add_argument("--sim-rounds", type=int, default=None,
                    help="override the simulated window length")
    ap.add_argument("--e", type=_int_tuple, default=None, metavar="E1,E2,..",
                    help="override the PEs-per-router sweep")
    ap.add_argument("--n", type=_int_tuple, default=None, metavar="N1,N2,..",
                    help="override the mesh-size sweep")
    ap.add_argument("--workloads", default=None,
                    help="comma-separated subset of alexnet,vgg16,resnet50")
    ap.add_argument("--pe-budget", type=int, default=None, metavar="P",
                    help="mapper section: per-chip W*H*E PE ceiling "
                         "(default: the space's own budget, 64)")
    ap.add_argument("--chips", type=_int_tuple, default=None,
                    metavar="C1,C2,..",
                    help="mapper section: package-replication axis, e.g. "
                         "1,2,4 (default 1 = flat mesh; DESIGN.md S14)")
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "vectorized", "compiled", "heap"),
                    help="simulation backend: auto/vectorized = array "
                         "kernels with compiled fallback (default), "
                         "compiled = PR-4 flat replay only, heap = "
                         "ground-truth event loop (all bit-identical; "
                         "DESIGN.md S16)")
    ap.add_argument("--no-cache", action="store_true",
                    help="bypass the plan-keyed window cache (ground truth)")
    ap.add_argument("--jobs", type=int, default=None, metavar="N",
                    help="fan sweeps/mapper search over N processes "
                         "(0 = all cores; default 1)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persistent window-cache directory (default "
                         f"${simcache.CACHE_DIR_ENV} or results/.simcache)")
    ap.add_argument("--plan-dir", default=None, metavar="DIR",
                    help="ExecutionPlan store for --section plan (default "
                         "$REPRO_PLAN_DIR or results/.plans)")
    ap.add_argument("--no-persist", action="store_true",
                    help="in-memory window cache only (no on-disk store)")
    args = ap.parse_args(argv)

    sweep: SweepConfig = QUICK_SWEEP if args.quick else DEFAULT_SWEEP
    overrides = {}
    if args.sim_rounds is not None:
        if args.sim_rounds < 1:
            ap.error("--sim-rounds must be >= 1")
        overrides["sim_rounds"] = args.sim_rounds
    for flag, value in (("--e", args.e), ("--n", args.n)):
        if value is not None and (not value or min(value) < 1):
            ap.error(f"{flag} needs at least one positive value")
    if args.e is not None:
        overrides["e_list"] = args.e
    if args.n is not None:
        overrides["n_list"] = args.n
    if args.workloads is not None:
        from repro.core.workloads import WORKLOADS
        workloads = tuple(w for w in args.workloads.split(",") if w)
        unknown = [w for w in workloads if w not in WORKLOADS]
        if unknown or not workloads:
            ap.error(f"unknown workloads {unknown}; "
                     f"pick from {sorted(WORKLOADS)}")
        overrides["workloads"] = workloads
    if args.pe_budget is not None:
        if args.pe_budget < 1:
            ap.error("--pe-budget must be >= 1")
        overrides["mapper_pe_budget"] = args.pe_budget
    if args.chips is not None:
        if not args.chips or min(args.chips) < 1:
            ap.error("--chips needs at least one positive value")
        overrides["mapper_chips"] = args.chips
    if args.jobs is not None:
        from repro.exec import default_jobs
        if args.jobs < 0:
            ap.error("--jobs must be >= 0 (0 = all cores)")
        overrides["jobs"] = default_jobs(args.jobs if args.jobs else None)
    if args.plan_dir is not None:
        overrides["plan_dir"] = args.plan_dir
    if overrides:
        sweep = dataclasses.replace(sweep, **overrides)

    loaded = 0
    if args.no_cache:
        simcache.configure(False)
    elif not args.no_persist:
        cache_dir = args.cache_dir or simcache.SIM_CACHE.persist_default_dir()
        loaded = simcache.SIM_CACHE.persist(cache_dir)
    sections = tuple(s for s in args.sections.split(",") if s)
    unknown = [s for s in sections if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown sections {unknown}; pick from {SECTIONS}")
    from contextlib import ExitStack

    from repro.core.noc.compiled import compiled_disabled
    from repro.core.noc.vectorized import vector_stats, vectorized_disabled
    with ExitStack() as stack:
        # All three backends are bit-identical; the flag exists to measure
        # them against each other and to pin down a backend when debugging.
        if args.engine == "compiled":
            stack.enter_context(vectorized_disabled())
        elif args.engine == "heap":
            stack.enter_context(compiled_disabled())
        results = run_all(sweep, out_dir=args.out, sections=sections)
    meta = results["_meta"]
    for section in sections:
        fig = results[section]
        line = f"{section}: {len(fig['rows'])} rows"
        if "average" in fig:
            avg = fig["average"]
            line += (f"  (avg latency_x={avg['latency_x']:.3f}, "
                     f"power_x={avg['power_x']:.3f}, "
                     f"energy_x={avg['energy_x']:.3f})")
        print(line)
    cache = meta["cache"]
    persisted = ""
    if not args.no_cache and not args.no_persist:
        saved = simcache.SIM_CACHE.save()
        persisted = (f"; persistent store: {loaded} rows loaded, "
                     f"{saved} saved ({simcache.SIM_CACHE.stats()['persist_dir']})")
    print(f"artifacts in {args.out}/ (summary.md, benchmarks.csv, "
          f"per-section JSON); cache: {cache['entries']} entries, "
          f"{cache['hits']} hits / {cache['misses']} misses "
          f"({cache['hit_rate']:.1%} hit rate)"
          f"{persisted}")
    v = vector_stats()
    state = "on" if v["enabled"] else "off"
    print(f"vectorized backend [{state}]: "
          f"{v['windows_closed_form']} closed-form windows "
          f"({v['windows_batched']} batched), "
          f"{v['columns_replayed']} column replays, "
          f"{v['programs_lowered']} DAG programs, "
          f"{v['fallbacks']} fallbacks")
    return 0


if __name__ == "__main__":
    sys.exit(main())
