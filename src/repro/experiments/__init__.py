"""Paper-evaluation sweep engine (Tables I/II, Figs 7-12, scaling studies).

``python -m repro.experiments`` runs the complete evaluation of the INA
paper through the plan-keyed simulation cache and emits per-figure JSON
plus a markdown summary into ``results/`` — see EXPERIMENTS.md for the CLI
and the cache design.  The ``benchmarks/bench_tables.py`` /
``bench_ws_ina.py`` / ``bench_ws_vs_os.py`` entry points are thin wrappers
over this package.
"""
from .sweeps import (DEFAULT_SWEEP, QUICK_SWEEP, SweepConfig, run_all,
                     run_fig7_9, run_fig10_12, run_mesh_scaling, run_tables)

__all__ = ["SweepConfig", "DEFAULT_SWEEP", "QUICK_SWEEP", "run_tables",
           "run_fig7_9", "run_fig10_12", "run_mesh_scaling", "run_all"]
