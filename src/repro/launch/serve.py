"""Batched decode serving driver.

Prefill is a forward pass that also populates the KV cache implicitly via
one serve_step per prompt token (CPU-scale demo); the serving loop then
decodes greedily with a batched, donated cache.  On a production mesh the
same ``build_serve_step`` artifact runs the decode_32k / long_500k cells.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 16 --gen 16 --psum-mode ina
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.core.collectives import CLI_PSUM_MODES
from repro.models.api import get_model
from repro.parallel.steps import build_serve_step
from repro.parallel.tp import ParallelCtx
from repro.plan import add_plan_cli_args, plan_for_launch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--psum-mode", default="ina", choices=CLI_PSUM_MODES)
    add_plan_cli_args(ap)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    mesh = make_host_mesh(args.model_parallel)

    max_seq = args.prompt_len + args.gen
    shape = ShapeConfig("cli", max_seq, args.batch, "decode")
    plan, _ = plan_for_launch(cfg, mesh, shape, args.psum_mode,
                              plan_dir=args.plan_dir,
                              enabled=not args.no_plan)
    pctx = ParallelCtx(mesh=mesh, psum_mode=args.psum_mode, plan=plan)
    ss = build_serve_step(model, mesh, shape, pctx, donate_cache=True)

    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            ss.param_sharding)
    cache = jax.device_put(model.init_cache(args.batch, max_seq),
                           ss.cache_sharding)

    key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 3,
                                 cfg.vocab)
    media = None
    if cfg.family in ("encdec", "vlm") and cfg.num_media_tokens:
        media = jnp.ones((args.batch, cfg.num_media_tokens, cfg.d_model),
                         jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        from repro.models import vision
        cache = vision.prefill_media_kv(params, cfg, media, cache, pctx)
        cache = jax.device_put(cache, ss.cache_sharding)

    # prefill token-by-token through the serve step (keeps one artifact)
    tok = prompts[:, :1]
    t0 = time.time()
    for pos in range(args.prompt_len):
        batch = {"tokens": prompts[:, pos:pos + 1],
                 "pos": jnp.asarray(pos, jnp.int32)}
        if media is not None:
            batch["media"] = media
        nxt, cache = ss.fn(params, batch, cache)
    print(f"[serve] prefill {args.prompt_len} steps "
          f"{(time.time()-t0)*1e3:.0f} ms")

    generated = []
    tok = nxt[:, None]
    t0 = time.time()
    for i in range(args.gen):
        batch = {"tokens": tok, "pos": jnp.asarray(args.prompt_len + i,
                                                   jnp.int32)}
        if media is not None:
            batch["media"] = media
        nxt, cache = ss.fn(params, batch, cache)
        generated.append(nxt)
        tok = nxt[:, None]
    dt = time.time() - t0
    out = jnp.stack(generated, axis=1)
    print(f"[serve] generated {args.gen} x {args.batch} tokens in "
          f"{dt*1e3:.0f} ms ({args.gen*args.batch/dt:.1f} tok/s)")
    print(f"[serve] sample row: {out[0].tolist()}")
    assert out.shape == (args.batch, args.gen)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())


if __name__ == "__main__":
    main()
