"""Serving driver: continuous batching + paged KV over the ServingEngine.

The default path seats every prompt through the engine
(:mod:`repro.serve.engine`): chunked **batched** prefill under a
prefill-phase ExecutionPlan, then vmapped per-slot decode under a
decode-phase plan — prefill/decode disaggregation with one plan per phase
via :func:`~repro.plan.plan_for_launch`.

``--legacy-loop`` keeps the pre-serving-engine behaviour (one batch, one
serve_step per prompt token) as an escape hatch and as the reference the
token-equivalence test pins against: the engine must produce exactly the
tokens the legacy loop does, request by request.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \\
      --batch 4 --prompt-len 16 --gen 16 --slots 2 --psum-mode ina
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.core.collectives import CLI_PSUM_MODES
from repro.models.api import get_model
from repro.parallel.steps import build_serve_step
from repro.parallel.tp import ParallelCtx
from repro.plan import add_plan_cli_args, plan_for_launch


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests (legacy: batch rows)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--psum-mode", default="ina", choices=CLI_PSUM_MODES)
    add_plan_cli_args(ap)
    ap.add_argument("--model-parallel", type=int, default=1)
    # engine path
    ap.add_argument("--slots", type=int, default=None,
                    help="continuous-batching slots (default: --batch)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--no-batched-prefill", action="store_true",
                    help="prefill via the per-token decode loop")
    ap.add_argument("--check", action="store_true",
                    help="verify paged==monolithic cache on every retire")
    ap.add_argument("--legacy-loop", action="store_true",
                    help="pre-engine path: one batch, per-token prefill")
    return ap


def make_prompts(cfg, batch: int, prompt_len: int):
    """The seeded prompt block both paths share (key 7, legacy-compatible)."""
    return jax.random.randint(jax.random.PRNGKey(7), (batch, prompt_len), 3,
                              cfg.vocab)


def run_engine(args, cfg) -> None:
    from repro.serve.batching import Request
    from repro.serve.engine import ServingEngine

    mesh = make_host_mesh(args.model_parallel)
    max_seq = args.prompt_len + args.gen + 1
    slots = args.slots or args.batch
    # one plan per phase: prefill and decode disaggregate
    dshape = ShapeConfig("cli", max_seq, slots, "decode")
    pshape = ShapeConfig("cli", max_seq, slots, "prefill")
    decode_plan, _ = plan_for_launch(cfg, mesh, dshape, args.psum_mode,
                                     plan_dir=args.plan_dir,
                                     enabled=not args.no_plan)
    prefill_plan, _ = plan_for_launch(cfg, mesh, pshape, args.psum_mode,
                                      plan_dir=args.plan_dir,
                                      enabled=not args.no_plan)
    block = args.block_size
    if max_seq % block:
        block = 1 << max(0, (max_seq & -max_seq).bit_length() - 1)
        block = min(block, args.block_size)
        print(f"[serve] block size {args.block_size} does not divide "
              f"max_seq {max_seq}; using {block}")
    engine = ServingEngine(
        cfg, slots=slots, max_seq=max_seq, block_size=block,
        prefill_chunk=args.prefill_chunk, psum_mode=args.psum_mode,
        prefill_plan=prefill_plan, decode_plan=decode_plan,
        batched_prefill=not args.no_batched_prefill, check=args.check,
        model_parallel=args.model_parallel)

    prompts = make_prompts(cfg, args.batch, args.prompt_len)
    requests = [
        Request(rid=f"req{i}", prompt_len=args.prompt_len,
                max_new=args.gen + 1,
                prompt=tuple(int(t) for t in prompts[i]))
        for i in range(args.batch)]

    t0 = time.time()
    report = engine.run(requests)
    dt = time.time() - t0
    total = sum(len(r["tokens"]) for r in report.requests)
    print(f"[serve] engine: {args.batch} requests on {slots} slots, "
          f"{report.iterations} iterations ({report.prefill_chunks} prefill "
          f"chunks, {report.decode_steps} decode steps), {total} tokens in "
          f"{dt*1e3:.0f} ms ({total/dt:.1f} tok/s)")
    by_rid = report.tokens()
    sample = by_rid["req0"]
    print(f"[serve] sample req0: {sample}")
    for rid, toks in by_rid.items():
        assert all(0 <= t < cfg.vocab for t in toks), rid


def run_legacy(args, cfg) -> None:
    """The pre-engine loop: one fixed batch, per-token prefill steps."""
    model = get_model(cfg)
    mesh = make_host_mesh(args.model_parallel)

    max_seq = args.prompt_len + args.gen
    shape = ShapeConfig("cli", max_seq, args.batch, "decode")
    plan, _ = plan_for_launch(cfg, mesh, shape, args.psum_mode,
                              plan_dir=args.plan_dir,
                              enabled=not args.no_plan)
    pctx = ParallelCtx(mesh=mesh, psum_mode=args.psum_mode, plan=plan)
    ss = build_serve_step(model, mesh, shape, pctx, donate_cache=True)

    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            ss.param_sharding)
    cache = jax.device_put(model.init_cache(args.batch, max_seq),
                           ss.cache_sharding)

    prompts = make_prompts(cfg, args.batch, args.prompt_len)
    media = None
    if cfg.family in ("encdec", "vlm") and cfg.num_media_tokens:
        media = jnp.ones((args.batch, cfg.num_media_tokens, cfg.d_model),
                         jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        from repro.models import vision
        cache = vision.prefill_media_kv(params, cfg, media, cache, pctx)
        cache = jax.device_put(cache, ss.cache_sharding)

    # prefill token-by-token through the serve step (keeps one artifact)
    t0 = time.time()
    for pos in range(args.prompt_len):
        batch = {"tokens": prompts[:, pos:pos + 1],
                 "pos": jnp.asarray(pos, jnp.int32)}
        if media is not None:
            batch["media"] = media
        nxt, cache = ss.fn(params, batch, cache)
    print(f"[serve] prefill {args.prompt_len} steps "
          f"{(time.time()-t0)*1e3:.0f} ms")

    generated = []
    tok = nxt[:, None]
    t0 = time.time()
    for i in range(args.gen):
        batch = {"tokens": tok, "pos": jnp.asarray(args.prompt_len + i,
                                                   jnp.int32)}
        if media is not None:
            batch["media"] = media
        nxt, cache = ss.fn(params, batch, cache)
        generated.append(nxt)
        tok = nxt[:, None]
    dt = time.time() - t0
    out = jnp.stack(generated, axis=1)
    print(f"[serve] generated {args.gen} x {args.batch} tokens in "
          f"{dt*1e3:.0f} ms ({args.gen*args.batch/dt:.1f} tok/s)")
    print(f"[serve] sample row: {out[0].tolist()}")
    assert out.shape == (args.batch, args.gen)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    if args.legacy_loop or cfg.family in ("encdec", "vlm"):
        if not args.legacy_loop:
            print(f"[serve] family {cfg.family!r} needs media plumbing; "
                  "running the legacy loop")
        run_legacy(args, cfg)
    else:
        run_engine(args, cfg)


if __name__ == "__main__":
    main()
