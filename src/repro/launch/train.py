"""End-to-end training driver.

On a production pod this runs under ``jax.distributed`` with the 16x16 (or
2x16x16) mesh; on this CPU container it runs real training of reduced
configs (``--reduced``) over the host mesh — same code path, same
fault-tolerance machinery.

Example (trains a ~small dense model for 50 steps with checkpoints):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --psum-mode ina
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.api import get_model
from repro.optim.adamw import adamw_init
from repro.core.collectives import CLI_PSUM_MODES
from repro.parallel.steps import build_train_step
from repro.parallel.tp import ParallelCtx
from repro.plan import add_plan_cli_args, plan_for_launch
from repro.runtime.fault_tolerance import FTConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--psum-mode", default="ina", choices=CLI_PSUM_MODES)
    add_plan_cli_args(ap)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(args.model_parallel))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    plan, _ = plan_for_launch(cfg, mesh, shape, args.psum_mode,
                              plan_dir=args.plan_dir,
                              enabled=not args.no_plan)
    pctx = ParallelCtx(mesh=mesh, psum_mode=args.psum_mode, plan=plan)
    ts = build_train_step(model, mesh, shape, pctx, base_lr=args.lr,
                          warmup=min(20, args.steps // 5 + 1),
                          total_steps=args.steps, donate=False)

    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}) "
          f"mesh={dict(mesh.shape)} psum={args.psum_mode}")
    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            ts.param_sharding)
    opt = jax.device_put(adamw_init(params), ts.opt_sharding)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {n_params/1e6:.1f}M params")

    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch))

    def step_fn(state, batch):
        params, opt = state
        batch = {k: jax.device_put(v, ts.batch_sharding[k])
                 for k, v in batch.items()}
        params, opt, stats = ts.fn(params, opt, batch)
        return (params, opt), stats

    losses = []

    def on_metrics(step, metrics, dt):
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss {loss:7.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e}  {dt*1e3:6.0f} ms")

    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    state = (params, opt)
    state, last, stragglers = run_training(
        step_fn, state, pipe.batch, ft=ft, num_steps=args.steps,
        on_metrics=on_metrics)
    print(f"[train] done at step {last}; loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}; stragglers={len(stragglers)}")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
