"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

try:    # AxisType landed after 0.4; older jax means all-Auto implicitly
    from jax.sharding import AxisType
except ImportError:                     # pragma: no cover - jax-dependent
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU smoke / small examples)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return _make_mesh((n // mp, mp), ("data", "model"))
