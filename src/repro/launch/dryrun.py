import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real train/serve/prefill step with
ShapeDtypeStruct inputs (no allocation), compiles it for the production
mesh, and records memory_analysis / cost_analysis / collective byte counts
parsed from the HLO — the inputs to EXPERIMENTS.md SS Dry-run/Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--psum-mode ina]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json

``--psum-mode auto`` plans through the persistent ExecutionPlan store
(DESIGN.md S11): the first run builds and persists each cell's plan (plus
its collective-simulation rows), the second run plans entirely from the
warm store — 0 collective simulations, identical step artifacts.
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.compat import compiled_cost_analysis
from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models.api import get_model
from repro.parallel.steps import build_prefill, build_serve_step, build_train_step
from repro.parallel.tp import ParallelCtx

# bytes of every collective op parsed out of the per-device HLO
_COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\([^)]*\)|\S+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\b")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u8": 1, "s8": 1,
                "u16": 2, "s16": 2, "u32": 4, "s32": 4, "u64": 8, "s64": 8,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[128,1024]{1,0}'."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo: str) -> dict:
    """Sum output-shape bytes per collective kind from HLO text."""
    out: dict[str, float] = {}
    for line in hlo.splitlines():
        m = re.search(
            r"=\s*((?:\w+\[[^\]]*\][^ ]*|\([^)]*\)))\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", line)
        if not m:
            continue
        kind = m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(m.group(1))
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def _lower_step(cfg, shape, mesh, pctx):
    model = get_model(cfg)
    if shape.kind == "train":
        from repro.optim.adamw import adamw_init
        ts = build_train_step(model, mesh, shape, pctx)
        opt_shapes = jax.eval_shape(adamw_init, ts.param_shapes)
        return ts.fn.lower(ts.param_shapes, opt_shapes,
                           model.input_specs(shape))
    if shape.kind == "prefill":
        fn, psh, bsh, pshapes = build_prefill(model, mesh, shape, pctx)
        return fn.lower(pshapes, model.input_specs(shape))
    ss = build_serve_step(model, mesh, shape, pctx)
    return ss.fn.lower(ss.param_shapes, model.input_specs(shape),
                       ss.cache_shapes)


def _cost_point(cfg, shape, mesh, pctx) -> dict:
    """flops/bytes/collective-bytes of one compiled (per-device) program."""
    compiled = _lower_step(cfg, shape, mesh, pctx).compile()
    cost = compiled_cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": coll.get("total", 0.0), "coll_by_kind": coll}


def roofline_costs(cfg, shape, mesh, pctx, fast: bool = False) -> dict:
    """Per-unit marginal HLO costs via fully-unrolled shallow compiles,
    extrapolated to full depth (XLA cost_analysis counts scan bodies once —
    DESIGN.md S6).  ``fast``: single-compile variant (1 unit, fixed costs
    folded into the marginal -> <~5% overestimate of embed/logits terms);
    used for the chunk-heavy ssm/hybrid train/prefill cells where the
    two-point compile is prohibitive on this container.
    """
    from repro.configs.base import depth_scaled, depth_units
    units = depth_units(cfg)
    m1 = _cost_point(depth_scaled(cfg, 1), shape, mesh, pctx)
    out = {}
    if fast:
        for key in ("flops", "bytes", "coll"):
            out[key] = m1[key] * units
            out[f"{key}_per_unit"] = m1[key]
            out[f"{key}_fixed"] = 0.0
        out["units"] = units
        out["fast"] = True
        out["coll_by_kind_u2"] = m1["coll_by_kind"]
        return out
    m2 = _cost_point(depth_scaled(cfg, 2), shape, mesh, pctx)
    for key in ("flops", "bytes", "coll"):
        marginal = max(m2[key] - m1[key], 0.0)
        fixed = max(m1[key] - marginal, 0.0)
        out[key] = fixed + marginal * units
        out[f"{key}_per_unit"] = marginal
        out[f"{key}_fixed"] = fixed
    out["units"] = units
    out["coll_by_kind_u2"] = m2["coll_by_kind"]
    return out


def run_cell(arch: str, shape_name: str, mesh, psum_mode: str = "xla_spmd",
             verbose: bool = True, roofline: bool = True,
             plan_dir=None, use_plan: bool = True) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    # One plan per cell through the shared launch helper (same store keys
    # as train/serve); ``info`` carries the warm-store evidence — a warm
    # second dry-run plans every cell with 0 collective simulations.
    from repro.plan import plan_for_launch
    plan, plan_info = plan_for_launch(cfg, mesh, shape, psum_mode,
                                      plan_dir=plan_dir, enabled=use_plan,
                                      verbose=False)
    pctx = ParallelCtx(mesh=mesh, psum_mode=psum_mode, plan=plan)

    t0 = time.time()
    lowered = _lower_step(cfg, shape, mesh, pctx)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled_cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    n_dev = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": dict(mesh.shape), "devices": n_dev,
        "psum_mode": psum_mode,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }
    if plan_info is not None:
        result["plan"] = plan_info
    if roofline:
        fast = cfg.family in ("ssm", "hybrid") and \
            shape.kind in ("train", "prefill")
        result["roofline"] = roofline_costs(cfg, shape, mesh, pctx,
                                            fast=fast)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {n_dev}dev "
              f"({psum_mode}): lower {t_lower:.1f}s compile {t_compile:.1f}s")
        if plan_info is not None:
            src = "warm store" if plan_info["from_store"] else "built"
            print(f"  plan: {plan_info['key']} ({src}, "
                  f"{plan_info['collective_sims']} collective sims, "
                  f"{plan_info['plan_s']}s) "
                  f"modes={plan_info['psum']['modes']}")
        print(f"  memory: args={result['memory']['argument_bytes']:.3e} "
              f"temp={result['memory']['temp_bytes']:.3e} "
              f"peak={result['memory']['peak_bytes']:.3e}")
        if roofline:
            r = result["roofline"]
            print(f"  roofline/dev: flops={r['flops']:.3e} "
                  f"bytes={r['bytes']:.3e} coll={r['coll']:.3e} "
                  f"(units={r['units']})")
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    from repro.core.collectives import CLI_PSUM_MODES
    from repro.plan import add_plan_cli_args
    ap.add_argument("--psum-mode", default="xla_spmd",
                    choices=CLI_PSUM_MODES)
    add_plan_cli_args(ap)
    ap.add_argument("--no-roofline", action="store_true",
                    help="skip the unrolled costing compiles")
    ap.add_argument("--out", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    cells = []
    if args.all:
        for arch, cfg in ARCHS.items():
            for sname, shp in SHAPES.items():
                if shape_applicable(cfg, shp):
                    cells.append((arch, sname))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results, failures = [], []
    done = set()
    if args.out and args.resume:
        try:
            with open(args.out) as f:
                prev = json.load(f)
            results = prev.get("results", [])
            done = {(r["arch"], r["shape"], tuple(sorted(r["mesh"].items())))
                    for r in results}
            print(f"[dryrun] resuming: {len(done)} cells already done")
        except FileNotFoundError:
            pass

    def flush():
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"results": results, "failures": failures}, f,
                          indent=1)

    for mesh in meshes:
        for arch, sname in cells:
            key = (arch, sname, tuple(sorted(dict(mesh.shape).items())))
            if key in done:
                continue
            try:
                multi = "pod" in mesh.axis_names
                results.append(run_cell(arch, sname, mesh, args.psum_mode,
                                        roofline=not (args.no_roofline or multi),
                                        plan_dir=args.plan_dir,
                                        use_plan=not args.no_plan))
            except Exception as e:               # noqa: BLE001
                traceback.print_exc()
                failures.append({"arch": arch, "shape": sname,
                                 "mesh": dict(mesh.shape), "error": str(e)})
            flush()

    if args.out:
        print(f"wrote {args.out}")
    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    for f in failures:
        print(f"  FAIL {f['arch']} x {f['shape']} x {f['mesh']}: "
              f"{f['error'][:200]}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
