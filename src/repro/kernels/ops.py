"""Dispatching wrappers: Pallas kernel on TPU, jnp reference elsewhere.

The framework's model code is pure JAX so the 512-device CPU dry-run can
compile it; these ops are the drop-in accelerated paths for real TPU runs
(``use_pallas=True``) and are validated against ref.py in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ina_matmul import ina_matmul
from repro.kernels.wkv6 import wkv6


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(x: jax.Array, w: jax.Array, *, use_pallas: bool | None = None,
           interpret: bool = False, plan=None, **blocks) -> jax.Array:
    """``plan`` (a :class:`repro.plan.ExecutionPlan`) supplies the pallas
    block sizes for this problem shape when it planned one; explicit
    ``blocks`` kwargs always win (the caller measured something)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        if plan is not None and not blocks:
            tiles = plan.tile_for(x.shape[0], x.shape[1], w.shape[1],
                                  str(x.dtype))
            if tiles is not None:
                blocks = dict(zip(("bm", "bn", "bk"), tiles))
        return ina_matmul(x, w, interpret=interpret or not _on_tpu(), **blocks)
    return ref.matmul_ref(x, w)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, use_pallas: bool | None = None,
              interpret: bool = False, **blocks) -> jax.Array:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return flash_attention(q, k, v, causal=causal,
                               interpret=interpret or not _on_tpu(), **blocks)
    return ref.attention_ref(q, k, v, causal=causal)


def wkv(r, k, v, logw, u, *, use_pallas: bool | None = None,
        interpret: bool = False, **kw) -> jax.Array:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return wkv6(r, k, v, logw, u, interpret=interpret or not _on_tpu(),
                    **kw)
    return ref.wkv6_ref(r, k, v, logw, u)
