"""Pure-jnp oracles for every kernel + the eject/inject matmul baseline."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)
                   ).astype(x.dtype)


def matmul_eject_inject(x: jax.Array, w: jax.Array, bk: int = 512,
                        ) -> jax.Array:
    """The paper's Fig. 4(a) baseline at chip level: each K-block partial product
    is materialized (ejected to HBM) and re-read to accumulate.  Numerically
    identical to the INA kernel; its cost model moves (K/bk) x M x N x 4 B of
    extra HBM traffic — the contrast measured in benchmarks/bench_kernels.py.
    """
    m, k = x.shape
    bk = min(bk, k)
    nk = k // bk
    partials = jnp.stack([
        jnp.dot(x[:, i * bk:(i + 1) * bk].astype(jnp.float32),
                w[i * bk:(i + 1) * bk].astype(jnp.float32))
        for i in range(nk)])
    if k % bk:
        partials = jnp.concatenate(
            [partials, jnp.dot(x[:, nk * bk:].astype(jnp.float32),
                               w[nk * bk:].astype(jnp.float32))[None]])
    # optimization barrier = the HBM round-trip (prevents re-fusion)
    partials = jax.lax.optimization_barrier(partials)
    return partials.sum(0).astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q/k/v: [BH, S, D]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def wkv6_ref(r, k, v, logw, u):
    """Step-by-step WKV6 recurrence (the ground-truth semantics).

    r/k/v/logw: [BH, S, hd]; u: [BH, hd].
    """
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(state, xs):
        rt, kt, vt, wt = xs                      # [BH, hd]
        kv = kt[:, :, None] * vt[:, None, :]     # [BH, hd, hd]
        y = jnp.einsum("bc,bcd->bd", rt, state) \
            + jnp.einsum("bc,bc,bc,bd->bd", rt, uf, kt, vt)
        state = state * wt[:, :, None] + kv
        return state, y

    bh, s, hd = r.shape
    state0 = jnp.zeros((bh, hd, hd), jnp.float32)
    xs = (rf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1),
          wf.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(r.dtype)
