"""Blocked (flash) causal attention kernel — the prefill hot-spot.

Online-softmax over KV blocks with m/l/acc scratch resident in VMEM; the
[Sq, Sk] score matrix never exists.  Grid: (batch*heads, q-blocks, kv-blocks)
with the kv dim sequential ("arbitrary") so scratch carries across kv steps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nkv: int, bq: int, bkv: int, scale: float, causal: bool):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # skip fully-masked blocks (block-sparsity of the causal mask)
        run = (kb * bkv) <= (qb * bq + bq - 1)

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0]                                   # [bq, d]
        k = k_ref[0]                                   # [bkv, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = kb * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == nkv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bkv", "causal", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    bq: int = 512, bkv: int = 512, causal: bool = True,
                    interpret: bool = False) -> jax.Array:
    """q/k/v: [BH, S, D] (batch*heads flattened, KV already GQA-expanded)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq, bkv = min(bq, sq), min(bkv, sk)
    assert sq % bq == 0 and sk % bkv == 0
    nkv = sk // bkv
    scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_kernel, nkv=nkv, bq=bq, bkv=bkv, scale=scale,
                          causal=causal),
        grid=(bh, sq // bq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
