"""INA matmul kernel: K-blocked matmul with a VMEM-resident accumulator.

The chip-level analogue of the paper's In-Network Accumulation (DESIGN.md
S2.2): when the contraction dim is blocked (the PE's "weights split across
multiple memory-limited units"), partial sums either
  (a) bounce through HBM per K-block — eject/inject (kernels/ref.py), or
  (b) stay resident in VMEM across the K grid and only the finished tile is
      written — in-network accumulation (this kernel).
The MXU sees hardware-aligned (multiples of 128) tiles via BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ina_matmul(x: jax.Array, w: jax.Array, *, bm: int = 256, bn: int = 256,
               bk: int = 512, interpret: bool = False) -> jax.Array:
    """[M, K] @ [K, N] with in-VMEM psum accumulation over K blocks.

    The static defaults suit MXU-aligned shapes; planned per-shape blocks
    (an :class:`repro.plan.ExecutionPlan`'s ``tile_for``) arrive as
    ``bm``/``bn``/``bk`` via :func:`repro.kernels.ops.matmul`.  Blocks
    must divide the problem dims exactly (the plan's chooser guarantees
    this; hand-picked blocks are asserted below).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"dims {(m, n, k)} not divisible by blocks {(bm, bn, bk)}"
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
