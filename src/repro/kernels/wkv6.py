"""RWKV6 WKV kernel: chunked data-dependent-decay recurrence.

Grid: (batch*heads, seq-chunks); the chunk dim is sequential so the [hd, hd]
(k x v) state lives in VMEM scratch across chunks — the recurrent state never
leaves the chip, the in-network-accumulation idea applied to a recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *,
            chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # [C, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = w_ref[0].astype(jnp.float32)       # [C, hd] (negative)
    u = u_ref[0].astype(jnp.float32)          # [1, hd]

    cum = jnp.cumsum(logw, axis=0)            # [C, hd]
    cum_prev = cum - logw
    re = r * jnp.exp(cum_prev)
    # Factorized intra-chunk decay: exact while the per-chunk cumulative
    # decay stays <= 80 nats (clamp keeps saturated-decay regimes finite;
    # use a smaller chunk for exactness there).
    kf = k * jnp.exp(-jnp.maximum(cum, -80.0))
    scores = jax.lax.dot_general(re, kf, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    tpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(tpos > spos, scores, 0.0)      # strictly causal
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)  # u-bonus (s == t)

    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag * v
    y = y + jax.lax.dot_general(re, state_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)

    tail = jnp.exp(cum[-1:] - cum)            # [C, hd]
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1])[:, None] + \
        jax.lax.dot_general((k * tail), v, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, *, chunk: int = 128,
         interpret: bool = False) -> jax.Array:
    """r/k/v/logw: [BH, S, hd]; u: [BH, hd].  Returns [BH, S, hd]."""
    bh, s, hd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    u3 = u[:, None, :]                        # [BH, 1, hd]

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(bh, s // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), r.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, logw, u3)
