"""train_step / serve_step builders with full sharding plumbing.

``build_train_step``: loss -> grads -> AdamW, params+optimizer FSDP/TP
sharded, batch sharded over (pod, data).  ``build_serve_step``: one-token
decode against a sharded KV cache.  Both return (jitted_fn, shardings) so
the dry-run can ``.lower().compile()`` them with ShapeDtypeStructs only.

Every builder accepts ``plan`` (a :class:`repro.plan.ExecutionPlan`): it is
attached to the ``ParallelCtx`` the step traces under, so ``mode="auto"``
psum sites read their precomputed strategy instead of re-consulting the NoC
cost model per call site (DESIGN.md S11).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.api import Model, cache_specs, param_specs
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, cosine_schedule
from repro.parallel.sharding import fit_specs, shardings_for
from repro.parallel.tp import ParallelCtx


def _data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _with_plan(pctx: Optional[ParallelCtx], mesh: Mesh,
               plan) -> ParallelCtx:
    """The step's ParallelCtx, carrying ``plan`` when one was supplied.

    An explicit ``pctx.plan`` wins (the caller already decided); otherwise
    the plan handle is attached so auto psum sites resolve through it.
    """
    pctx = pctx if pctx is not None else ParallelCtx(mesh=mesh)
    if plan is not None and pctx.plan is None:
        pctx = dataclasses.replace(pctx, plan=plan)
    return pctx


@dataclasses.dataclass
class TrainStep:
    fn: object                    # jitted (params, opt, batch) -> ...
    param_sharding: dict
    opt_sharding: object
    batch_sharding: dict
    param_shapes: dict


def build_train_step(model: Model, mesh: Mesh, shape: ShapeConfig,
                     pctx: Optional[ParallelCtx] = None,
                     base_lr: float = 3e-4, warmup: int = 200,
                     total_steps: int = 10_000,
                     donate: bool = True, plan=None) -> TrainStep:
    cfg = model.cfg
    pctx = _with_plan(pctx, mesh, plan)
    lr = cosine_schedule(base_lr, warmup, total_steps)

    # Shapes without allocation; sharding intents fitted to real dims.
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = fit_specs(param_specs(pshapes, mesh), pshapes, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    oshapes = jax.eval_shape(adamw_init, pshapes)
    osh = AdamWState(
        step=NamedSharding(mesh, P()),
        m=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P)),
        v=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P)))

    baxes = _data_axes(mesh)
    bspecs = model.batch_specs(shape, data_axes=baxes)
    _ishapes = model.input_specs(shape)
    bspecs = {k: fit_specs(v, _ishapes[k], mesh) for k, v in bspecs.items()}
    bsh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}

    def step(params, opt: AdamWState, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, pctx))(params)
        new_params, new_opt, stats = adamw_update(params, grads, opt, lr)
        stats["loss"] = loss
        return new_params, new_opt, stats

    jitted = jax.jit(
        step,
        in_shardings=(psh, osh, bsh),
        out_shardings=(psh, osh, None),
        donate_argnums=(0, 1) if donate else ())
    return TrainStep(fn=jitted, param_sharding=psh, opt_sharding=osh,
                     batch_sharding=bsh, param_shapes=pshapes)


@dataclasses.dataclass
class ServeStep:
    fn: object                    # jitted (params, batch, cache) -> ...
    param_sharding: dict
    cache_sharding: dict
    batch_sharding: dict
    param_shapes: dict
    cache_shapes: dict


def build_serve_step(model: Model, mesh: Mesh, shape: ShapeConfig,
                     pctx: Optional[ParallelCtx] = None,
                     donate_cache: bool = True, plan=None) -> ServeStep:
    cfg = model.cfg
    pctx = _with_plan(pctx, mesh, plan)
    baxes = _data_axes(mesh)

    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = fit_specs(param_specs(pshapes, mesh), pshapes, mesh)
    if pctx.serve_replicated_params:
        # Serving layout: drop FSDP axes so decode never gathers params
        # (params replicated over data/pod, sharded over model only).
        def _strip(spec):
            return P(*[tuple(a for a in (e if isinstance(e, tuple) else (e,))
                             if a == "model") or None
                       if e is not None else None for e in spec])
        pspecs = jax.tree.map(_strip, pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))

    cshapes = jax.eval_shape(
        partial(model.init_cache, shape.global_batch, shape.seq_len))
    cspecs = fit_specs(cache_specs(cfg, baxes), cshapes, mesh)
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                       is_leaf=lambda x: isinstance(x, P))

    bspecs = model.batch_specs(shape, data_axes=baxes)
    _ishapes = model.input_specs(shape)
    bspecs = {k: fit_specs(v, _ishapes[k], mesh) for k, v in bspecs.items()}
    bsh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}

    def step(params, batch, cache):
        logits, new_cache = model.decode_step(params, batch, cache, pctx)
        # greedy next-token (serving semantics)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, new_cache

    from repro.parallel.sharding import fit_spec
    tok_spec = fit_spec(P(baxes), (shape.global_batch,), mesh)
    jitted = jax.jit(
        step,
        in_shardings=(psh, bsh, csh),
        out_shardings=(NamedSharding(mesh, tok_spec), csh),
        donate_argnums=(2,) if donate_cache else ())
    return ServeStep(fn=jitted, param_sharding=psh, cache_sharding=csh,
                     batch_sharding=bsh, param_shapes=pshapes,
                     cache_shapes=cshapes)


@dataclasses.dataclass
class PagedServeStep:
    """Continuous-batching decode artifact (repro.serve engine).

    ``fn(params, batch, cache) -> (next_tok [B], cache)`` where
    ``batch = {"tokens": [B, 1], "pos": [B]}`` carries a **per-slot**
    position — each cache slot advances independently, which is what lets
    requests join/leave the batch at token boundaries.  Semantically slot
    ``i`` computes exactly what a ``B=1`` ``decode_step`` at ``pos[i]``
    would (the step is a vmap of the per-request decode), so continuous
    batching cannot change any request's output.
    """

    fn: object
    param_sharding: dict
    cache_sharding: dict
    param_shapes: dict
    cache_shapes: dict
    cache_batch_axes: dict        # per-leaf batch-axis index (slot plumbing)


def build_paged_serve_step(model: Model, mesh: Mesh, shape: ShapeConfig,
                           pctx: Optional[ParallelCtx] = None,
                           donate_cache: bool = True,
                           plan=None) -> PagedServeStep:
    """Per-slot-position decode step for the paged-KV serving engine.

    ``shape.global_batch`` is the slot count (the continuous-batching
    capacity), ``shape.seq_len`` the per-slot cache length.  Inactive slots
    simply decode garbage that the engine ignores; their cache writes land
    at positions the scheduler will overwrite before they become visible
    (decode attention is masked to ``<= pos``).
    """
    from repro.models.api import cache_batch_axes

    cfg = model.cfg
    pctx = _with_plan(pctx, mesh, plan)
    baxes = _data_axes(mesh)
    baxis = cache_batch_axes(cfg)

    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = fit_specs(param_specs(pshapes, mesh), pshapes, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    cshapes = jax.eval_shape(
        partial(model.init_cache, shape.global_batch, shape.seq_len))
    cspecs = fit_specs(cache_specs(cfg, baxes), cshapes, mesh)
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                       is_leaf=lambda x: isinstance(x, P))

    has_media = cfg.family in ("encdec", "vlm") and cfg.num_media_tokens

    def _row(params, tok, pos, cache_row, media_row=None):
        # One request's decode: vmap stripped the batch axis from every
        # cache leaf; reinsert a singleton so the family decode_step sees
        # its usual batched layout.
        cache_b = jax.tree.map(lambda l, a: jnp.expand_dims(l, a),
                               cache_row, baxis)
        batch = {"tokens": tok[None, :], "pos": pos}
        if media_row is not None:
            batch["media"] = media_row[None]
        logits, new_cache = model.decode_step(params, batch, cache_b, pctx)
        nxt = jnp.argmax(logits[0, -1, :], axis=-1)
        return nxt, jax.tree.map(lambda l, a: jnp.squeeze(l, axis=a),
                                 new_cache, baxis)

    if has_media:
        vmapped = jax.vmap(_row, in_axes=(None, 0, 0, baxis, 0),
                           out_axes=(0, baxis))

        def step(params, batch, cache):
            return vmapped(params, batch["tokens"], batch["pos"], cache,
                           batch["media"])
    else:
        vmapped = jax.vmap(_row, in_axes=(None, 0, 0, baxis),
                           out_axes=(0, baxis))

        def step(params, batch, cache):
            return vmapped(params, batch["tokens"], batch["pos"], cache)

    jitted = jax.jit(step, donate_argnums=(2,) if donate_cache else ())
    return PagedServeStep(fn=jitted, param_sharding=psh, cache_sharding=csh,
                          param_shapes=pshapes, cache_shapes=cshapes,
                          cache_batch_axes=baxis)


@dataclasses.dataclass
class PrefillStep:
    """Chunked cache-populating prefill artifact (repro.serve engine).

    ``fn(params, batch, cache) -> (logits [B, C, V], cache)`` with
    ``batch = {"tokens": [B, C], "pos0": scalar}``: one compile serves
    every chunk of a chunked prefill (``pos0`` is traced).
    """

    fn: object
    chunk: int
    param_sharding: dict
    cache_sharding: dict


def build_prefill_step(model: Model, mesh: Mesh, shape: ShapeConfig,
                       chunk: int, pctx: Optional[ParallelCtx] = None,
                       donate_cache: bool = True, plan=None) -> PrefillStep:
    """Batched prefill into a KV cache, ``chunk`` tokens per call.

    Requires the family to implement ``prefill`` (``model.has_prefill``);
    the serving engine falls back to a decode-step loop otherwise.
    ``shape`` fixes the cache geometry (slots x max_seq) like
    :func:`build_paged_serve_step`.
    """
    if not model.has_prefill:
        raise NotImplementedError(
            f"family {model.cfg.family!r} has no batched prefill")
    cfg = model.cfg
    pctx = _with_plan(pctx, mesh, plan)
    baxes = _data_axes(mesh)

    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = fit_specs(param_specs(pshapes, mesh), pshapes, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    cshapes = jax.eval_shape(
        partial(model.init_cache, shape.global_batch, shape.seq_len))
    cspecs = fit_specs(cache_specs(cfg, baxes), cshapes, mesh)
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                       is_leaf=lambda x: isinstance(x, P))

    def step(params, batch, cache):
        return model.prefill(params, {"tokens": batch["tokens"]}, cache,
                             pctx, pos_offset=batch["pos0"])

    jitted = jax.jit(step, donate_argnums=(2,) if donate_cache else ())
    return PrefillStep(fn=jitted, chunk=chunk, param_sharding=psh,
                       cache_sharding=csh)


def build_prefill(model: Model, mesh: Mesh, shape: ShapeConfig,
                  pctx: Optional[ParallelCtx] = None, plan=None):
    """Forward-only full-sequence pass (the prefill_32k cells)."""
    pctx = _with_plan(pctx, mesh, plan)
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = fit_specs(param_specs(pshapes, mesh), pshapes, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    baxes = _data_axes(mesh)
    bspecs = model.batch_specs(shape, data_axes=baxes)
    _ishapes = model.input_specs(shape)
    bspecs = {k: fit_specs(v, _ishapes[k], mesh) for k, v in bspecs.items()}
    bsh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}

    def fwd(params, batch):
        return model.forward(params, batch, pctx)

    jitted = jax.jit(fwd, in_shardings=(psh, bsh))
    return jitted, psh, bsh, pshapes
