"""Tensor parallelism with the paper's INA toggle.

Column-parallel projections shard the *output* feature dim over the ``model``
axis and need no communication.  Row-parallel projections shard the
*contraction* dim — each device produces a full-shape **partial sum**, the
exact WS-dataflow situation of the paper (weights split across PEs), and the
accumulation strategy is selectable:

  * ``mode="ina"``        — XLA psum (lowers to in-network reduce on the ICI
                            ring; the INA fast path)
  * ``mode="ina_ring"``   — explicit chunked ring with in-flight accumulation
                            (the paper's algorithm, visible in HLO)
  * ``mode="eject_inject"`` — full-tensor relay ring with endpoint adds
                            (the paper's Fig. 4(a) baseline)
  * ``mode="auto"``       — resolved per call site: from the attached
                            ``plan`` (a repro.plan.ExecutionPlan, decided
                            once per (config, mesh, phase, dtype) and
                            persisted) when one is carried, else at trace
                            time by the NoC collective cost model (simulated
                            mesh latency of each strategy for this tensor
                            size / axis span; repro.core.noc.collective.cost)
  * ``mode="xla_spmd"``   — no shard_map at all: plain einsum, GSPMD chooses

The shard_map regions are *partial*: only the ``model`` axis is manual; the
``data``/``pod`` axes stay auto (GSPMD handles batch/FSDP sharding through
the region transparently).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.core.collectives import psum_with_mode


@dataclass(frozen=True)
class ParallelCtx:
    """How model-axis parallelism is executed inside the forward pass."""
    mesh: Optional[Mesh] = None
    psum_mode: str = "xla_spmd"   # xla_spmd | ina | ina_ring | eject_inject
                                  # | auto (NoC-simulated cost picks per site)
    axis: str = "model"
    plan: Optional[object] = None  # repro.plan.ExecutionPlan: precomputed
                                  # per-site strategies consulted by
                                  # mode="auto" (None -> trace-time fallback)
    seq_shard: bool = True        # Megatron-style sequence-sharded activations
    rs_seq: bool = False          # row-parallel psum -> reduce-scatter(seq):
                                  # the INA output stays scattered (SP fusion)
    sp_entry: bool = False        # rs_seq via explicit bf16 ppermute ring
    serve_replicated_params: bool = False   # serving layout: params TP-only
                                  # (no FSDP) — kills per-token param gathers

    @property
    def manual(self) -> bool:
        return self.mesh is not None and self.psum_mode != "xla_spmd" \
            and self.axis in self.mesh.axis_names and \
            self.mesh.shape[self.axis] > 1


def col_linear(x: jax.Array, w: jax.Array, pctx: Optional[ParallelCtx] = None,
               b: Optional[jax.Array] = None) -> jax.Array:
    """Column-parallel matmul: w sharded on its last dim; no communication."""
    out = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        out = out + b.astype(x.dtype)
    return out


def row_linear(x: jax.Array, w: jax.Array, pctx: Optional[ParallelCtx] = None,
               b: Optional[jax.Array] = None) -> jax.Array:
    """Row-parallel matmul + psum: the paper's INA site.

    ``x``: [..., F] activations sharded on F over the model axis;
    ``w``: [F, D] sharded on F.  Every device computes a partial [..., D]
    and partials are accumulated per ``pctx.psum_mode``.
    """
    if pctx is None or not pctx.manual:
        out = jnp.einsum("...f,fd->...d", x, w.astype(x.dtype))
    else:
        nd = x.ndim
        xs = P(*([None] * (nd - 1)), pctx.axis)
        ws = P(pctx.axis, None)
        span = pctx.mesh.shape[pctx.axis]
        rs_seq = (pctx.rs_seq and nd == 3 and x.shape[1] % span == 0
                  and x.shape[1] >= span)
        if rs_seq:
            # In-network accumulation straight into the sequence-parallel
            # layout: each hop accumulates and keeps only its seq shard —
            # half the wire bytes of RS+AG and no re-gather before the
            # residual add (the carry is seq-sharded anyway).
            os_ = P(None, pctx.axis, None)

            def local(xl, wl):
                partial = jnp.einsum("...f,fd->...d", xl,
                                     wl.astype(xl.dtype))
                if pctx.sp_entry:
                    # bf16-safe in-flight ring (ppermute-based; avoids the
                    # f32-wire CPU workaround of psum_scatter)
                    from repro.core.collectives import ring_reduce_scatter_ina
                    return ring_reduce_scatter_ina(partial, pctx.axis,
                                                   scatter_axis=1)
                from repro.core.collectives import reduce_scatter_with_mode
                return reduce_scatter_with_mode(partial, pctx.axis,
                                                pctx.psum_mode,
                                                scatter_axis=1,
                                                plan=pctx.plan)
        else:
            os_ = P(*([None] * nd))

            def local(xl, wl):
                partial = jnp.einsum("...f,fd->...d", xl,
                                     wl.astype(xl.dtype))
                return psum_with_mode(partial, pctx.axis, pctx.psum_mode,
                                      scatter_axis=partial.ndim - 1,
                                      plan=pctx.plan)

        out = shard_map(local, mesh=pctx.mesh, in_specs=(xs, ws),
                        out_specs=os_, axis_names={pctx.axis},
                        check_vma=False)(x, w)
    if b is not None:
        out = out + b.astype(x.dtype)
    return out


def combine_experts(combine: jax.Array, expert_out: jax.Array,
                    pctx: Optional[ParallelCtx] = None) -> jax.Array:
    """Combine expert-parallel outputs: the MoE INA site.

    ``combine``: [B, S, E, C] combine weights; ``expert_out``: [E, C, D]
    per-expert outputs, both sharded on the expert dim E over the model axis
    (EP).  The contraction over E produces per-device partial sums that are
    accumulated per ``pctx.psum_mode`` — the same WS psum situation as
    row-parallel linears, with experts in place of weight slices.
    """
    if pctx is None or not pctx.manual:
        return jnp.einsum("bsec,ecd->bsd", combine,
                          expert_out.astype(combine.dtype))

    def local(cl, el):
        partial = jnp.einsum("bsec,ecd->bsd", cl, el.astype(cl.dtype))
        return psum_with_mode(partial, pctx.axis, pctx.psum_mode,
                              scatter_axis=partial.ndim - 1,
                              plan=pctx.plan)

    return shard_map(
        local, mesh=pctx.mesh,
        in_specs=(P(None, None, pctx.axis, None), P(pctx.axis, None, None)),
        out_specs=P(None, None, None), axis_names={pctx.axis},
        check_vma=False)(combine, expert_out)


def constrain_acts(x: jax.Array, pctx: Optional[ParallelCtx],
                   seq_dim: int = 1) -> jax.Array:
    """Sequence-parallel activation constraint between layers.

    Shards [B, S, D] activations: batch over (pod, data), sequence over the
    model axis (Megatron SP) — this bounds the per-device residual-carry
    memory of the layer scan.  No-op when the dims do not divide (decode
    S=1) or there is no mesh.
    """
    if pctx is None or pctx.mesh is None:
        return x
    from jax.sharding import NamedSharding
    mesh = pctx.mesh
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspan = 1
    for a in baxes:
        bspan *= mesh.shape[a]
    spec = [None] * x.ndim
    if baxes and x.shape[0] % bspan == 0 and x.shape[0] >= bspan:
        spec[0] = baxes if len(baxes) > 1 else baxes[0]
    mspan = mesh.shape.get(pctx.axis, 1)
    if pctx.seq_shard and mspan > 1 and x.ndim > seq_dim and             x.shape[seq_dim] % mspan == 0 and x.shape[seq_dim] >= mspan:
        spec[seq_dim] = pctx.axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
