from .tp import ParallelCtx, col_linear, combine_experts, row_linear

__all__ = ["ParallelCtx", "col_linear", "combine_experts", "row_linear"]
