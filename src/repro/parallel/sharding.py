"""Sharding utilities: fit PartitionSpecs to actual shapes and meshes.

Name-rule specs (models/api.py) are *intents*; real shapes sometimes cannot
honor them (GQA KV heads narrower than the TP span, batch=1 long-context
decode, odd vocab sizes).  ``fit_specs`` repairs a spec pytree against the
shape pytree: axes that do not divide their dim are moved to the largest
free dim they do divide (e.g. batch=1 decode -> sequence/context sharding),
or dropped.
"""
from __future__ import annotations

from typing import Iterable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axes_of(entry) -> tuple:
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        return tuple(entry)
    return (entry,)


def fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Repair one PartitionSpec against a concrete shape."""
    ndim = len(shape)
    entries = list(spec) + [None] * (ndim - len(spec))
    entries = entries[:ndim]
    sizes = dict(mesh.shape)

    placed: list[list] = [[] for _ in range(ndim)]
    used: set = set()
    homeless: list[str] = []
    for d, entry in enumerate(entries):
        for ax in _axes_of(entry):
            if ax not in sizes or ax in used:
                continue                       # absent from mesh / duplicate
            span = int(np.prod([sizes[a] for a in placed[d]] + [sizes[ax]]))
            if shape[d] % span == 0 and shape[d] >= span:
                placed[d].append(ax)
                used.add(ax)
            else:
                homeless.append(ax)

    # Try to relocate homeless axes to the largest free divisible dim.
    for ax in homeless:
        if ax in used:
            continue
        cands = sorted(range(ndim), key=lambda d: -shape[d])
        for d in cands:
            span = int(np.prod([sizes[a] for a in placed[d]] + [sizes[ax]]))
            if shape[d] % span == 0 and shape[d] >= span and shape[d] > 1:
                placed[d].append(ax)
                used.add(ax)
                break

    out = []
    for d in range(ndim):
        if not placed[d]:
            out.append(None)
        elif len(placed[d]) == 1:
            out.append(placed[d][0])
        else:
            out.append(tuple(placed[d]))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def fit_specs(specs, shapes, mesh: Mesh):
    """Tree-version: ``shapes`` is a pytree of ShapeDtypeStruct/arrays."""
    return jax.tree.map(
        lambda sp, sh: fit_spec(sp, sh.shape, mesh), specs, shapes,
        is_leaf=lambda x: isinstance(x, P))


def shardings_for(specs, shapes, mesh: Mesh):
    fitted = fit_specs(specs, shapes, mesh)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), fitted,
                        is_leaf=lambda x: isinstance(x, P))
