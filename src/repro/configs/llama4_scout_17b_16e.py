"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E].  Early-fusion frontend out of scope
(text backbone per the assignment)."""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192, num_shared=1),
)
