"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block every 6
layers (32 heads over 2*d_model concat input) [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, tie_embeddings=True,
    ssm=SSMConfig(kind="mamba2", d_state=64, expand=2, head_dim=64,
                  conv_kernel=4),
    shared_attn_every=6, shared_attn_heads=32, shared_attn_d_ff=10240,
    sub_quadratic=True,
)
