"""llama-3.2-vision-11b [vlm] — dense decoder + gated cross-attn image layers
every 5th layer; ViT frontend is a STUB (precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, rope_theta=500_000.0,
    cross_attn_every=5, num_media_tokens=1601,
)
