"""whisper-medium [audio] — enc-dec backbone; conv frontend is a STUB
(input_specs supplies 1500 precomputed frame embeddings) [arXiv:2212.04356].
24 encoder + 24 decoder layers."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    encoder_layers=24, num_media_tokens=1500,
    max_seq=524_288,     # positional table sized for the assigned shapes
)
