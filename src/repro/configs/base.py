"""Model / run configuration dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # shared (always-on) experts
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2
    first_dense_layers: int = 0  # leading dense layers before MoE stack


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0         # 0 = no query compression (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"         # "mamba2" | "rwkv6"
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    chunk: int = 256             # SSD/WKV sequence-chunk length
    scores_dtype: str = "float32"   # intra-chunk decay-matrix dtype


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq: int = 131_072

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # Zamba2: one weight-shared attention+MLP block invoked every k layers.
    shared_attn_every: int = 0
    shared_attn_heads: int = 0
    shared_attn_d_ff: int = 0

    # Llama-3.2-Vision: cross-attention layers every k layers.
    cross_attn_every: int = 0
    num_media_tokens: int = 0    # stub frontend: precomputed patch/frame embeds

    # Whisper: encoder-decoder; n_layers is the decoder depth.
    encoder_layers: int = 0

    # numerics
    dtype: str = "bfloat16"      # activation/compute dtype
    param_dtype: str = "float32"

    # attention memory policy: chunked (online-softmax) KV blocking above this
    attn_chunk: int = 1024
    # fully unroll layer/sequence scans (roofline costing only)
    scan_unroll: bool = False
    # activation remat policy: nothing | dots | dots_nb
    remat_policy: str = "nothing"

    sub_quadratic: bool = False  # True for ssm/hybrid: may run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        changes: dict = dict(
            n_layers=min(self.n_layers, 2 if not self.shared_attn_every else 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            vocab=256,
            max_seq=128,
            num_media_tokens=min(self.num_media_tokens, 16) if self.num_media_tokens else 0,
            attn_chunk=32,
            dtype="float32",
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                num_shared=min(self.moe.num_shared, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1))
        if self.mla:
            changes["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                       qk_nope_head_dim=16, qk_rope_head_dim=8,
                                       v_head_dim=16)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.shared_attn_every:
            changes["shared_attn_every"] = 2
            changes["shared_attn_heads"] = 4
            changes["shared_attn_d_ff"] = 128
        if self.cross_attn_every:
            changes["cross_attn_every"] = 2
        if self.encoder_layers:
            changes["encoder_layers"] = 2
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (DESIGN.md S4)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


import dataclasses as _dc


def depth_scaled(cfg: ModelConfig, units: int) -> ModelConfig:
    """A structurally-identical config with ``units`` repeating units
    (layers or groups) and fully-unrolled scans — used by the roofline
    analysis to measure exact per-unit HLO cost marginals (XLA's
    cost_analysis counts while-loop bodies once, so full-depth scanned
    programs cannot be costed directly)."""
    ch: dict = {"scan_unroll": True}
    if cfg.family == "hybrid":
        ch["n_layers"] = cfg.shared_attn_every * units
    elif cfg.family == "vlm":
        ch["n_layers"] = cfg.cross_attn_every * units
    elif cfg.family == "encdec":
        ch["n_layers"] = units
        ch["encoder_layers"] = units
    elif cfg.moe is not None and cfg.moe.first_dense_layers:
        ch["n_layers"] = cfg.moe.first_dense_layers + units
    else:
        ch["n_layers"] = units
    return _dc.replace(cfg, **ch)


def depth_units(cfg: ModelConfig) -> int:
    """Number of repeating units at full depth (for extrapolation)."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.shared_attn_every
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_attn_every
    if cfg.family == "encdec":
        return cfg.n_layers
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        return cfg.n_layers - cfg.moe.first_dense_layers
    return cfg.n_layers
