"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 64 routed top-6 + 2 shared
experts, expert d_ff=1408 [arXiv:2405.04434; hf].

Assignment-line note (DESIGN.md S4): the line lists both "MoE 64e top-6" and
"160 routed"; 64 routed matches the HF V2-Lite checkpoint (160 is full V2).
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="mla_moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944,                       # dense first layer (HF config)
    vocab=102400, rope_theta=10_000.0,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
                  first_dense_layers=1),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
)
