"""Architecture registry: ``--arch <id>`` resolves through ARCHS."""
from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs.phi3_mini_3_8b import CONFIG as phi3_mini_3_8b
from repro.configs.llama3_8b import CONFIG as llama3_8b
from repro.configs.qwen3_14b import CONFIG as qwen3_14b
from repro.configs.qwen2_1_5b import CONFIG as qwen2_1_5b
from repro.configs.deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from repro.configs.llama4_scout_17b_16e import CONFIG as llama4_scout_17b_16e
from repro.configs.zamba2_2_7b import CONFIG as zamba2_2_7b
from repro.configs.rwkv6_7b import CONFIG as rwkv6_7b
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.llama_3_2_vision_11b import CONFIG as llama_3_2_vision_11b

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        phi3_mini_3_8b, llama3_8b, qwen3_14b, qwen2_1_5b,
        deepseek_v2_lite_16b, llama4_scout_17b_16e, zamba2_2_7b, rwkv6_7b,
        whisper_medium, llama_3_2_vision_11b,
    ]
}

__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "shape_applicable"]
