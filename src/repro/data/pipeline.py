"""Deterministic, shard-aware synthetic token pipeline.

Production-shaped: every batch is a pure function of (seed, step), so any
host can regenerate any step's data — restart after preemption replays
exactly, and elastic re-sharding (a different host count mid-run) yields the
same global batch.  Documents are sampled from a Zipf-ish unigram model with
document boundaries (BOS/EOS) so the loss curve is non-trivial.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bos: int = 1
    eos: int = 2
    mean_doc_len: int = 256


class TokenPipeline:
    """``batch(step)`` -> {tokens, labels} for the *global* batch (the caller
    device_puts with the step's NamedSharding; per-host slicing uses
    ``host_batch`` with the host's row range)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed Zipf unigram distribution over the vocab (host-side, cheap)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks
        probs[: 3] = probs.max() * 0.01      # special tokens are rare
        self._probs = jnp.asarray(probs / probs.sum(), jnp.float32)

    def _key(self, step: int) -> jax.Array:
        return jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step)

    def batch(self, step: int) -> dict:
        c = self.cfg
        key = self._key(step)
        k1, k2 = jax.random.split(key)
        toks = jax.random.choice(k1, c.vocab, (c.global_batch, c.seq_len + 1),
                                 p=self._probs)
        # document boundaries: geometric(1/mean_doc_len) resets to BOS
        resets = jax.random.bernoulli(k2, 1.0 / c.mean_doc_len,
                                      (c.global_batch, c.seq_len + 1))
        toks = jnp.where(resets, c.bos, toks).astype(jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch(self, step: int, host_id: int, num_hosts: int) -> dict:
        """The rows of the global batch owned by ``host_id`` (data loading is
        sharded by host; every host can also regenerate any other shard)."""
        full = self.batch(step)
        rows = self.cfg.global_batch // num_hosts
        sl = slice(host_id * rows, (host_id + 1) * rows)
        return {k: v[sl] for k, v in full.items()}
