"""Fault-tolerant training runtime: preemption-safe loop, retry, elastic
re-mesh, straggler policy.

On a real multi-host pod this wraps ``jax.distributed`` initialization; the
mechanisms themselves (checkpoint/restore cadence, signal handling, step
retry, elastic resharding) are host-count independent and exercised by the
CPU tests/examples.

Straggler mitigation (documented design, enforced where expressible here):
  * deterministic data sharding — any host can regenerate any shard, so a
    replacement host joins without data-state handoff (data/pipeline.py);
  * checkpoint cadence bounds lost work to ``every`` steps;
  * per-step walltime watchdog: a step exceeding ``timeout_factor`` x the
    trailing median is logged as a straggler event and (on TPU runtimes
    with a job controller) triggers slice replacement — here we surface the
    event via callback so the launcher can act.
"""
from __future__ import annotations

import signal
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.exec.timing import Stopwatch


@dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    max_step_retries: int = 2
    timeout_factor: float = 3.0


class PreemptionGuard:
    """SIGTERM/SIGINT -> finish the current step, checkpoint, exit cleanly.

    The first signal only sets ``requested`` (the loop drains the current
    step, then checkpoints).  It also restores the original handlers, so
    a *second* signal is not swallowed: SIGINT raises KeyboardInterrupt
    immediately (``run_training`` force-saves on that path) and SIGTERM
    gets its pre-guard disposition — an operator pressing Ctrl-C twice
    means *now*, not *after this step*.
    """

    def __init__(self):
        self.requested = False
        self._orig = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._orig[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True
        self._restore()

    def _restore(self):
        for sig, orig in self._orig.items():
            signal.signal(sig, orig)
        self._orig = {}

    def __exit__(self, *exc):
        self._restore()
        return False


@dataclass
class StragglerWatch:
    factor: float = 3.0
    history: list = field(default_factory=list)
    events: list = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step was a straggler."""
        is_straggler = False
        if len(self.history) >= 5:
            median = float(np.median(self.history[-20:]))
            if seconds > self.factor * median:
                self.events.append((step, seconds, median))
                is_straggler = True
        self.history.append(seconds)
        return is_straggler


def run_training(step_fn: Callable, state, batch_fn: Callable, *,
                 ft: FTConfig, num_steps: int,
                 state_shardings=None,
                 on_metrics: Optional[Callable] = None,
                 on_straggler: Optional[Callable] = None) -> tuple:
    """Preemption-safe training loop.

    ``step_fn(state, batch) -> (state, metrics)``; ``state`` is any pytree
    (params, opt, ...).  Resumes from the newest checkpoint if present.
    Returns (state, last_step, straggler_events).
    """
    mgr = CheckpointManager(ft.ckpt_dir, keep=ft.keep, every=ft.ckpt_every)
    start = 0
    restored = mgr.restore_or_none(state, shardings=state_shardings)
    if restored is not None:
        state, start = restored
        start += 1

    watch = StragglerWatch(factor=ft.timeout_factor)
    with PreemptionGuard() as guard:
        step = start
        try:
            while step < num_steps:
                batch = batch_fn(step)
                sw = Stopwatch()
                for attempt in range(ft.max_step_retries + 1):
                    try:
                        state, metrics = step_fn(state, batch)
                        break
                    except jax.errors.JaxRuntimeError:  # transient device err
                        if attempt == ft.max_step_retries:
                            mgr.maybe_save(state, step, force=True)
                            raise
                dt = sw.seconds
                if watch.observe(step, dt) and on_straggler:
                    on_straggler(step, dt)
                if on_metrics:
                    on_metrics(step, metrics, dt)
                mgr.maybe_save(state, step)
                if guard.requested:
                    mgr.maybe_save(state, step, force=True)
                    break
                step += 1
        except KeyboardInterrupt:
            # Second Ctrl-C (the guard restored the default handler):
            # checkpoint the last completed state and leave immediately.
            mgr.maybe_save(state, step, force=True)
            raise
    return state, step, watch.events


def elastic_restore(tree_like, ckpt_dir: str, mesh, spec_fn):
    """Restore a checkpoint onto a (possibly different) mesh.

    ``spec_fn(tree_like, mesh) -> PartitionSpec pytree``.  Because the
    checkpoint stores full logical arrays, a job restarted with a different
    device count reshards transparently — elastic scaling.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.ckpt import restore_pytree
    from repro.parallel.sharding import fit_specs

    specs = fit_specs(spec_fn(tree_like, mesh), tree_like, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return restore_pytree(tree_like, ckpt_dir, shardings=shardings)
