"""Gradient compression for the cross-pod DP all-reduce.

Two codecs, both with error feedback (the residual of one step is added
back into the next step's gradient, so compression error does not bias the
optimizer in expectation):

  * int8 per-tensor-block quantization (~4x over fp32 on the wire)
  * top-k magnitude sparsification (values + dense mask; k as a fraction)

``compressed_psum`` applies codec -> psum over the pod axis -> decode inside
a shard_map region, modeling the compressed wire format explicitly so the
dry-run HLO shows the reduced collective bytes.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.compat import axis_size

Codec = Literal["none", "int8", "topk"]


# --------------------------------------------------------------------------- #
# int8 error-feedback quantization
# --------------------------------------------------------------------------- #
def int8_encode(g: jax.Array, err: jax.Array):
    g32 = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    return q, scale.astype(jnp.float32), new_err


def int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# --------------------------------------------------------------------------- #
# top-k error-feedback sparsification
# --------------------------------------------------------------------------- #
def topk_encode(g: jax.Array, err: jax.Array, frac: float = 0.05):
    g32 = g.astype(jnp.float32) + err
    flat = g32.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(g32) >= thresh
    sparse = jnp.where(mask, g32, 0.0)
    return sparse, g32 - sparse


# --------------------------------------------------------------------------- #
# compressed cross-pod psum
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class CompressionState:
    """Error-feedback residuals, one per gradient leaf (same pytree)."""
    err: dict

    @staticmethod
    def init(grads) -> "CompressionState":
        return CompressionState(err=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def compressed_psum(grads, state: CompressionState, axis: str,
                    codec: Codec = "int8", topk_frac: float = 0.05):
    """psum ``grads`` over ``axis`` under the codec; must run inside
    shard_map with ``axis`` bound.  Returns (reduced_grads, new_state)."""
    n = axis_size(axis)

    def leaf(g, e):
        if codec == "none" or g.ndim == 0:
            return jax.lax.psum(g, axis) / n, jnp.zeros(g.shape, jnp.float32)
        if codec == "int8":
            q, scale, err = int8_encode(g, e)
            # wire format: int8 payload + fp32 scale (HLO shows 1/4 bytes)
            total = jax.lax.psum(q.astype(jnp.int32), axis)
            scale_sum = jax.lax.psum(scale, axis)
            return (total.astype(jnp.float32) * (scale_sum / n) / n
                    ).astype(g.dtype), err
        if codec == "topk":
            sparse, err = topk_encode(g, e, topk_frac)
            return (jax.lax.psum(sparse, axis) / n).astype(g.dtype), err
        raise ValueError(codec)

    out = jax.tree.map(leaf, grads, state.err)
    reduced = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return reduced, CompressionState(err=new_err)
