"""CNN workload definitions used by the paper: AlexNet, VGG-16, ResNet-50.

Layer shapes follow the papers the INA paper cites:
  * AlexNet ("one weird trick" single-tower variant, arXiv:1404.5997) — the
    R/C/F/O values match the INA paper's Table I exactly.
  * VGG-16 (ICLR'15) — matches Table II exactly.
  * ResNet-50 (CVPR'16) — the INA paper gives no table; we enumerate every
    CONV layer of the standard v1 bottleneck network.

Beyond the paper (the mapper's front-end, see DESIGN.md S9): the FC layers
the paper's tables omit (:data:`ALEXNET_FC` / :data:`VGG16_FC`, as
:class:`~repro.core.ops.GemmLayer` shapes) and transformer projection/MLP
GEMMs derived from the ``configs/`` model registry
(:func:`mapper_workloads`).  ``WORKLOADS`` itself stays CONV-only — the
fig7-12 pins depend on it.
"""
from __future__ import annotations

from .ina_model import ConvLayer
from .ops import GemmLayer, LayerShape, transformer_gemms

# --------------------------------------------------------------------------- #
# AlexNet (Table I)
# --------------------------------------------------------------------------- #
ALEXNET = [
    ConvLayer("CONV1", R=11, C=3,   F=64,  O=55, stride=4),
    ConvLayer("CONV2", R=5,  C=64,  F=192, O=27),
    ConvLayer("CONV3", R=3,  C=192, F=384, O=13),
    ConvLayer("CONV4", R=3,  C=384, F=256, O=13),
    ConvLayer("CONV5", R=3,  C=256, F=256, O=13),
]

# --------------------------------------------------------------------------- #
# VGG-16 (Table II)
# --------------------------------------------------------------------------- #
VGG16 = [
    ConvLayer("CONV1",  R=3, C=3,   F=64,  O=224),
    ConvLayer("CONV2",  R=3, C=64,  F=64,  O=224),
    ConvLayer("CONV3",  R=3, C=64,  F=128, O=112),
    ConvLayer("CONV4",  R=3, C=128, F=128, O=112),
    ConvLayer("CONV5",  R=3, C=128, F=256, O=56),
    ConvLayer("CONV6",  R=3, C=256, F=256, O=56),
    ConvLayer("CONV7",  R=3, C=256, F=256, O=56),
    ConvLayer("CONV8",  R=3, C=256, F=512, O=28),
    ConvLayer("CONV9",  R=3, C=512, F=512, O=28),
    ConvLayer("CONV10", R=3, C=512, F=512, O=28),
    ConvLayer("CONV11", R=3, C=512, F=512, O=14),
    ConvLayer("CONV12", R=3, C=512, F=512, O=14),
    ConvLayer("CONV13", R=3, C=512, F=512, O=14),
]


# --------------------------------------------------------------------------- #
# ResNet-50 v1 (bottleneck blocks)
# --------------------------------------------------------------------------- #
def _bottleneck(stage: str, idx: int, c_in: int, width: int, c_out: int,
                o: int, first_stride: int) -> list[ConvLayer]:
    """One bottleneck block: 1x1 reduce, 3x3, 1x1 expand (+ projection on idx 0)."""
    tag = f"{stage}_{idx}"
    layers = [
        ConvLayer(f"{tag}_1x1a", R=1, C=c_in,  F=width, O=o, stride=first_stride),
        ConvLayer(f"{tag}_3x3",  R=3, C=width, F=width, O=o),
        ConvLayer(f"{tag}_1x1b", R=1, C=width, F=c_out, O=o),
    ]
    if idx == 0:
        layers.append(ConvLayer(f"{tag}_proj", R=1, C=c_in, F=c_out, O=o,
                                stride=first_stride))
    return layers


def _resnet50() -> list[ConvLayer]:
    layers = [ConvLayer("CONV1", R=7, C=3, F=64, O=112, stride=2)]
    c_in = 64
    for stage, (blocks, width, c_out, o) in {
        "conv2": (3, 64, 256, 56),
        "conv3": (4, 128, 512, 28),
        "conv4": (6, 256, 1024, 14),
        "conv5": (3, 512, 2048, 7),
    }.items():
        for idx in range(blocks):
            stride = 2 if (idx == 0 and stage != "conv2") else 1
            layers.extend(_bottleneck(stage, idx, c_in, width, c_out, o, stride))
            c_in = c_out
    return layers


RESNET50 = _resnet50()

WORKLOADS: dict[str, list[ConvLayer]] = {
    "alexnet": ALEXNET,
    "vgg16": VGG16,
    "resnet50": RESNET50,
}


# --------------------------------------------------------------------------- #
# FC layers (single-image GEMMs the paper's tables leave out)
# --------------------------------------------------------------------------- #
ALEXNET_FC = [
    GemmLayer("FC6", M=1, K=256 * 6 * 6, N=4096),
    GemmLayer("FC7", M=1, K=4096, N=4096),
    GemmLayer("FC8", M=1, K=4096, N=1000),
]

VGG16_FC = [
    GemmLayer("FC14", M=1, K=512 * 7 * 7, N=4096),
    GemmLayer("FC15", M=1, K=4096, N=4096),
    GemmLayer("FC16", M=1, K=4096, N=1000),
]

FC_LAYERS: dict[str, list[GemmLayer]] = {
    "alexnet": ALEXNET_FC,
    "vgg16": VGG16_FC,
}


def full_workload(name: str) -> list[LayerShape]:
    """CONV stack plus the FC tail (where the network has one)."""
    return list(WORKLOADS[name]) + list(FC_LAYERS.get(name, []))


def mapper_workloads(conv: tuple[str, ...] = ("alexnet", "vgg16", "resnet50"),
                     transformers: tuple[str, ...] = ("llama3-8b",
                                                      "qwen2-1.5b"),
                     tokens: int = 256) -> dict[str, list[LayerShape]]:
    """The mapper's workload set: FC-complete CNNs + transformer GEMM blocks.

    ``transformers`` are ``configs/`` registry names; each contributes one
    decoder block's q/k/v/o + gate/up/down GEMMs under the key
    ``"<name>:gemm"`` (ratios are depth-invariant, see ``core.ops``).
    """
    out: dict[str, list[LayerShape]] = {n: full_workload(n) for n in conv}
    if transformers:
        from repro.configs import ARCHS
        for t in transformers:
            out[f"{t}:gemm"] = list(transformer_gemms(ARCHS[t], tokens))
    return out
