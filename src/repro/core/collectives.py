"""INA as pod-scale collectives: accumulate-while-routing vs eject/inject.

The paper's dichotomy (Fig. 4) maps exactly onto how a partial-sum
all-reduce can be scheduled on a TPU ICI ring (DESIGN.md S2.1):

* ``ring_psum_eject_inject``  — Fig. 4(a).  The *full* psum tensor is relayed
  around the ring; at every stop it is "ejected" into the endpoint (added to
  the local accumulator) and the received tensor is "re-injected" for the
  next hop.  P-1 steps, each moving ``|x|`` bytes per link: per-link traffic
  ``(P-1) * |x|``.

* ``ring_reduce_scatter_ina`` — Fig. 4(b).  The tensor is chunked 1/P; each
  hop *accumulates the local contribution into the moving chunk and forwards
  it* — the add happens "in the network" (inside the step, fused with the
  permute), never bouncing through an endpoint buffer.  P-1 steps, each
  moving ``|x|/P``: per-link traffic ``(P-1)/P * |x|`` — a ~P x reduction,
  the datacenter-scale version of the paper's result.

* ``psum_ina``                — reduce-scatter + all-gather when the full
  reduced tensor is needed (2(P-1)/P * |x| per link).

``*_xla`` variants use XLA's native collectives (``psum_scatter`` /
``psum``), which lower to the same in-network schedule but let the compiler
fuse/overlap; the explicit ring variants keep the paper's algorithm visible
in the HLO (collective-permute chains) for the roofline analysis.

All functions must be called inside ``shard_map`` with ``axis_name`` bound.
"""
from __future__ import annotations

import functools
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.compat import axis_size

PsumMode = Literal["ina", "ina_ring", "eject_inject", "xla", "auto"]

#: The ``--psum-mode`` choices every launch CLI offers (one source of
#: truth for train/serve/dryrun argparse).
CLI_PSUM_MODES = ("xla_spmd", "ina", "ina_ring", "eject_inject", "auto")


# --------------------------------------------------------------------------- #
# Fig. 4(a): eject -> local add -> inject, hop by hop (full tensor each hop).
# --------------------------------------------------------------------------- #
def ring_psum_eject_inject(x: jax.Array, axis_name: str) -> jax.Array:
    """Unchunked ring all-reduce: P-1 full-tensor hops with endpoint adds."""
    p = axis_size(axis_name)
    if p == 1:
        return x
    perm = [(i, (i + 1) % p) for i in range(p)]
    acc = x
    send = x
    for _ in range(p - 1):
        send = jax.lax.ppermute(send, axis_name, perm)   # inject -> next hop
        acc = acc + send                                 # eject -> local add
    return acc


# --------------------------------------------------------------------------- #
# Fig. 4(b): chunked ring reduce-scatter with in-flight accumulation.
# --------------------------------------------------------------------------- #
def ring_reduce_scatter_ina(x: jax.Array, axis_name: str,
                            scatter_axis: int = 0) -> jax.Array:
    """In-network accumulation: each hop adds its contribution to the moving
    1/P chunk and forwards it.  Device ``i`` returns fully-reduced chunk ``i``.
    """
    p = axis_size(axis_name)
    if p == 1:
        return x
    if x.shape[scatter_axis] % p != 0:
        raise ValueError(
            f"scatter axis {scatter_axis} ({x.shape[scatter_axis]}) "
            f"not divisible by axis size {p}")
    i = jax.lax.axis_index(axis_name)
    c = x.shape[scatter_axis] // p
    perm = [(j, (j + 1) % p) for j in range(p)]

    def chunk(k):
        k = jnp.mod(k, p)
        return jax.lax.dynamic_slice_in_dim(x, k * c, c, axis=scatter_axis)

    # Each step the moving chunk arrives from the ring predecessor, our local
    # contribution is added (the INA add), and it is forwarded.  Seeded with
    # chunk (i-1) so that after p-1 steps device i holds chunk i summed over
    # every device (the moving chunk index decreases by one per hop).
    carry = chunk(i - 1)
    for s in range(p - 1):
        carry = jax.lax.ppermute(carry, axis_name, perm)
        carry = carry + chunk(i - 2 - s)   # in-network accumulation
    return carry


def ring_all_gather(x: jax.Array, axis_name: str, gather_axis: int = 0,
                    ) -> jax.Array:
    """Ring all-gather (P-1 hops of |x| each); inverse of the scatter."""
    p = axis_size(axis_name)
    if p == 1:
        return x
    i = jax.lax.axis_index(axis_name)
    perm = [(j, (j + 1) % p) for j in range(p)]
    c = x.shape[gather_axis]
    out_shape = list(x.shape)
    out_shape[gather_axis] = c * p
    out = jnp.zeros(out_shape, x.dtype)

    send = x
    out = jax.lax.dynamic_update_slice_in_dim(
        out, send, jnp.mod(i, p) * c, axis=gather_axis)
    for s in range(p - 1):
        send = jax.lax.ppermute(send, axis_name, perm)
        # After s+1 forwards we are holding the chunk owned by (i - s - 1).
        out = jax.lax.dynamic_update_slice_in_dim(
            out, send, jnp.mod(i - s - 1, p) * c, axis=gather_axis)
    return out


def psum_ina(x: jax.Array, axis_name: str, scatter_axis: int = 0) -> jax.Array:
    """Full all-reduce via INA: reduce-scatter (in-flight adds) + all-gather."""
    rs = ring_reduce_scatter_ina(x, axis_name, scatter_axis)
    return ring_all_gather(rs, axis_name, scatter_axis)


# --------------------------------------------------------------------------- #
# XLA-native fast paths (same in-network schedule, compiler-optimized).
# --------------------------------------------------------------------------- #
def _needs_f32_workaround(x: jax.Array) -> bool:
    """XLA CPU's AllReducePromotion pass crashes on bf16 all-reduce/
    reduce-scatter inside manual shard_map regions (``Invalid binary
    instruction opcode copy``).  Upcast around the collective on CPU only;
    TPU keeps bf16 on the wire.  The dry-run's measured collective bytes for
    these sites are therefore f32 (2x the TPU bf16 bytes) — noted in
    EXPERIMENTS.md."""
    return x.dtype == jnp.bfloat16 and jax.default_backend() == "cpu"


def psum_scatter_xla(x: jax.Array, axis_name: str, scatter_axis: int = 0,
                     ) -> jax.Array:
    if _needs_f32_workaround(x):
        return jax.lax.psum_scatter(
            x.astype(jnp.float32), axis_name, scatter_dimension=scatter_axis,
            tiled=True).astype(x.dtype)
    return jax.lax.psum_scatter(x, axis_name, scatter_dimension=scatter_axis,
                                tiled=True)


def psum_xla(x: jax.Array, axis_name: str) -> jax.Array:
    if _needs_f32_workaround(x):
        return jax.lax.psum(x.astype(jnp.float32), axis_name).astype(x.dtype)
    return jax.lax.psum(x, axis_name)


# --------------------------------------------------------------------------- #
# Simulated-mesh cost bridge: PsumMode selection driven by the NoC subsystem
# (repro.core.noc.collective.cost) instead of the per-link formulas below.
# --------------------------------------------------------------------------- #
def mesh_psum_costs(p: int, nbytes: int):
    """Simulated mesh allreduce cost per PsumMode (latency cycles, pJ)."""
    from repro.core.noc.collective.cost import psum_mode_costs
    return psum_mode_costs(p, nbytes)


def choose_psum_mode(p: int, nbytes: int,
                     objective: str = "latency") -> PsumMode:
    """Best PsumMode for a ``p``-device axis by simulated mesh cost."""
    from repro.core.noc.collective.cost import choose_psum_mode as _choose
    return _choose(p, nbytes, objective=objective)


# --------------------------------------------------------------------------- #
# ExecutionPlan bridge: how ``mode="auto"`` call sites resolve.
#
# Three regimes, in priority order (DESIGN.md S11):
#   1. *Recording* — inside :func:`record_psum_sites` the site's shape is
#      appended to the active trace and a shape-preserving stand-in mode is
#      returned without touching the simulator; the plan builder resolves
#      the deduplicated sites afterwards, once each.
#   2. *Plan-driven* — a :class:`repro.plan.ExecutionPlan` handed down from
#      ``ParallelCtx`` answers from its precomputed per-site table.
#   3. *Planless fallback* — the original trace-time path: the NoC
#      collective cost model simulates the candidate strategies for this
#      (span, payload), hoisted behind a process-wide memo so one site
#      shape costs one resolution per process no matter how many identical
#      call sites a model traces.
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class PsumSite:
    """One ``mode="auto"`` call site, as seen at trace time."""

    op: str                 # "psum" | "reduce_scatter"
    p: int                  # axis span
    nbytes: int             # per-device partial-sum payload


_TRACE_SITES: Optional[list] = None


@contextmanager
def record_psum_sites():
    """Collect ``mode="auto"`` sites instead of resolving them.

    Inside the context every auto site appends a :class:`PsumSite` to the
    yielded list and traces under a fixed stand-in strategy (``"ina"``;
    every strategy is shape-preserving, so recording traces are exact).
    Used by the plan builder's abstract trace; reentrant.
    """
    global _TRACE_SITES
    prev, sites = _TRACE_SITES, []
    _TRACE_SITES = sites
    try:
        yield sites
    finally:
        _TRACE_SITES = prev


@functools.lru_cache(maxsize=None)
def _fallback_choice(p: int, nbytes: int,
                     objective: str = "latency") -> str:
    """Per-process memo of the planless resolution (one simulation set per
    distinct site shape per trace, however many sites share it)."""
    return choose_psum_mode(p, nbytes, objective=objective)


def resolve_auto_mode(op: str, p: int, nbytes: int,
                      plan: Optional[object] = None) -> str:
    """Resolve one ``mode="auto"`` site (see the regime table above).

    ``plan`` is duck-typed: anything with a ``psum_mode(p, nbytes) ->
    Optional[str]`` method (an :class:`repro.plan.ExecutionPlan`).  A plan
    miss — a site the plan never saw, e.g. after a shape change — falls
    back to the trace-time path rather than erroring, resolved under the
    *plan's* objective so one trace never mixes decision criteria.
    Known limit: the fallback costs under the default :class:`NocConfig`;
    a plan built with a custom ``noc_cfg`` (no CLI does this) should
    cover its sites or accept default-costed misses.
    """
    if _TRACE_SITES is not None:
        _TRACE_SITES.append(PsumSite(op=op, p=p, nbytes=int(nbytes)))
        return "ina"
    if plan is not None:
        mode = plan.psum_mode(p, int(nbytes))
        if mode is not None:
            return mode
        return _fallback_choice(p, int(nbytes),
                                getattr(plan, "objective", "latency"))
    return _fallback_choice(p, int(nbytes))


# --------------------------------------------------------------------------- #
# Mode dispatch used by the tensor-parallel layers.
# --------------------------------------------------------------------------- #
def psum_with_mode(x: jax.Array, axis_name: str, mode: PsumMode,
                   scatter_axis: int = 0,
                   plan: Optional[object] = None) -> jax.Array:
    """Fully-reduced psum under the selected accumulation strategy.

    ``mode="auto"`` resolves at trace time: from ``plan`` (an
    :class:`repro.plan.ExecutionPlan` carried by ``ParallelCtx``) when one
    is attached, else from the NoC collective cost model for this tensor
    size and axis span (the sizes are static under jit, so the simulation
    runs once per distinct shape — see :func:`resolve_auto_mode`).
    """
    if mode == "auto":
        p = axis_size(axis_name)
        mode = resolve_auto_mode("psum", p, x.nbytes, plan)
        if mode == "ina_ring" and x.shape[scatter_axis] % p != 0:
            # The chunked ring needs the scatter axis to divide; fall back
            # to the compiler-scheduled in-network reduce, which doesn't.
            mode = "ina"
    if mode == "eject_inject":
        return ring_psum_eject_inject(x, axis_name)
    if mode == "ina_ring":
        return psum_ina(x, axis_name, scatter_axis)
    if mode in ("ina", "xla"):
        return psum_xla(x, axis_name)
    raise ValueError(f"unknown psum mode: {mode}")


def reduce_scatter_with_mode(x: jax.Array, axis_name: str, mode: PsumMode,
                             scatter_axis: int = 0,
                             plan: Optional[object] = None) -> jax.Array:
    """Reduce-scattered psum (output stays sharded on ``scatter_axis``)."""
    if mode == "auto":
        mode = resolve_auto_mode("reduce_scatter", axis_size(axis_name),
                                 x.nbytes, plan)
    if mode == "eject_inject":
        # The baseline has no in-network reduction: full all-reduce, then the
        # caller's shard is sliced out locally (the ejected copy).
        full = ring_psum_eject_inject(x, axis_name)
        p = axis_size(axis_name)
        i = jax.lax.axis_index(axis_name)
        c = x.shape[scatter_axis] // p
        return jax.lax.dynamic_slice_in_dim(full, i * c, c, axis=scatter_axis)
    if mode == "ina_ring":
        return ring_reduce_scatter_ina(x, axis_name, scatter_axis)
    if mode in ("ina", "xla"):
        return psum_scatter_xla(x, axis_name, scatter_axis)
    raise ValueError(f"unknown psum mode: {mode}")


# --------------------------------------------------------------------------- #
# Analytic per-link traffic (bytes) — used by the roofline cross-check.
# --------------------------------------------------------------------------- #
def per_link_bytes(mode: PsumMode, p: int, nbytes: int,
                   need_full: bool = True) -> float:
    """Bytes crossing each ring link per psum of an ``nbytes`` tensor."""
    if p == 1:
        return 0.0
    if mode == "eject_inject":
        return (p - 1) * nbytes
    if mode in ("ina", "ina_ring", "xla", "auto"):
        rs = (p - 1) / p * nbytes
        return rs * 2 if need_full else rs
    raise ValueError(mode)
