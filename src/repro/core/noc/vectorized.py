"""Vectorized array-kernel simulation backend (DESIGN.md S16).

The compiled engine (:mod:`repro.core.noc.compiled`) removed the heap
engine's per-op *Python object* cost but kept its per-*event* cost: every
flit grant is still one ``heappop``.  This module removes the event loop
itself for the program families that dominate full-space search, by
lowering :class:`~repro.core.noc.collective.schedule.PacketOp` programs
into array kernels whose dependency structure is resolved **once at
lowering time**:

K1 — *window pipeline closed form* (``ws_ina`` / ``os_gather`` /
    ``ws_noina`` with P#=1).  A window of ``k`` rounds is ``k*W``
    identical, dependency-free column gather packets.  Columns are
    resource-disjoint and each column is a uniform tandem pipeline, so
    every grant time has the exact solution ``g(r, j) = r*F + ni + R +
    j*(R+L)`` and the window makespan is **linear in k**::

        latency(k) = (k-1)*F + 2*ni + R + n_links*(R+L) + F - 1

    which evaluates *all window lengths of all stacked plan shapes* in
    one batched array pass (the two outer batching axes the event loop
    cannot express: windows x candidate mappings).

K2 — *column-factored replay* (``ws_noina`` with P# > 1).  Relay chains
    make per-round timing genuinely contention-coupled, but columns stay
    exactly resource-disjoint and identical, so the full ``W``-column
    window is priced by replaying **one column** on the compiled engine
    (latency is the column's; the ledger scales by ``W``) — ``W``x fewer
    events with bit-identical results.

K3 — *contention-free DAG wavefront kernel* (tree collectives).  When
    every link/port is used by at most one op (single-tree INA reduce /
    multicast / gather: segments are edge-disjoint, leaves inject on
    distinct ports, one root ejection) — plus the one benign exception
    of sibling root-fanout segments sharing the root's injection port —
    grant times degenerate to a pure longest-path over the dependency
    DAG.  Dependency levels are resolved at lowering; each wavefront is
    one batched ``maximum.at`` array step instead of thousands of heap
    pops.

Bit-exactness contract (the heap engine stays the oracle, as PR 4):
every kernel reproduces the event engines' integer grant arithmetic
*exactly*, and ledgers are only ever produced through the dyadic-
exactness gate (:func:`_scale_exact`): a float total is scaled/multiplied
only when every partial sum of the event engines' sequential accumulation
is provably exact (all components are dyadic rationals of bounded
magnitude), so any summation order — including a multiplication — yields
the identical float.  Programs outside these families (eject-inject
relays, rs_ag chunk trees, express-lane paths, non-dyadic payloads) raise
:class:`UnvectorizableProgram` and fall back to the compiled/heap engines
with an attributable :data:`VECTOR_STATS` counter.

numpy is optional: the closed forms and column replay are scalar-exact
without it; the batched window pass and the K3 wavefront kernel require
it and fall back cleanly when it is absent.  ``jax.numpy`` can be dropped
in for the batched window pass (``set_array_backend("jax")``) when x64 is
enabled — elementwise float64 IEEE arithmetic is identical.
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Iterable, Optional, Sequence

from .compiled import (CompiledProgram, UncompilableProgram, compile_program)
from .router import EnergyLedger, NocConfig
from .simulator import effective_vcs

try:                                    # soft dependency: kernels degrade
    import numpy as _np                 # to scalar closed forms without it
except ImportError:                     # pragma: no cover - env dependent
    _np = None

#: Observable lowering/execution effort, in the style of
#: ``topology.ROUTE_STATS`` / ``collective.cost.COST_STATS``.  The
#: ``fallback_*`` counters attribute every refusal to its reason, so a
#: sweep can prove which families ran vectorized (surfaced next to
#: ``SimCache.stats()`` in benchmark snapshots and sweep summaries).
VECTOR_STATS = {
    "programs_lowered": 0,      # K3 DAG programs lowered + run
    "wavefronts_batched": 0,    # dependency levels executed as one step
    "windows_closed_form": 0,   # K1 window results (incl. batched)
    "windows_batched": 0,       # K1 results produced by a batched pass
    "columns_replayed": 0,      # K2 column-factored window replays
    "fallback_contention": 0,   # resource shared outside the known forms
    "fallback_route": 0,        # unencodable route/port (compiled refuses)
    "fallback_energy": 0,       # non-dyadic energy component (order matters)
    "fallback_backend": 0,      # numpy missing / backend unavailable
}

_STATE = {"enabled": True, "backend": "numpy"}


def vectorized_enabled() -> bool:
    return _STATE["enabled"]


@contextmanager
def vectorized_disabled():
    """Force the compiled/heap engines (PR-4 behaviour) everywhere."""
    prev = _STATE["enabled"]
    _STATE["enabled"] = False
    try:
        yield
    finally:
        _STATE["enabled"] = prev


def set_array_backend(name: str) -> str:
    """Select the array module for batched passes: ``numpy`` (default) or
    ``jax`` (requires x64; falls back to numpy otherwise — float32 would
    break the bit-exactness contract).  Returns the backend in effect."""
    if name == "jax":
        try:
            import jax
            if jax.config.jax_enable_x64:
                _STATE["backend"] = "jax"
                return "jax"
        except ImportError:
            pass
        VECTOR_STATS["fallback_backend"] += 1
        _STATE["backend"] = "numpy"
        return "numpy"
    _STATE["backend"] = "numpy"
    return "numpy"


def _xp():
    if _STATE["backend"] == "jax":      # pragma: no cover - optional path
        import jax.numpy as jnp
        return jnp
    return _np


class UnvectorizableProgram(ValueError):
    """The program is outside every family the lowering can express."""


def vector_stats() -> dict:
    """A ``SimCache.stats()``-style summary snapshot (private copy)."""
    out = dict(VECTOR_STATS)
    out["enabled"] = _STATE["enabled"]
    out["fallbacks"] = sum(v for k, v in VECTOR_STATS.items()
                           if k.startswith("fallback_"))
    return out


def reset_vector_stats() -> None:
    for k in VECTOR_STATS:
        VECTOR_STATS[k] = 0


# --------------------------------------------------------------------------- #
# Dyadic-exactness gate
# --------------------------------------------------------------------------- #
#: Energy components are gated as m * 2^-16 with |total| < 2^53: every
#: partial sum of the engines' sequential accumulation is then exactly
#: representable, so sum order is irrelevant and N*v == v+v+...+v bit for
#: bit.  (Default configs produce small ints and n/4 dyadics —
#: gather_payload_bits 32 over flit_bits 128; an exotic flit_bits makes
#: the check fail and the program fall back.)
_DYADIC_SCALE = 65536.0
_EXACT_BOUND = float(2 ** 53)


def _scale_exact(value: float, count: float) -> bool:
    """True iff ``count`` sequential float adds of ``value`` are provably
    exact (equivalently: ``count * value`` equals the sequential sum)."""
    scaled = value * _DYADIC_SCALE
    return scaled == int(scaled) and abs(scaled) * count < _EXACT_BOUND


# --------------------------------------------------------------------------- #
# K1 — window pipeline closed form
# --------------------------------------------------------------------------- #
def window_family(mode: str, p: int) -> str:
    """``"pipeline"`` (K1) or ``"chain"`` (K2) for a WS/OS window key."""
    return "chain" if (mode == "ws_noina" and p > 1) else "pipeline"


def _pipeline_consts(cfg: NocConfig, mode: str, g: int, p: int,
                     gather_flits: int, e_pes: int):
    """Per-round constants of the K1 closed form, or None if the shape is
    outside the family's guarantees (then: compiled/heap fallback).

    Returns ``(width, flits, d1, energy_tuple)`` where a ``k``-round
    window has latency ``(k-1)*flits + d1`` and ledger ``k*width *
    energy_tuple`` (per-op static contributions, identical to
    ``compile_program``'s lowering of the single gather op).
    """
    if window_family(mode, p) != "pipeline":
        return None
    if effective_vcs(cfg) < 2:          # gather rides VC1
        return None
    w, h = cfg.width, cfg.height
    if w < 1 or h < 1 or gather_flits < 1:
        return None
    f = gather_flits
    n_links = h - 1
    r_cyc, l_cyc, ni = cfg.router_cycles, cfg.link_cycles, cfg.ni_cycles
    d1 = 2 * ni + r_cyc + n_links * (r_cyc + l_cyc) + f - 1
    ina = mode == "ws_ina"
    extra = float(f - 1)
    reduce_words = g * (p - 1) if ina else 0
    if ina:
        extra += (reduce_words * e_pes * cfg.gather_payload_bits
                  / cfg.flit_bits)
    energy = (0.0,                              # pe_adds
              extra + f * 2,                    # ni flits (inject + eject)
              float(f * (n_links + 1)),         # flit x router
              float(f * n_links),               # flit x link
              float(n_links),                   # packet hops
              float(reduce_words),              # INA adds
              2.0)                              # packets built (inj + ej)
    return w, f, d1, energy


def _ledger_from_components(counts_x_energy: Sequence[float]) -> EnergyLedger:
    pe, ni, routers, links, hops, radds, pkts = counts_x_energy
    return EnergyLedger(pe_adds=pe, ni_flits=ni, flit_routers=routers,
                        flit_links=links, packet_hops=hops,
                        router_adds=radds, packets_built=pkts)


def _pipeline_window(cfg: NocConfig, mode: str, window: int, g: int, p: int,
                     gather_flits: int, e_pes: int
                     ) -> Optional[tuple[float, EnergyLedger]]:
    consts = _pipeline_consts(cfg, mode, g, p, gather_flits, e_pes)
    if consts is None:
        return None
    w, f, d1, energy = consts
    n_ops = window * w
    if not all(_scale_exact(e, n_ops) for e in energy if e):
        VECTOR_STATS["fallback_energy"] += 1
        return None
    latency = float((window - 1) * f + d1)
    VECTOR_STATS["windows_closed_form"] += 1
    return latency, _ledger_from_components([e * n_ops for e in energy])


# --------------------------------------------------------------------------- #
# K2 — column-factored replay (ws_noina, P# > 1)
# --------------------------------------------------------------------------- #
#: (cfg, mode, g, p, gather_flits, unicast_flits, e_pes) -> compiled
#: one-round column-0 program (windows replicate it, as _ROUND_PROGRAMS).
_COLUMN_PROGRAMS: dict = {}


def clear_vector_caches() -> None:
    _COLUMN_PROGRAMS.clear()


def _column_round(cfg: NocConfig, mode: str, g: int, p: int,
                  gather_flits: int, unicast_flits: int, e_pes: int
                  ) -> Optional[CompiledProgram]:
    """One round of column 0 only, compiled (deps reindexed).

    ``ws_round_program`` emits per-column op groups whose resources and
    dependencies never cross columns, and every column is the same
    pattern shifted in x — so the W-column window's latency is column
    0's and its ledger is W x column 0's (gated by :func:`_scale_exact`).
    """
    key = (cfg, mode, g, p, gather_flits, unicast_flits, e_pes)
    hit = _COLUMN_PROGRAMS.get(key)
    if hit is not None:
        return hit
    from .collective.schedule import ws_round_program
    prog = ws_round_program(cfg, mode, 1, g=g, p=p,
                            gather_flits=gather_flits,
                            unicast_flits=unicast_flits, e_pes=e_pes)
    col, remap = [], {}
    for i, op in enumerate(prog):
        if op.src[0] != 0:
            continue
        if op.dst[0] != 0 or any(d not in remap for d in op.deps):
            VECTOR_STATS["fallback_contention"] += 1    # cross-column op
            return None
        remap[i] = len(col)
        if op.deps:
            op = dataclasses.replace(op,
                                     deps=tuple(remap[d] for d in op.deps))
        col.append(op)
    if not col or len(col) * cfg.width != len(prog):
        VECTOR_STATS["fallback_contention"] += 1        # asymmetric columns
        return None
    try:
        base = compile_program(col, cfg)
    except UncompilableProgram:
        VECTOR_STATS["fallback_route"] += 1
        return None
    _COLUMN_PROGRAMS[key] = base
    return base


def _chain_window(cfg: NocConfig, mode: str, window: int, g: int, p: int,
                  gather_flits: int, unicast_flits: int, e_pes: int
                  ) -> Optional[tuple[float, EnergyLedger]]:
    base = _column_round(cfg, mode, g, p, gather_flits, unicast_flits, e_pes)
    if base is None:
        return None
    latency, ledger, _, _ = base.replicate(window).run()
    w = cfg.width
    comps = ledger.as_tuple()
    if not all(_scale_exact(c, w) for c in comps if c):
        VECTOR_STATS["fallback_energy"] += 1
        return None
    VECTOR_STATS["columns_replayed"] += 1
    return float(latency), EnergyLedger.from_tuple([c * w for c in comps])


# --------------------------------------------------------------------------- #
# Window entry points (traffic._sim_rounds_window + mapper prefetch)
# --------------------------------------------------------------------------- #
def window_result(cfg: NocConfig, mode: str, window: int, g: int, p: int,
                  gather_flits: int, unicast_flits: int, e_pes: int
                  ) -> Optional[tuple[float, EnergyLedger]]:
    """Exact (latency, ledger) of one WS/OS window, or None (fallback)."""
    if not _STATE["enabled"]:
        return None
    if window_family(mode, p) == "pipeline":
        return _pipeline_window(cfg, mode, window, g, p, gather_flits, e_pes)
    return _chain_window(cfg, mode, window, g, p, gather_flits,
                         unicast_flits, e_pes)


def prefetch_windows(keys: Iterable[tuple]) -> int:
    """Batch-evaluate window keys and fill ``SIM_CACHE``; returns the
    number of keys answered.

    ``keys`` use the ``_sim_rounds_window`` layout ``(cfg, mode, window,
    g, p, gather_flits, unicast_flits, e_pes)``.  Pipeline-family keys
    are stacked into one array pass — this is the mapper's candidate-
    mapping batching axis: all (hardware, dataflow, E, G, window) shapes
    of a layer's keep set price in one vectorized step.  Chain-family
    keys replay their column programs individually.
    """
    from .simcache import SIM_CACHE

    if not (_STATE["enabled"] and SIM_CACHE.enabled):
        return 0
    pipeline, chain, answered = [], [], 0
    seen = set()
    for key in keys:
        if key in seen or key in SIM_CACHE:
            continue
        seen.add(key)
        cfg, mode, window, g, p, gather_flits, unicast_flits, e_pes = key
        if window_family(mode, p) == "pipeline":
            consts = _pipeline_consts(cfg, mode, g, p, gather_flits, e_pes)
            if consts is not None:
                pipeline.append((key, window, consts))
                continue
        chain.append(key)

    xp = _xp()
    if pipeline and xp is not None and len(pipeline) > 1:
        ws = xp.asarray([window for _, window, _ in pipeline],
                        dtype=xp.int64)
        f = xp.asarray([c[1] for _, _, c in pipeline], dtype=xp.int64)
        d1 = xp.asarray([c[2] for _, _, c in pipeline], dtype=xp.int64)
        n_ops = (ws * xp.asarray([c[0] for _, _, c in pipeline],
                                 dtype=xp.int64)).astype(xp.float64)
        lat = ((ws - 1) * f + d1).astype(xp.float64)
        comps = [xp.asarray([c[3][j] for _, _, c in pipeline],
                            dtype=xp.float64) * n_ops for j in range(7)]
        for i, (key, window, consts) in enumerate(pipeline):
            if not all(_scale_exact(e, window * consts[0])
                       for e in consts[3] if e):
                VECTOR_STATS["fallback_energy"] += 1
                continue                # compiled path answers this key
            SIM_CACHE.put(key, float(lat[i]), _ledger_from_components(
                [float(comp[i]) for comp in comps]))
            VECTOR_STATS["windows_closed_form"] += 1
            VECTOR_STATS["windows_batched"] += 1
            answered += 1
    else:
        chain = [key for key, _, _ in pipeline] + chain

    for key in chain:
        cfg, mode, window, g, p, gather_flits, unicast_flits, e_pes = key
        hit = window_result(cfg, mode, window, g, p, gather_flits,
                            unicast_flits, e_pes)
        if hit is not None:
            SIM_CACHE.put(key, hit[0], hit[1])
            answered += 1
    return answered


# --------------------------------------------------------------------------- #
# K3 — contention-free DAG wavefront kernel
# --------------------------------------------------------------------------- #
class VectorProgram:
    """One PacketOp program lowered to per-wavefront arrays.

    Lowering proves zero resource contention (or the sibling root-fanout
    form), precomputes every op's completion *duration* and the exact
    (order-free) ledger totals; :meth:`run` is then a pure longest-path
    propagation: per dependency level, one batched ``maximum.at`` step.
    """

    __slots__ = ("n", "levels", "t_of", "delay_of", "dur", "ledger_totals",
                 "delivers")

    def __init__(self, n: int, levels: list, t_of, delay_of, dur,
                 ledger_totals: tuple, delivers: list):
        self.n = n
        self.levels = levels            # [(idx, edge_src, edge_dst)]
        self.t_of = t_of
        self.delay_of = delay_of        # 0 where the op has no deps
        self.dur = dur
        self.ledger_totals = ledger_totals
        self.delivers = delivers        # [(op_index, node, offset)]

    def run(self, t0: int = 0) -> tuple[int, EnergyLedger, list, dict]:
        # The wavefront kernel needs in-place scatter-max (numpy ufunc
        # ``.at``); the optional jax backend only serves the elementwise
        # batched window pass.
        n = self.n
        done = _np.zeros(n, dtype=_np.int64)
        issue = _np.zeros(n, dtype=_np.int64)
        ready = self.t_of + t0
        for idx, edge_src, edge_dst in self.levels:
            if edge_src.size:
                _np.maximum.at(ready, edge_dst, done[edge_src])
            lv_issue = ready[idx] + self.delay_of[idx]
            issue[idx] = lv_issue
            done[idx] = lv_issue + self.dur[idx]
            VECTOR_STATS["wavefronts_batched"] += 1
        delivered: dict = {}
        for i, node, off in self.delivers:
            t = int(issue[i]) + off
            if node not in delivered or t < delivered[node]:
                delivered[node] = t
        latency = int(done.max()) if n else 0
        VECTOR_STATS["programs_lowered"] += 1
        return (latency, _ledger_from_components(self.ledger_totals),
                [int(d) for d in done], delivered)


def lower_program(prog: Sequence, cfg: NocConfig) -> VectorProgram:
    """Lower ``prog`` for wavefront replay or raise UnvectorizableProgram.

    Rides ``compile_program`` for route/port encoding and the per-op
    static energy tuples, then statically discharges the two obligations
    the event engines resolve dynamically:

    * **occupancy** — every link and ejection port is used by at most one
      op; an injection port is either exclusive or shared by sibling ops
      with identical (deps, t, delay, flits), whose grants provably
      serialize in program order at ``issue + rank*flits``;
    * **ledger order** — every energy component passes the dyadic gate,
      so the engines' dynamic issue-order accumulation equals the static
      program-order total bit for bit.
    """
    if _np is None and _STATE["backend"] == "numpy":
        VECTOR_STATS["fallback_backend"] += 1
        raise UnvectorizableProgram("numpy unavailable")
    try:
        cp = compile_program(prog, cfg)
    except UncompilableProgram as e:
        VECTOR_STATS["fallback_route"] += 1
        raise UnvectorizableProgram(str(e)) from e
    n = cp.n
    ops = cp.ops
    r_cyc, l_cyc, ni = cp.router_cycles, cp.link_cycles, cp.ni_cycles

    # --- occupancy census -------------------------------------------------- #
    link_user = {}
    ej_user = {}
    inj_groups: dict[int, list[int]] = {}
    for i, op in enumerate(ops):
        (_, _, _, virtual, flits, inject, eject, link_ids,
         inj_pid, ej_pid, _, _, _) = op
        if virtual:
            continue
        for lid in link_ids:
            if lid in link_user:
                VECTOR_STATS["fallback_contention"] += 1
                raise UnvectorizableProgram(f"link {lid} shared")
            link_user[lid] = i
        if eject:
            if ej_pid in ej_user:
                VECTOR_STATS["fallback_contention"] += 1
                raise UnvectorizableProgram(f"eject port {ej_pid} shared")
            ej_user[ej_pid] = i
        if inject:
            inj_groups.setdefault(inj_pid, []).append(i)
    inj_rank = [0] * n
    for pid, members in inj_groups.items():
        if len(members) == 1:
            continue
        # Sibling root-fanout form: same deps/t/delay/flits => equal issue
        # times, grants serialize in program order spaced by flits.
        sig = {(ops[i][0], ops[i][1], ops[i][2], ops[i][4]) for i in members}
        if len(sig) != 1:
            VECTOR_STATS["fallback_contention"] += 1
            raise UnvectorizableProgram(f"inject port {pid} shared "
                                        "by non-sibling ops")
        for rank, i in enumerate(members):
            inj_rank[i] = rank

    # --- exact ledger totals ----------------------------------------------- #
    totals = [0.0] * 7
    for op in ops:
        e = op[12]
        comps = e[:2] if op[3] else e           # virtual: pe + ni only
        for j, v in enumerate(comps):
            if v and not _scale_exact(v, n):
                VECTOR_STATS["fallback_energy"] += 1
                raise UnvectorizableProgram("non-dyadic energy component")
            totals[j] += v

    # --- per-op durations + deliveries ------------------------------------- #
    dur = [0] * n
    delivers: list[tuple[int, object, int]] = []
    for i, op in enumerate(ops):
        (t, delay, deps, virtual, flits, inject, eject, link_ids,
         inj_pid, ej_pid, hop_deliver, completion, _) = op
        if not virtual:
            inj_off = inj_rank[i] * flits + ni if inject else 0
            n_links = len(link_ids)
            d = inj_off + n_links * (r_cyc + l_cyc)
            d += (r_cyc + ni + flits - 1) if eject else (flits - 1)
            dur[i] = d
            if hop_deliver is not None:
                for st, node in enumerate(hop_deliver):
                    if node is not None:
                        delivers.append(
                            (i, node, inj_off + (st + 1) * (r_cyc + l_cyc)
                             + flits - 1))
        for node in completion:
            delivers.append((i, node, dur[i]))

    # --- dependency levels -------------------------------------------------- #
    level = [0] * n
    for i, op in enumerate(ops):
        if op[2]:
            level[i] = 1 + max(level[d] for d in op[2])
    n_levels = (max(level) + 1) if n else 0
    levels = []
    for lv in range(n_levels):
        idx = [i for i in range(n) if level[i] == lv]
        esrc, edst = [], []
        for i in idx:
            for d in ops[i][2]:
                esrc.append(d)
                edst.append(i)
        levels.append((_np.asarray(idx, dtype=_np.int64),
                       _np.asarray(esrc, dtype=_np.int64),
                       _np.asarray(edst, dtype=_np.int64)))
    t_of = _np.asarray([op[0] for op in ops], dtype=_np.int64)
    delay_of = _np.asarray([op[1] if op[2] else 0 for op in ops],
                           dtype=_np.int64)
    return VectorProgram(n, levels, t_of, delay_of,
                         _np.asarray(dur, dtype=_np.int64),
                         tuple(totals), delivers)


def run_vectorized(prog: Sequence, cfg: NocConfig, t0: int = 0
                   ) -> tuple[int, EnergyLedger, list, dict]:
    """Lower + run in one call (raises UnvectorizableProgram on fallback)."""
    return lower_program(prog, cfg).run(t0)
