"""2D-mesh topology and dimension-ordered (XY) routing.

Routes are pure functions of ``(src, dst)``, so :func:`xy_route` /
:func:`links_of` are memoized — the simulator replays the same few hundred
(src, dst) pairs millions of times across a sweep, and deriving the path
per packet dominated ``enqueue`` before PR 4 (DESIGN.md S10).  The
uncached derivations stay exposed (``xy_route_uncached``) as the ground
truth the regression tests compare against; ``ROUTE_STATS`` counts actual
derivations so tests can assert repeated enqueues never re-derive.

The memo tables are *bounded* (FIFO eviction at :data:`ROUTE_CACHE_MAX`
entries, counted in ``ROUTE_STATS["evicted"]``) and clearable
(:func:`clear_route_caches`): multi-chip hierarchy sweeps enqueue
thousands of distinct (src, dst) pairs per chip shape, and the pre-PR-8
unbounded ``lru_cache`` grew without limit across a long sweep.  Flat
8x8-mesh pairs (the hot set) stay resident — the hierarchy regression in
``tests/test_hierarchy.py`` pins that a multi-chip sweep re-derives zero
warm flat-mesh routes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

#: ``derived`` increments once per *derived* (not cache-served) route;
#: ``evicted`` once per FIFO eviction from a full cache.
ROUTE_STATS = {"derived": 0, "evicted": 0}

#: Per-table entry bound.  32k (src, dst) pairs cover a 180-node mesh's
#: full pair set; bigger sweeps recycle cold entries FIFO.
ROUTE_CACHE_MAX = 1 << 15

_ROUTE_CACHE: dict = {}
_LINK_CACHE: dict = {}


def clear_route_caches() -> None:
    """Drop every memoized route/link tuple (stats are cumulative)."""
    _ROUTE_CACHE.clear()
    _LINK_CACHE.clear()


def route_cache_sizes() -> dict[str, int]:
    return {"routes": len(_ROUTE_CACHE), "links": len(_LINK_CACHE)}


def _trim(cache: dict) -> None:
    while len(cache) > ROUTE_CACHE_MAX:
        del cache[next(iter(cache))]          # FIFO: dicts keep insert order
        ROUTE_STATS["evicted"] += 1


def memo_route(key, derive) -> tuple:
    """Memoize an arbitrary derived route in the bounded route cache.

    The fault layer (:mod:`~repro.core.noc.faults`) keys detour routes as
    ``(src, dst, fault_key)`` — disjoint from the plain ``(src, dst)`` XY
    keys, so one fault set can never serve another's (or the clean mesh's)
    entries, while sharing the same FIFO bound and eviction stats.
    """
    hit = _ROUTE_CACHE.get(key)
    if hit is None:
        hit = _ROUTE_CACHE[key] = tuple(derive())
        _trim(_ROUTE_CACHE)
    return hit


@dataclass(frozen=True)
class Mesh:
    """A W x H 2D mesh.  Nodes are (x, y) with x = column, y = row.

    ``n`` is the width in columns; ``rows`` is the height (None = square,
    the paper's N x N).  Rectangular shapes are part of the mapper's search
    space (DESIGN.md S9).
    """

    n: int
    rows: Optional[int] = None

    @property
    def width(self) -> int:
        return self.n

    @property
    def height(self) -> int:
        return self.rows if self.rows is not None else self.n

    def node_id(self, x: int, y: int) -> int:
        return y * self.width + x

    def coords(self, nid: int) -> tuple[int, int]:
        return nid % self.width, nid // self.width

    @property
    def num_nodes(self) -> int:
        return self.width * self.height

    def seeded_faults(self, **rates):
        """A deterministic :class:`~repro.core.noc.faults.FaultModel` for
        this mesh's shape (see :func:`~repro.core.noc.faults.seeded_faults`
        for the rate/seed knobs).  Lazy import: ``faults`` depends on this
        module."""
        from .faults import seeded_faults
        return seeded_faults(self.width, self.height, **rates)


def xy_route_uncached(src: tuple[int, int],
                      dst: tuple[int, int]) -> list[tuple[int, int]]:
    """Dimension-ordered XY route: list of nodes visited, inclusive of
    endpoints.  Unmemoized ground truth (regression tests compare the
    cached path against this)."""
    ROUTE_STATS["derived"] += 1
    x, y = src
    dx, dy = dst
    path = [(x, y)]
    step = 1 if dx > x else -1
    while x != dx:
        x += step
        path.append((x, y))
    step = 1 if dy > y else -1
    while y != dy:
        y += step
        path.append((x, y))
    return path


def xy_route_tuple(src: tuple[int, int],
                   dst: tuple[int, int]) -> tuple[tuple[int, int], ...]:
    """Memoized XY route as an immutable tuple (safe to share)."""
    key = (src, dst)
    hit = _ROUTE_CACHE.get(key)
    if hit is None:
        hit = _ROUTE_CACHE[key] = tuple(xy_route_uncached(src, dst))
        _trim(_ROUTE_CACHE)
    return hit


def xy_route(src: tuple[int, int], dst: tuple[int, int]) -> list[tuple[int, int]]:
    """Dimension-ordered XY route (memoized; returns a fresh list)."""
    return list(xy_route_tuple(src, dst))


def route_links(src: tuple[int, int], dst: tuple[int, int],
                ) -> tuple[tuple[tuple[int, int], tuple[int, int]], ...]:
    """Memoized directed links of the XY route (the ``enqueue`` hot path)."""
    key = (src, dst)
    hit = _LINK_CACHE.get(key)
    if hit is None:
        path = xy_route_tuple(src, dst)
        hit = _LINK_CACHE[key] = tuple(zip(path[:-1], path[1:]))
        _trim(_LINK_CACHE)
    return hit


def yx_route(src: tuple[int, int], dst: tuple[int, int]) -> list[tuple[int, int]]:
    """Dimension-ordered YX route (vertical dimension resolved first)."""
    return [(x, y) for y, x in xy_route(src[::-1], dst[::-1])]


def route(src: tuple[int, int], dst: tuple[int, int],
          order: str = "xy") -> list[tuple[int, int]]:
    """Dimension-ordered route under the given dimension order."""
    if order == "xy":
        return xy_route(src, dst)
    if order == "yx":
        return yx_route(src, dst)
    raise ValueError(f"unknown route order: {order!r}")


def links_of(path: list[tuple[int, int]]) -> list[tuple[tuple[int, int], tuple[int, int]]]:
    """Directed links traversed along a node path."""
    return list(zip(path[:-1], path[1:]))
