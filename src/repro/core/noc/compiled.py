"""Compiled packet programs: record a program once, replay it closure-free.

:func:`compile_program` lowers a list of
:class:`~repro.core.noc.collective.schedule.PacketOp` into flat per-op
tuples — int link ids, port ids, dependency edges, and the op's static
energy contribution — and :meth:`CompiledProgram.run` replays them with a
single event loop that touches only local lists and ints.  This removes
everything the heap engine pays per run *per op*: closure allocation
(``on_done``/``on_hop`` lambdas), ``_Packet`` construction, route
derivation, and attribute chasing.

Replay is bit-identical to ``engine.run_program`` + ``NocSim.run`` by
construction:

* identical issue order (dependency-free ops in program order, children
  issued recursively inside completions) and identical heap tie-breaking
  (one monotone sequence number shared by first pushes and re-pushes);
* identical integer timing arithmetic per stage (inject port, per-link
  wormhole reservation, eject port);
* identical per-op ledger contributions applied at issue time in issue
  order (event counts are path-determined, never contention-determined).

``tests/test_perf_layer.py`` asserts latency *and* full-ledger equality
against the heap engine across every fig7-12 plan shape.

Programs whose coordinates fall outside the configured mesh (or whose
path overrides take non-unit steps) raise :class:`UncompilableProgram`;
callers fall back to the heap engine.  The module-level switch
(:func:`compiled_disabled`) forces the fallback everywhere — that is the
ground-truth mode benchmarks use to time the legacy path.
"""
from __future__ import annotations

from contextlib import contextmanager
from heapq import heappop, heappush
from typing import Sequence

from .router import EnergyLedger, NocConfig
from .simulator import (effective_vcs, link_array_size, path_link_ids,
                        port_array_size, port_index, route_link_ids)

#: Global switch: when False, ``run_program``/``_sim_rounds_window`` use the
#: heap engine even for compilable programs (ground-truth/reference mode).
_STATE = {"enabled": True}


def compiled_enabled() -> bool:
    return _STATE["enabled"]


@contextmanager
def compiled_disabled():
    """Force the closure-based heap engine (legacy/reference execution)."""
    prev = _STATE["enabled"]
    _STATE["enabled"] = False
    try:
        yield
    finally:
        _STATE["enabled"] = prev


class UncompilableProgram(ValueError):
    """The program uses features the flat executor cannot encode."""


class CompiledProgram:
    """One packet program lowered to flat arrays, replayable many times."""

    __slots__ = ("n", "ops", "children", "dep_count", "n_links", "n_ports",
                 "ni_cycles", "router_cycles", "link_cycles")

    def __init__(self, cfg: NocConfig):
        self.n = 0
        self.ops: list[tuple] = []
        self.children: list[tuple[int, ...]] = []
        self.dep_count: list[int] = []
        self.n_links = link_array_size(cfg)
        self.n_ports = port_array_size(cfg)
        self.ni_cycles = cfg.ni_cycles
        self.router_cycles = cfg.router_cycles
        self.link_cycles = cfg.link_cycles

    # ------------------------------------------------------------------ #
    def run(self, t0: int = 0) -> tuple[int, EnergyLedger, list, dict]:
        """Replay; returns ``(latency, ledger, done, delivered)``."""
        ops = self.ops
        children = self.children
        remaining = list(self.dep_count)
        n = self.n
        done: list = [None] * n
        link_free = [0] * self.n_links
        port_free = [0] * self.n_ports
        heap: list = []
        ni_cycles = self.ni_cycles
        router_cycles = self.router_cycles
        link_cycles = self.link_cycles
        # Per-run mutable packet state (parallel to ops).
        stage = [0] * n
        head = [0] * n
        delivered: dict = {}
        # Ledger accumulators (issue-order, see module docstring).
        acc = [0.0] * 7   # pe, ni, routers, links, hops, radds, pkts
        seq = 0

        def deliver(node, t: int) -> None:
            if node not in delivered or t < delivered[node]:
                delivered[node] = t

        def issue(i: int, t: int) -> None:
            nonlocal seq
            op = ops[i]
            # op = (t, delay, deps, virtual, flits, inject, eject, link_ids,
            #       inj_pid, ej_pid, hop_deliver, completion_delivers, energy)
            e = op[12]
            acc[0] += e[0]
            acc[1] += e[1]
            if op[3]:                          # virtual synchronisation op
                complete(i, t)
                return
            acc[2] += e[2]
            acc[3] += e[3]
            acc[4] += e[4]
            acc[5] += e[5]
            acc[6] += e[6]
            stage[i] = -1 if op[5] else 0
            head[i] = t
            heappush(heap, (t, seq, i))
            seq += 1

        def complete(i: int, td: int) -> None:
            done[i] = td
            op = ops[i]
            for node in op[11]:
                deliver(node, td)
            for j in children[i]:
                remaining[j] -= 1
                if remaining[j] == 0:
                    child = ops[j]
                    t = t0 + child[0]
                    for d in child[2]:
                        if done[d] > t:
                            t = done[d]
                    issue(j, t + child[1])

        for i, op in enumerate(ops):
            if not op[2]:
                issue(i, t0 + op[0])

        makespan = 0
        while heap:
            t, s, i = heappop(heap)
            op = ops[i]
            st = stage[i]
            flits = op[4]
            if st == -1:                                 # injection port
                pid = op[8]
                free = port_free[pid]
                if free > t:
                    heappush(heap, (free, seq, i))
                    seq += 1
                    continue
                port_free[pid] = t + flits
                head[i] = t + ni_cycles
                stage[i] = 0
                heappush(heap, (head[i], seq, i))
                seq += 1
                continue
            link_ids = op[7]
            if st < len(link_ids):                       # link hop
                lid = link_ids[st]
                ready = head[i] + router_cycles
                free = link_free[lid]
                if free > ready:
                    head[i] = free - router_cycles
                    heappush(heap, (free, seq, i))
                    seq += 1
                    continue
                link_free[lid] = ready + flits
                h = ready + link_cycles
                head[i] = h
                stage[i] = st + 1
                hop = op[10]
                if hop is not None:
                    node = hop[st]
                    if node is not None:
                        deliver(node, h + flits - 1)
                heappush(heap, (h, seq, i))
                seq += 1
                continue
            if op[6]:                                    # ejection port
                pid = op[9]
                ready = head[i] + router_cycles
                free = port_free[pid]
                if free > ready:
                    head[i] = free - router_cycles
                    heappush(heap, (free, seq, i))
                    seq += 1
                    continue
                port_free[pid] = ready + flits
                dt = ready + ni_cycles + flits - 1
            else:
                dt = head[i] + flits - 1
            if dt > makespan:
                makespan = dt
            complete(i, dt)

        stuck = [i for i, d in enumerate(done) if d is None]
        assert not stuck, f"deadlocked ops (circular/unmet deps): {stuck}"
        ledger = EnergyLedger(
            pe_adds=acc[0], ni_flits=acc[1], flit_routers=acc[2],
            flit_links=acc[3], packet_hops=acc[4], router_adds=acc[5],
            packets_built=acc[6])
        return max([makespan] + done), ledger, done, delivered

    # ------------------------------------------------------------------ #
    def replicate(self, k: int) -> "CompiledProgram":
        """The program repeated ``k`` times back-to-back (dep-shifted).

        Exactly equivalent to compiling the ``k``-fold concatenation:
        op order is preserved round-major, and dependency/children indices
        are offset per repetition.  Valid because the source program's
        dependencies are internal (guaranteed for ``ws_round_program``
        rounds, whose ops never reference another round) — this is what
        lets a :class:`~repro.core.noc.traffic.CompiledWindow` be built
        from one compiled round instead of re-planning and re-compiling
        every distinct window length.
        """
        if k == 1:
            return self
        out = CompiledProgram.__new__(CompiledProgram)
        out.n_links = self.n_links
        out.n_ports = self.n_ports
        out.ni_cycles = self.ni_cycles
        out.router_cycles = self.router_cycles
        out.link_cycles = self.link_cycles
        n = self.n
        ops: list[tuple] = []
        children: list[tuple[int, ...]] = []
        for r in range(k):
            off = r * n
            if off == 0:
                ops.extend(self.ops)
                children.extend(self.children)
                continue
            for op in self.ops:
                if op[2]:
                    op = op[:2] + (tuple(d + off for d in op[2]),) + op[3:]
                ops.append(op)
            children.extend(tuple(c + off for c in ch)
                            for ch in self.children)
        out.ops = ops
        out.children = children
        out.dep_count = self.dep_count * k
        out.n = n * k
        return out


def compile_program(prog: Sequence, cfg: NocConfig) -> CompiledProgram:
    """Lower ``prog`` (a sequence of PacketOps) for flat replay.

    Raises :class:`UncompilableProgram` when an op cannot be encoded into
    the mesh-sized flat arrays (out-of-mesh coordinate, non-unit path
    step, VC beyond the config) — callers fall back to the heap engine.
    """
    cp = CompiledProgram(cfg)
    width, height = cfg.width, cfg.height
    vcs = effective_vcs(cfg)
    n = len(prog)
    children: list[list[int]] = [[] for _ in range(n)]
    for i, op in enumerate(prog):
        for d in op.deps:
            if not 0 <= d < i:
                raise UncompilableProgram(f"op {i} depends on non-prior {d}")
            children[d].append(i)

    def port_id(kind: int, vc: int, node) -> int:
        pid = port_index(kind, vc, node, width, height, vcs)
        if pid is None:
            raise UncompilableProgram(f"port ({kind}, {vc}, {node}) "
                                      f"outside the {width}x{height} mesh")
        return pid

    for i, op in enumerate(prog):
        virtual = op.flits == 0 and not op.inject and not op.eject
        link_ids: tuple[int, ...] = ()
        inj_pid = ej_pid = 0
        hop_deliver = None
        if not virtual:
            if op.path is not None:
                link_ids, _, links = path_link_ids(width, height,
                                                   tuple(op.path))
            else:
                link_ids, _, links = route_link_ids(width, height,
                                                    op.src, op.dst)
            if link_ids is None:
                raise UncompilableProgram(f"op {i}: route {op.src}->{op.dst} "
                                          f"leaves the {width}x{height} mesh")
            if op.inject:
                inj_pid = port_id(0, op.vc, op.src)
            if op.eject:
                ej_pid = port_id(1, op.vc, op.dst)
            midway = set(op.delivers) - {op.dst}
            if midway:
                hop_deliver = tuple(l[1] if l[1] in midway else None
                                    for l in links)
        n_links = len(link_ids)
        completion = tuple(node for node in op.delivers
                           if node == op.dst or op.flits == 0)
        energy = (op.pe_adds,
                  op.extra_ni_flits
                  + op.flits * (int(op.inject) + int(op.eject)),
                  op.flits * (n_links + 1),
                  op.flits * n_links,
                  n_links,
                  op.reduce_words,
                  int(op.inject) + int(op.eject))
        cp.ops.append((op.t, op.delay, tuple(op.deps), virtual, op.flits,
                       op.inject, op.eject, link_ids, inj_pid, ej_pid,
                       hop_deliver, completion, energy))
    cp.children = [tuple(c) for c in children]
    cp.dep_count = [len(op.deps) for op in prog]
    cp.n = n
    return cp
