"""Plan-keyed memoization for the event-driven WS/OS round simulator.

:func:`~repro.core.noc.traffic._sim_rounds_window` replays a window of
accumulation/gather rounds whose traffic depends only on the *plan shape* —
``(cfg, mode, window, g, p, gather_flits, unicast_flits, e_pes)`` — and not
on the layer identity.  Whole-network sweeps therefore re-simulate the same
window program once per layer (ResNet-50 alone is ~53 layers, ~40 of which
share the degenerate P#=1 shape), and the paper's full Figs 7-12 evaluation
(3 workloads x 4 E values x 3 modes) repeats a handful of distinct programs
hundreds of times.

This module is the keyed cache that collapses those repeats, extending the
facade pattern of :mod:`repro.core.noc.collective.cost` (which memoizes
``plan_collective`` + ``run_program`` per collective signature) down to the
WS dataflow windows.  Invalidation is structural: :class:`NocConfig` is a
frozen dataclass and a full member of the key, so any timing/energy-constant
change hashes to a different entry — there is nothing to flush when a sweep
varies ``n``, ``e_pes`` or energy constants.

Entries store ``(latency, EnergyLedger)``.  Ledgers are mutable event-count
accumulators, so the cache keeps a private copy and hands out a fresh
:meth:`EnergyLedger.copy` per hit, keeping cached runs bit-identical to
uncached ones (see ``tests/test_experiments.py``).

Persistence (DESIGN.md S10): :meth:`SimCache.persist` attaches a versioned
on-disk store (``window_cache.json`` under ``results/.simcache/`` by
default) so repeated benchmark, sweep, and CI runs start warm across
processes.  Keys are serialized as ``repr()`` of the live key — the frozen
``NocConfig`` is part of the key, so a config-field change re-keys every
entry — and the file carries a schema hash over the key layout plus the
``NocConfig``/``EnergyLedger`` field lists: any schema drift makes the
whole file invisible (cold start) instead of serving stale rows.  Saves
re-read the file and merge before an atomic replace, so concurrent
processes union their entries instead of clobbering each other.
"""
from __future__ import annotations

import atexit
import hashlib
import json
import os
import tempfile
from contextlib import contextmanager
from pathlib import Path
from typing import Hashable, Optional

try:
    import fcntl
except ImportError:                              # non-POSIX: no inter-process
    fcntl = None                                 # lock; saves may interleave

from .router import EnergyLedger, NocConfig

#: Cache key of one simulated window: (cfg, mode, window, g, p,
#: gather_flits, unicast_flits, e_pes).
WindowKey = Hashable

#: Bump when the window-key layout or the stored payload shape changes.
SCHEMA_VERSION = 1

#: Environment override for the persistent store location (see
#: EXPERIMENTS.md); CLI ``--cache-dir`` flags take precedence.
CACHE_DIR_ENV = "REPRO_SIMCACHE_DIR"

_CACHE_FILE = "window_cache.json"


def atomic_write_text(path: Path, text: str) -> None:
    """Write ``text`` to ``path`` via tempfile + ``os.replace``.

    Readers never observe a torn file; the temp file is unlinked on any
    failure.  Shared by the window store below and the plan store
    (:mod:`repro.plan.store`).
    """
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=f".{path.name}-")
    try:
        with os.fdopen(fd, "w") as fh:
            fh.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def schema_hash() -> str:
    """Hash of everything the serialized entries structurally depend on."""
    parts = (SCHEMA_VERSION,
             tuple(NocConfig.__dataclass_fields__),
             tuple(EnergyLedger.__dataclass_fields__))
    return hashlib.sha1(repr(parts).encode()).hexdigest()[:16]


class SimCache:
    """Keyed store of ``(latency_cycles, EnergyLedger)`` window results."""

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        #: Incremented on :meth:`clear`; dependent side-caches (e.g. the
        #: mapper's layer-result memo) key off it to invalidate themselves.
        self.generation = 0
        self._store: dict[WindowKey, tuple[float, EnergyLedger]] = {}
        self._disk: dict[str, tuple] = {}        # key repr -> [lat, fields]
        self._persist_dir: Optional[Path] = None
        self._persist_pid: Optional[int] = None
        self._saved_size: Optional[int] = None   # len(_store) at last save

    def get(self, key: WindowKey) -> Optional[tuple[float, EnergyLedger]]:
        if not self.enabled:
            return None
        hit = self._store.get(key)
        if hit is None and self._disk:
            row = self._disk.pop(repr(key), None)
            if row is not None:                  # promote disk row to memory
                hit = (float(row[0]), EnergyLedger.from_tuple(row[1]))
                self._store[key] = hit
                self.disk_hits += 1
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        t, ledger = hit
        return t, ledger.copy()

    def put(self, key: WindowKey, latency: float, ledger: EnergyLedger) -> None:
        if self.enabled:
            self._store[key] = (latency, ledger.copy())

    def merge(self, entries: dict[WindowKey, tuple[float, EnergyLedger]],
              ) -> int:
        """Adopt entries computed elsewhere (a pool worker's delta).

        Deterministic regardless of arrival order: keys are pure functions
        of the plan shape, so duplicate keys carry identical values.
        Returns the number of new keys.
        """
        new = 0
        for key, (latency, ledger) in entries.items():
            if key not in self._store:
                self._store[key] = (latency, ledger.copy())
                new += 1
        return new

    def export(self, keys=None) -> dict[WindowKey, tuple[float, EnergyLedger]]:
        """Snapshot entries (all, or the given keys) for cross-process merge."""
        src = self._store if keys is None else {
            k: self._store[k] for k in keys if k in self._store}
        return {k: (t, led.copy()) for k, (t, led) in src.items()}

    def clear(self) -> None:
        self.hits = self.misses = self.disk_hits = 0
        self.generation += 1
        self._store.clear()
        self._disk.clear()

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: WindowKey) -> bool:
        return key in self._store

    def stats(self) -> dict:
        looked = self.hits + self.misses
        return {"enabled": self.enabled, "entries": len(self._store),
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / looked if looked else 0.0,
                "disk_hits": self.disk_hits,
                "persist_dir": str(self._persist_dir)
                if self._persist_dir else None}

    # ------------------------------------------------------------------ #
    # Persistent store
    # ------------------------------------------------------------------ #
    def load(self, dir_path: str | Path) -> int:
        """Read the on-disk store; returns the number of rows made visible.

        A missing/corrupt file or a schema-hash mismatch loads nothing
        (cold start) — never an error.
        """
        path = Path(dir_path) / _CACHE_FILE
        try:
            doc = json.loads(path.read_text())
        except (OSError, ValueError):
            return 0
        if doc.get("schema") != schema_hash():
            return 0
        self._disk.update(doc.get("entries", {}))
        return len(doc.get("entries", {}))

    def save(self, dir_path: Optional[str | Path] = None) -> int:
        """Atomically merge in-memory entries into the on-disk store.

        The read-merge-replace sequence runs under an exclusive advisory
        file lock (``.lock`` beside the store, where ``fcntl`` exists), so
        concurrent savers serialize and genuinely union their entries;
        the write itself is tempfile + ``os.replace`` so readers never
        observe a torn file.  Returns the number of rows written.
        """
        target = Path(dir_path) if dir_path is not None else self._persist_dir
        if target is None:
            return 0
        target.mkdir(parents=True, exist_ok=True)
        if fcntl is None:                        # pragma: no cover
            return self._merge_and_replace(target)
        # Lock files are advisory rendezvous points, not artifacts: torn
        # content is irrelevant (flock works on the inode, the file stays
        # empty) and atomic replace would defeat the rendezvous.
        with open(target / (_CACHE_FILE + ".lock"), "w") as lock:  # lint: allow(non-atomic-write)
            fcntl.flock(lock, fcntl.LOCK_EX)
            try:
                return self._merge_and_replace(target)
            finally:
                fcntl.flock(lock, fcntl.LOCK_UN)

    def _merge_and_replace(self, target: Path) -> int:
        path = target / _CACHE_FILE
        entries: dict[str, tuple] = {}
        try:
            doc = json.loads(path.read_text())
            if doc.get("schema") == schema_hash():
                entries.update(doc.get("entries", {}))
        except (OSError, ValueError):
            pass
        entries.update(self._disk)               # unpromoted loaded rows
        for key, (latency, ledger) in self._store.items():
            entries[repr(key)] = (latency, ledger.as_tuple())
        atomic_write_text(
            path, json.dumps({"schema": schema_hash(), "entries": entries}))
        if target == self._persist_dir:
            self._saved_size = len(self._store)
        return len(entries)

    def persist(self, dir_path: str | Path) -> int:
        """Load-on-start + merge-on-exit against ``dir_path``.

        Registers a single atexit save guarded by PID, so forked pool
        workers (which exit via ``os._exit``) never write, and re-calls
        just retarget the directory.  Returns rows loaded.
        """
        self._persist_dir = Path(dir_path)
        loaded = self.load(self._persist_dir)
        if self._persist_pid is None:
            self._persist_pid = os.getpid()
            atexit.register(self._save_at_exit)
        return loaded

    def _save_at_exit(self) -> None:
        if self._persist_dir is None or os.getpid() != self._persist_pid:
            return
        if self._saved_size == len(self._store):
            return                               # nothing new since last save
        try:
            self.save()
        except OSError:
            pass                                 # best effort on teardown

    def persist_default_dir(self) -> str:
        """The store location honoring the environment override."""
        return os.environ.get(CACHE_DIR_ENV, os.path.join(
            "results", ".simcache"))


#: Process-wide cache consulted by ``_sim_rounds_window``.
SIM_CACHE = SimCache()


def configure(enabled: bool) -> None:
    """Globally enable/disable the window cache (clears it when disabling)."""
    SIM_CACHE.enabled = enabled
    if not enabled:
        SIM_CACHE.clear()


@contextmanager
def sim_cache_disabled():
    """Temporarily bypass the cache (ground-truth runs in tests/benchmarks)."""
    prev = SIM_CACHE.enabled
    SIM_CACHE.enabled = False
    try:
        yield
    finally:
        SIM_CACHE.enabled = prev


@contextmanager
def fresh_sim_cache():
    """Swap in an empty, non-persistent cache state (reference timings).

    Restores the previous store, counters, and persistence wiring on exit —
    the surrounding process keeps its warm cache.
    """
    saved = (SIM_CACHE.hits, SIM_CACHE.misses, SIM_CACHE.disk_hits,
             SIM_CACHE._store, SIM_CACHE._disk, SIM_CACHE._persist_dir)
    SIM_CACHE.hits = SIM_CACHE.misses = SIM_CACHE.disk_hits = 0
    SIM_CACHE._store, SIM_CACHE._disk, SIM_CACHE._persist_dir = {}, {}, None
    SIM_CACHE.generation += 1
    try:
        yield SIM_CACHE
    finally:
        (SIM_CACHE.hits, SIM_CACHE.misses, SIM_CACHE.disk_hits,
         SIM_CACHE._store, SIM_CACHE._disk, SIM_CACHE._persist_dir) = saved
        SIM_CACHE.generation += 1
