"""Plan-keyed memoization for the event-driven WS/OS round simulator.

:func:`~repro.core.noc.traffic._sim_rounds_window` replays a window of
accumulation/gather rounds whose traffic depends only on the *plan shape* —
``(cfg, mode, window, g, p, gather_flits, unicast_flits, e_pes)`` — and not
on the layer identity.  Whole-network sweeps therefore re-simulate the same
window program once per layer (ResNet-50 alone is ~53 layers, ~40 of which
share the degenerate P#=1 shape), and the paper's full Figs 7-12 evaluation
(3 workloads x 4 E values x 3 modes) repeats a handful of distinct programs
hundreds of times.

This module is the keyed cache that collapses those repeats, extending the
facade pattern of :mod:`repro.core.noc.collective.cost` (which memoizes
``plan_collective`` + ``run_program`` per collective signature) down to the
WS dataflow windows.  Invalidation is structural: :class:`NocConfig` is a
frozen dataclass and a full member of the key, so any timing/energy-constant
change hashes to a different entry — there is nothing to flush when a sweep
varies ``n``, ``e_pes`` or energy constants.

Entries store ``(latency, EnergyLedger)``.  Ledgers are mutable event-count
accumulators, so the cache keeps a private copy and hands out a fresh copy
per hit (``EnergyLedger.scaled(1.0)`` — exact for floats), keeping cached
runs bit-identical to uncached ones (see ``tests/test_experiments.py``).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Hashable, Optional

from .router import EnergyLedger

#: Cache key of one simulated window: (cfg, mode, window, g, p,
#: gather_flits, unicast_flits, e_pes).
WindowKey = Hashable


class SimCache:
    """Keyed store of ``(latency_cycles, EnergyLedger)`` window results."""

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self.hits = 0
        self.misses = 0
        self._store: dict[WindowKey, tuple[float, EnergyLedger]] = {}

    def get(self, key: WindowKey) -> Optional[tuple[float, EnergyLedger]]:
        if not self.enabled:
            return None
        hit = self._store.get(key)
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        t, ledger = hit
        return t, ledger.scaled(1.0)

    def put(self, key: WindowKey, latency: float, ledger: EnergyLedger) -> None:
        if self.enabled:
            self._store[key] = (latency, ledger.scaled(1.0))

    def clear(self) -> None:
        self.hits = self.misses = 0
        self._store.clear()

    def __len__(self) -> int:
        return len(self._store)

    def stats(self) -> dict:
        return {"enabled": self.enabled, "entries": len(self._store),
                "hits": self.hits, "misses": self.misses}


#: Process-wide cache consulted by ``_sim_rounds_window``.
SIM_CACHE = SimCache()


def configure(enabled: bool) -> None:
    """Globally enable/disable the window cache (clears it when disabling)."""
    SIM_CACHE.enabled = enabled
    if not enabled:
        SIM_CACHE.clear()


@contextmanager
def sim_cache_disabled():
    """Temporarily bypass the cache (ground-truth runs in tests/benchmarks)."""
    prev = SIM_CACHE.enabled
    SIM_CACHE.enabled = False
    try:
        yield
    finally:
        SIM_CACHE.enabled = prev
