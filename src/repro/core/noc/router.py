"""Router / network configuration and energy constants.

Timing parameters follow the paper's Table III exactly.  Energy constants are
Orion-3.0-style per-event energies (45 nm-class, pJ); the paper reports power
*ratios*, which are insensitive to the absolute scale — see EXPERIMENTS.md for
the calibration note.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


from typing import Optional


@dataclass(frozen=True)
class NocConfig:
    # ---- Table III timing ---------------------------------------------------
    n: int = 8                      # mesh width W in columns (8x8 square)
    router_cycles: int = 4          # router pipeline depth
    link_cycles: int = 1            # link traversal
    flit_bits: int = 128            # flit size
    vcs: int = 2                    # virtual channels (separate port resources)
    buffer_depth: int = 4           # flits per VC buffer
    gather_payload_bits: int = 32   # per-result payload in a gather packet

    # ---- NI / PE timing (eject->add->inject path, Fig. 4a) ------------------
    ni_cycles: int = 2              # network interface traversal (each direction)
    pe_add_cycles: int = 1          # local psum add (paper: comparable to INA add)
    mac_per_cycle: int = 1          # MACs per PE per cycle

    # ---- streaming architecture [12] ----------------------------------------
    # Two-way row streaming buses; each direction moves one flit per cycle.
    stream_buses_per_row: int = 2
    # Effective input-activation reuse on the streaming bus (row broadcast x
    # sliding-window overlap x cross-filter sharing).  Applies to WS and OS.
    ws_input_reuse: float = 64.0
    # OS weight reuse on the bus: weights are NOT stationary, so a streamed
    # weight word is only reused across the PEs of one assignment wave,
    # vs. the WS case where it is reused across all O^2 pixels.
    os_weight_reuse: float = 1.5
    # OS streaming concurrency (flits/cycle/row): [12] streams weights/inputs
    # through all row links in parallel (pipelined drop-off), so OS streaming
    # bandwidth exceeds a single bus lane.
    os_stream_bw: float = 28.0
    # How the WS-without-INA baseline returns finished results to the port:
    # "shared_gather" (one column gather packet, as with INA) or
    # "per_chain_unicast" (each chain tail ships its own result packet).
    baseline_collection: str = "shared_gather"

    # ---- Orion-3.0-style per-event energies (pJ) -----------------------------
    e_buf_write: float = 1.2        # per flit, input buffer write (per router)
    e_buf_read: float = 1.0         # per flit, input buffer read (per router)
    e_xbar: float = 0.6             # per flit, crossbar traversal (per router)
    e_arb: float = 0.2              # per packet-hop, switch/VC arbitration
    e_link: float = 2.0             # per flit, inter-router link
    e_ni: float = 4.0               # per flit, NI traversal (eject or inject)
    e_pkt_overhead: float = 6.0     # per packet (dis)assembly in the NI/PE
    e_add32: float = 0.1            # 32-bit digital add (router INA block / PE ALU)
    e_stream_bus: float = 1.6       # per flit-segment on the streaming bus (wire)
    e_mac: float = 0.8              # per MAC in the PE (common to all modes)

    # ---- mesh shape (mapper search space; DESIGN.md S9) ----------------------
    # Mesh height H in rows; None keeps the paper's square N x N.  The WS
    # placement puts chains in columns (height) and streams over rows (width),
    # so rectangular meshes trade chain capacity against column count.
    rows: Optional[int] = None

    @property
    def width(self) -> int:
        """Mesh width W (columns)."""
        return self.n

    @property
    def height(self) -> int:
        """Mesh height H (rows); equals ``n`` for the paper's square mesh."""
        return self.rows if self.rows is not None else self.n

    @property
    def num_nodes(self) -> int:
        return self.width * self.height

    @property
    def e_router_flit(self) -> float:
        return self.e_buf_write + self.e_buf_read + self.e_xbar

    def payload_flits(self, payload_bits: float) -> int:
        """Flits needed for a payload (excluding the header flit).

        Ceils on the *float* bit count: reuse-scaled payloads are fractional,
        and truncating before the ceiling division undercounts (128.5 bits
        must occupy 2 flits of 128, not 1).
        """
        return max(1, math.ceil(payload_bits / self.flit_bits))

    def unicast_flits(self, e_pes: int) -> int:
        """Unicast psum packet: header + E psum words (Table III: 2-3 flits)."""
        return 1 + self.payload_flits(e_pes * self.gather_payload_bits)

    def gather_flits(self, results: int) -> int:
        """Gather packet: header + collected results (Table III: 3/5/9 flits)."""
        return 1 + self.payload_flits(results * self.gather_payload_bits)


def cached_field_hash(self):
    """Hash of the field tuple, computed once per instance.

    ``NocConfig`` is a member of every window-cache key, so the generated
    dataclass ``__hash__`` (re-hashing 20+ fields per lookup) showed up in
    sweep profiles.  The cache lives outside the field set: invisible to
    ``repr``/``asdict``/``replace``/``__eq__``, and consistent within a
    process family (fork workers inherit the parent's hash seed).
    """
    h = self.__dict__.get("_hash_cache")
    if h is None:
        h = hash(tuple(self.__dict__[f] for f in self.__dataclass_fields__))
        object.__setattr__(self, "_hash_cache", h)
    return h


NocConfig.__hash__ = cached_field_hash


@dataclass
class EnergyLedger:
    """Event-count energy accumulator (the Orion model is event-based)."""

    flit_routers: float = 0   # flit x router traversals (buffers + crossbar)
    flit_links: float = 0     # flit x link traversals
    packet_hops: float = 0    # per-hop arbitration events
    ni_flits: float = 0       # flit x NI crossings (eject or inject direction)
    packets_built: float = 0  # packet (dis)assembly events
    router_adds: float = 0    # INA-block additions
    pe_adds: float = 0        # local PE additions (baseline path)
    stream_flit_segments: float = 0   # streaming-bus flit x segment
    macs: float = 0

    def network_energy_pj(self, cfg: NocConfig) -> float:
        """NoC energy: routers + links + NI + packetization + adders."""
        return (self.flit_routers * cfg.e_router_flit
                + self.flit_links * cfg.e_link
                + self.packet_hops * cfg.e_arb
                + self.ni_flits * cfg.e_ni
                + self.packets_built * cfg.e_pkt_overhead
                + self.router_adds * cfg.e_add32
                + self.pe_adds * cfg.e_add32)

    def energy_pj(self, cfg: NocConfig) -> float:
        """Network + streaming-bus + MAC energy."""
        return (self.network_energy_pj(cfg)
                + self.stream_flit_segments * cfg.e_stream_bus
                + self.macs * cfg.e_mac)

    def add(self, other: "EnergyLedger") -> None:
        for f in self.__dataclass_fields__:
            setattr(self, f, getattr(self, f) + getattr(other, f))

    def copy(self) -> "EnergyLedger":
        """Cheap exact copy (the hot-path alternative to ``scaled(1.0)``)."""
        return EnergyLedger(**self.__dict__)

    def as_tuple(self) -> tuple:
        """Field values in declaration order (persistent-cache payload)."""
        return tuple(self.__dict__[f] for f in self.__dataclass_fields__)

    @classmethod
    def from_tuple(cls, values) -> "EnergyLedger":
        return cls(**dict(zip(cls.__dataclass_fields__, values)))

    def scaled(self, k: float) -> "EnergyLedger":
        out = EnergyLedger()
        for f in self.__dataclass_fields__:
            setattr(out, f, getattr(self, f) * k)
        return out
