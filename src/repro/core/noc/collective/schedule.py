"""Lower collectives into time-stamped packet programs for the NoC engine.

A *program* is a list of :class:`PacketOp` with explicit dependencies; the
:mod:`engine` replays it on the discrete-event simulator.  Every collective
is planned under one of two router semantics (the paper's Fig. 4 dichotomy,
generalised from the WS gather chain to arbitrary trees):

* ``"ina"`` — collective-capable routers: operands are folded into passing
  packets by the router ALU (per-hop reduce), packets are absorbed/forked at
  tree merge nodes without leaving the network.  One packet per tree
  *segment* (maximal non-branching path).
* ``"eject_inject"`` — plain routers: every combine/fork bounces through a
  PE (eject -> local add -> inject).  The tree degenerates to its
  participant-level contraction; every logical edge is a full packet.

Supported ops: ``reduce``, ``broadcast`` (multicast), ``gather``, and
``allreduce`` in two algorithms — ``reduce_bcast`` (reduce to a root, then
multicast) and ``rs_ag`` (reduce-scatter: one chunk-tree per participant,
then an all-gather multicast per chunk).

Ops carry ``contribs``/``delivers`` metadata (which participants' operands a
packet aggregates, who receives payload) so tests can verify algebraic
correctness of a schedule without running it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

from ..router import NocConfig
from .trees import CollectiveTree, multicast_tree, reduction_tree, segments

Coord = tuple[int, int]

SEMANTICS = ("ina", "eject_inject")
ALLREDUCE_ALGORITHMS = ("reduce_bcast", "rs_ag")
COLLECTIVE_OPS = ("reduce", "broadcast", "gather", "allreduce")


@dataclass
class PacketOp:
    """One packet of a collective program.

    ``deps`` are indices of program ops that must complete before this op
    is issued (issue time = ``max(t, max(dep done) + delay)``).  ``src ==
    dst`` with ``inject=False`` models an in-router delivery (ejection of an
    already-accumulated value).  ``contribs``/``delivers`` are metadata for
    verification only and do not affect timing or energy.
    """

    src: Coord
    dst: Coord
    flits: int
    vc: int = 0
    inject: bool = True
    eject: bool = True
    reduce_words: int = 0          # in-network adds along this packet's path
    pe_adds: int = 0               # endpoint adds charged when this op issues
    extra_ni_flits: float = 0.0    # NI crossings beyond inject/eject (operand
                                   # deposits, multicast local copies)
    t: int = 0                     # earliest issue time
    deps: tuple[int, ...] = ()
    delay: int = 0                 # cycles after the last dep completes
    path: Optional[list[Coord]] = None   # route override (tree embedding)
    tag: str = ""
    chunk: int = 0
    contribs: frozenset = frozenset()
    delivers: tuple[Coord, ...] = ()


def _payload_flits(cfg: NocConfig, payload_bits: float) -> int:
    """Header + payload flits for one collective packet."""
    return 1 + cfg.payload_flits(payload_bits)


def _words(payload_bits: float, word_bits: int = 32) -> int:
    return max(1, math.ceil(payload_bits / word_bits))


# --------------------------------------------------------------------------- #
# Reduce
# --------------------------------------------------------------------------- #
def _plan_reduce_ina(prog: list[PacketOp], tree: CollectiveTree,
                     payload_bits: float, cfg: NocConfig, *, vc: int,
                     chunk: int, tag: str) -> int:
    """In-network reduce over the tree; returns the index of the final op
    (the one that ejects the fully-reduced value at the root)."""
    flits = _payload_flits(cfg, payload_bits)
    words = _words(payload_bits)
    parts = tree.participants
    segs = segments(tree)
    if not segs:                       # single-participant degenerate tree
        prog.append(PacketOp(tree.root, tree.root, 0, vc=vc,
                             inject=False, eject=False, tag=tag + ":self",
                             chunk=chunk, contribs=frozenset(parts),
                             delivers=(tree.root,)))
        return len(prog) - 1
    by_head = {s[0]: s for s in segs}
    ending_at: dict[Coord, list[Coord]] = {}
    for s in segs:
        ending_at.setdefault(s[-1], []).append(s[0])
    op_of_head: dict[Coord, int] = {}
    acc_of_head: dict[Coord, frozenset] = {}

    def emit(seg: list[Coord]) -> int:
        head, end = seg[0], seg[-1]
        if head in op_of_head:
            return op_of_head[head]
        is_leaf = head not in ending_at
        dep_idx = tuple(emit(by_head[h]) for h in ending_at.get(head, []))
        merged = frozenset().union(*(acc_of_head[h]
                                     for h in ending_at.get(head, []))) \
            if dep_idx else frozenset()
        # Adds charged to this packet: merging k absorbed child packets
        # costs k-1 adds (the first initialises the router accumulator),
        # the head's own operand costs one more, and every participant
        # router passed en route folds its operand in (the INA add).
        # Only *operand deposits* (not packet merges) cross the local NI.
        adds = deposits = 0
        acc = merged
        if is_leaf:
            acc = acc | {head}         # leaf operand seeds the packet
        else:
            adds += len(dep_idx) - 1
            if head in parts:
                adds += 1
                deposits += 1
                acc = acc | {head}
        interior = [v for v in seg[1:-1] if v in parts]
        adds += len(interior)
        deposits += len(interior)
        acc = acc | frozenset(interior)
        last = end == tree.root and len(ending_at.get(end, [])) == 1
        if last and end in parts:      # sole root arrival: root adds in-router
            adds += 1
            deposits += 1
            acc = acc | {end}
        idx = len(prog)
        prog.append(PacketOp(
            head, end, flits, vc=vc, inject=is_leaf, eject=last,
            reduce_words=adds * words,
            extra_ni_flits=deposits * payload_bits / cfg.flit_bits,
            deps=dep_idx, path=list(seg), tag=tag, chunk=chunk,
            contribs=acc, delivers=(end,) if last else ()))
        op_of_head[head] = idx
        acc_of_head[head] = acc
        return idx

    for s in segs:
        emit(s)
    root_heads = ending_at.get(tree.root, [])
    if len(root_heads) == 1:
        return op_of_head[root_heads[0]]
    # Several segments merge at the root: absorb them all, then eject the
    # accumulated value from the root router into the root PE.
    deps = tuple(op_of_head[h] for h in root_heads)
    root_contributes = tree.root in parts
    adds = len(deps) - 1 + (1 if root_contributes else 0)
    acc = frozenset().union(*(acc_of_head[h] for h in root_heads))
    if root_contributes:
        acc = acc | {tree.root}
    prog.append(PacketOp(
        tree.root, tree.root, flits, vc=vc, inject=False, eject=True,
        reduce_words=adds * words,
        extra_ni_flits=(payload_bits / cfg.flit_bits
                        if root_contributes else 0.0),
        deps=deps, tag=tag + ":eject", chunk=chunk, contribs=acc,
        delivers=(tree.root,)))
    return len(prog) - 1


def _logical_children(tree: CollectiveTree) -> dict[Coord, list[Coord]]:
    """Participant-level contraction: child participant -> nearest
    participant (or root) ancestor."""
    out: dict[Coord, list[Coord]] = {}
    for p in sorted(tree.participants | {tree.root}):
        if p == tree.root:
            continue
        v = tree.parent[p]
        while v != tree.root and v not in tree.participants:
            v = tree.parent[v]
        out.setdefault(v, []).append(p)
    return out


def _plan_reduce_eject_inject(prog: list[PacketOp], tree: CollectiveTree,
                              payload_bits: float, cfg: NocConfig, *,
                              vc: int, chunk: int, tag: str,
                              path_of=None) -> int:
    """Fig. 4(a) generalised: every logical tree edge is a full packet that
    is ejected, added at the PE, and re-injected toward the next hop.

    ``path_of(src, dst)`` (optional) supplies an explicit route override
    per logical edge — the fault-repaired planner routes every packet
    along the repaired tree instead of the default XY derivation.
    """
    flits = _payload_flits(cfg, payload_bits)
    words = _words(payload_bits)
    children = _logical_children(tree)
    parent_of = {c: par for par, kids in children.items() for c in kids}
    op_to_parent: dict[Coord, int] = {}
    acc: dict[Coord, frozenset] = {}

    def emit(v: Coord) -> Optional[int]:
        if v in op_to_parent:
            return op_to_parent[v]
        kids = children.get(v, [])
        dep_idx = tuple(i for i in (emit(c) for c in kids) if i is not None)
        a = frozenset({v} if v in tree.participants else set())
        a = a.union(*(acc[c] for c in kids)) if kids else a
        acc[v] = a
        if v == tree.root:
            return None
        # Arriving child packets are added into this PE's accumulator; the
        # last add gates the departure of the outgoing packet.
        idx = len(prog)
        prog.append(PacketOp(
            v, parent_of[v], flits, vc=vc,
            pe_adds=len(dep_idx) * words,
            deps=dep_idx, delay=cfg.pe_add_cycles if dep_idx else 0,
            path=path_of(v, parent_of[v]) if path_of else None,
            tag=tag, chunk=chunk, contribs=a))
        op_to_parent[v] = idx
        return idx

    for p in sorted(tree.participants):
        emit(p)
    root_deps = tuple(op_to_parent[c] for c in children.get(tree.root, []))
    a = acc.get(tree.root, frozenset(
        {tree.root} if tree.root in tree.participants else set()))
    a = a.union(*(acc[c] for c in children.get(tree.root, []))) \
        if children.get(tree.root) else a
    # Root-side adds: one per arriving packet, performed in the root PE.
    prog.append(PacketOp(
        tree.root, tree.root, 0, vc=vc, inject=False, eject=False,
        pe_adds=len(root_deps) * words, deps=root_deps,
        delay=cfg.pe_add_cycles, tag=tag + ":root", chunk=chunk,
        contribs=a, delivers=(tree.root,)))
    return len(prog) - 1


# --------------------------------------------------------------------------- #
# Multicast / broadcast
# --------------------------------------------------------------------------- #
def _plan_multicast_ina(prog: list[PacketOp], tree: CollectiveTree,
                        payload_bits: float, cfg: NocConfig, *, vc: int,
                        chunk: int, tag: str, contribs: frozenset,
                        deps: tuple[int, ...]) -> list[int]:
    """Tree multicast with forking routers: one packet per segment, forked
    (not ejected) at branch nodes; participants receive NI copies in
    passing.  Returns the indices of the leaf-terminal ops."""
    flits = _payload_flits(cfg, payload_bits)
    segs = segments(tree)
    parts = tree.participants
    if not segs:
        prog.append(PacketOp(tree.root, tree.root, 0, vc=vc,
                             inject=False, eject=False, deps=deps,
                             tag=tag + ":self", chunk=chunk,
                             contribs=contribs, delivers=(tree.root,)))
        return [len(prog) - 1]
    by_head = {s[0]: s for s in segs}
    op_of_head: dict[Coord, int] = {}
    finals: list[int] = []

    def emit(seg: list[Coord]) -> int:
        head, end = seg[0], seg[-1]   # flow is end -> head (root side = end)
        if head in op_of_head:
            return op_of_head[head]
        if end == tree.root:
            dep_idx = deps
            from_root = True
        else:
            dep_idx = (emit(by_head[end]),)
            from_root = False
        to_leaf = not any(s is not seg and s[-1] == head for s in segs)
        # NI copies: interior participants (and the fork node itself when it
        # participates and the packet is absorbed there) snoop the passing
        # packet through the local ejection port.
        drops = [v for v in seg[1:-1] if v in parts]
        if not to_leaf and head in parts:
            drops.append(head)
        idx = len(prog)
        prog.append(PacketOp(
            end, head, flits, vc=vc, inject=from_root,
            eject=to_leaf,
            extra_ni_flits=len(drops) * flits,
            deps=dep_idx, path=list(reversed(seg)), tag=tag, chunk=chunk,
            contribs=contribs,
            delivers=tuple(drops) + ((head,) if to_leaf else ())))
        op_of_head[head] = idx
        if to_leaf:
            finals.append(idx)
        return idx

    for s in segs:
        emit(s)
    return finals


def _plan_multicast_unicast(prog: list[PacketOp], tree: CollectiveTree,
                            payload_bits: float, cfg: NocConfig, *, vc: int,
                            chunk: int, tag: str, contribs: frozenset,
                            deps: tuple[int, ...], path_of=None) -> list[int]:
    """Multicast without router support: one unicast per destination,
    serialised through the root's injection port."""
    flits = _payload_flits(cfg, payload_bits)
    out = []
    for p in sorted(tree.participants - {tree.root}):
        prog.append(PacketOp(tree.root, p, flits, vc=vc, deps=deps,
                             path=path_of(tree.root, p) if path_of else None,
                             tag=tag, chunk=chunk, contribs=contribs,
                             delivers=(p,)))
        out.append(len(prog) - 1)
    return out


# --------------------------------------------------------------------------- #
# Gather (collection without combining; the paper's gather packet)
# --------------------------------------------------------------------------- #
def _plan_gather_ina(prog: list[PacketOp], tree: CollectiveTree,
                     result_bits: float, cfg: NocConfig, *, vc: int,
                     chunk: int, tag: str) -> int:
    """Gather-capable routers: packets collect result words in passing and
    merge at branch nodes; packet size tracks the results on board."""
    parts = tree.participants
    segs = segments(tree)
    if not segs:
        prog.append(PacketOp(tree.root, tree.root, 0, vc=vc,
                             inject=False, eject=False, tag=tag + ":self",
                             chunk=chunk, contribs=frozenset(parts),
                             delivers=(tree.root,)))
        return len(prog) - 1
    by_head = {s[0]: s for s in segs}
    ending_at: dict[Coord, list[Coord]] = {}
    for s in segs:
        ending_at.setdefault(s[-1], []).append(s[0])
    op_of_head: dict[Coord, int] = {}
    acc_of_head: dict[Coord, frozenset] = {}

    def emit(seg: list[Coord]) -> int:
        head, end = seg[0], seg[-1]
        if head in op_of_head:
            return op_of_head[head]
        dep_idx = tuple(emit(by_head[h]) for h in ending_at.get(head, []))
        acc = frozenset().union(*(acc_of_head[h]
                                  for h in ending_at.get(head, []))) \
            if dep_idx else frozenset()
        on_board = acc | frozenset(v for v in seg[:-1] if v in parts)
        # Results joining the packet cross the local NI — except the
        # root's own, which meets the payload inside its router at
        # ejection (consistent with the multi-arrival root path below).
        boarded = len(on_board) - len(acc)
        last = end == tree.root and len(ending_at.get(end, [])) == 1
        if last and end in parts:
            on_board = on_board | {end}
        flits = _payload_flits(cfg, len(on_board) * result_bits)
        idx = len(prog)
        prog.append(PacketOp(
            head, end, flits, vc=vc, inject=not dep_idx, eject=last,
            extra_ni_flits=boarded * result_bits / cfg.flit_bits,
            deps=dep_idx, path=list(seg), tag=tag, chunk=chunk,
            contribs=on_board, delivers=(end,) if last else ()))
        op_of_head[head] = idx
        acc_of_head[head] = on_board
        return idx

    for s in segs:
        emit(s)
    root_heads = ending_at.get(tree.root, [])
    if len(root_heads) == 1:
        return op_of_head[root_heads[0]]
    deps = tuple(op_of_head[h] for h in root_heads)
    acc = frozenset().union(*(acc_of_head[h] for h in root_heads))
    if tree.root in parts:
        acc = acc | {tree.root}
    flits = _payload_flits(cfg, len(acc) * result_bits)
    prog.append(PacketOp(
        tree.root, tree.root, flits, vc=vc, inject=False, eject=True,
        deps=deps, tag=tag + ":eject", chunk=chunk, contribs=acc,
        delivers=(tree.root,)))
    return len(prog) - 1


def _plan_gather_unicast(prog: list[PacketOp], tree: CollectiveTree,
                         result_bits: float, cfg: NocConfig, *, vc: int,
                         chunk: int, tag: str, path_of=None) -> int:
    """No gather support: every participant unicasts its own result packet
    to the root (the paper's ``per_chain_unicast`` baseline collection)."""
    flits = _payload_flits(cfg, result_bits)
    idxs = []
    for p in sorted(tree.participants - {tree.root}):
        prog.append(PacketOp(p, tree.root, flits, vc=vc, tag=tag,
                             path=path_of(p, tree.root) if path_of else None,
                             chunk=chunk, contribs=frozenset({p}),
                             delivers=(tree.root,)))
        idxs.append(len(prog) - 1)
    prog.append(PacketOp(tree.root, tree.root, 0, vc=vc, inject=False,
                         eject=False, deps=tuple(idxs), tag=tag + ":root",
                         chunk=chunk, contribs=frozenset(tree.participants),
                         delivers=(tree.root,)))
    return len(prog) - 1


# --------------------------------------------------------------------------- #
# Public planner
# --------------------------------------------------------------------------- #
def plan_collective(op: str, participants: Iterable[Coord],
                    payload_bits: float, cfg: NocConfig = NocConfig(), *,
                    root: Optional[Coord] = None,
                    algorithm: str = "reduce_bcast",
                    semantics: str = "ina",
                    order: str = "xy", vc: int = 0,
                    faults=None) -> list[PacketOp]:
    """Lower a collective into a packet program.

    ``payload_bits`` is the per-participant operand size (reduce/broadcast/
    allreduce) or per-participant result size (gather).  ``root`` defaults
    to the first participant.  ``algorithm`` selects the allreduce lowering;
    ``semantics`` selects router capability (see module docstring).

    ``faults`` (an optional :class:`~repro.core.noc.faults.FaultModel`)
    switches to the fault-repaired planner: trees rebuilt over the healthy
    fabric, dead participants remapped to healthy neighbors, and every
    packet carrying an explicit west-first-legal route override.  ``None``
    or an empty model takes this exact code path — bit-identical programs.
    """
    assert op in COLLECTIVE_OPS, op
    assert semantics in SEMANTICS, semantics
    if faults is not None and not faults.empty:
        return _plan_faulted(op, participants, payload_bits, cfg, root=root,
                             algorithm=algorithm, semantics=semantics,
                             vc=vc, faults=faults)
    parts = sorted(set(participants))
    assert parts, "empty participant set"
    root = parts[0] if root is None else root
    prog: list[PacketOp] = []

    if op == "reduce":
        tree = reduction_tree(root, parts, order)
        if semantics == "ina":
            _plan_reduce_ina(prog, tree, payload_bits, cfg, vc=vc, chunk=0,
                             tag="reduce")
        else:
            _plan_reduce_eject_inject(prog, tree, payload_bits, cfg, vc=vc,
                                      chunk=0, tag="reduce")
        return prog

    if op == "broadcast":
        tree = multicast_tree(root, parts, order)
        plan = _plan_multicast_ina if semantics == "ina" \
            else _plan_multicast_unicast
        plan(prog, tree, payload_bits, cfg, vc=vc, chunk=0, tag="bcast",
             contribs=frozenset({root}), deps=())
        return prog

    if op == "gather":
        tree = reduction_tree(root, parts, order)
        plan = _plan_gather_ina if semantics == "ina" \
            else _plan_gather_unicast
        plan(prog, tree, payload_bits, cfg, vc=vc, chunk=0, tag="gather")
        return prog

    # allreduce
    assert algorithm in ALLREDUCE_ALGORITHMS, algorithm
    if algorithm == "reduce_bcast":
        rtree = reduction_tree(root, parts, order)
        if semantics == "ina":
            final = _plan_reduce_ina(prog, rtree, payload_bits, cfg, vc=vc,
                                     chunk=0, tag="ar:reduce")
        else:
            final = _plan_reduce_eject_inject(prog, rtree, payload_bits, cfg,
                                              vc=vc, chunk=0, tag="ar:reduce")
        btree = multicast_tree(root, parts, order)
        plan = _plan_multicast_ina if semantics == "ina" \
            else _plan_multicast_unicast
        plan(prog, btree, payload_bits, cfg, vc=vc, chunk=0, tag="ar:bcast",
             contribs=frozenset(parts), deps=(final,))
        return prog

    # rs_ag: chunk c is reduced on a tree rooted at participant c, then
    # all-gathered by a multicast from that root.  Chunk trees have distinct
    # roots, so their traffic spreads over the mesh and overlaps in time.
    chunk_bits = payload_bits / len(parts)
    for c, r in enumerate(parts):
        rtree = reduction_tree(r, parts, order)
        if semantics == "ina":
            final = _plan_reduce_ina(prog, rtree, chunk_bits, cfg, vc=vc,
                                     chunk=c, tag=f"rs[{c}]")
        else:
            final = _plan_reduce_eject_inject(prog, rtree, chunk_bits, cfg,
                                              vc=vc, chunk=c, tag=f"rs[{c}]")
        btree = multicast_tree(r, parts, order)
        plan = _plan_multicast_ina if semantics == "ina" \
            else _plan_multicast_unicast
        plan(prog, btree, chunk_bits, cfg, vc=vc, chunk=c, tag=f"ag[{c}]",
             contribs=frozenset(parts), deps=(final,))
    return prog


# --------------------------------------------------------------------------- #
# Fault-repaired planning (DESIGN.md S15)
# --------------------------------------------------------------------------- #
def _tree_path(tree: CollectiveTree, child: Coord,
               ancestor: Coord) -> list[Coord]:
    """Nodes from ``child`` up the repaired tree to ``ancestor``
    (inclusive) — the explicit route override for a logical edge."""
    path = [child]
    v = child
    while v != ancestor:
        v = tree.parent[v]
        path.append(v)
    return path


def _plan_faulted(op: str, participants: Iterable[Coord],
                  payload_bits: float, cfg: NocConfig, *,
                  root: Optional[Coord], algorithm: str, semantics: str,
                  vc: int, faults) -> list[PacketOp]:
    """The fault-repaired lowering: same op/semantics/algorithm matrix as
    the clean planner, but trees come from a turn-restricted repair BFS,
    dead participants are remapped to healthy neighbors, and *every* packet
    (including the eject-inject unicasts that normally ride implicit XY)
    carries an explicit tree-path override — the simulator never derives a
    route that could cross a failed link.

    The whole program plans under one detour rule: west-first preferred
    (XY-compatible, minimal perturbation), falling back to up*/down* —
    which routes any connected fault pattern — when west-first's partial
    adaptivity leaves some participant unreachable.  Rules never mix
    within a program (mixing would break the per-rule deadlock argument).
    """
    from ..faults import UnroutableError
    try:
        return _plan_faulted_rule(op, participants, payload_bits, cfg,
                                  root=root, algorithm=algorithm,
                                  semantics=semantics, vc=vc, faults=faults,
                                  rule="west_first")
    except UnroutableError:
        return _plan_faulted_rule(op, participants, payload_bits, cfg,
                                  root=root, algorithm=algorithm,
                                  semantics=semantics, vc=vc, faults=faults,
                                  rule="updown")


def _plan_faulted_rule(op: str, participants: Iterable[Coord],
                       payload_bits: float, cfg: NocConfig, *,
                       root: Optional[Coord], algorithm: str,
                       semantics: str, vc: int, faults,
                       rule: str) -> list[PacketOp]:
    from ..faults import (remap_participants, remap_root,
                          repair_multicast_tree, repair_reduction_tree)
    assert not faults.transient, ("resolve transient faults with "
                                  "FaultModel.at_window() before planning")
    parts_all = sorted(set(participants))
    assert parts_all, "empty participant set"
    w, h = cfg.width, cfg.height
    healthy, _ = remap_participants(parts_all, faults, w, h)
    root = remap_root(parts_all[0] if root is None else root,
                      healthy, faults)
    prog: list[PacketOp] = []

    def up_path(tree):                    # leaf -> ancestor (reduce/gather)
        return lambda a, b: _tree_path(tree, a, b)

    def down_path(tree):                  # root -> leaf (multicast)
        return lambda a, b: list(reversed(_tree_path(tree, b, a)))

    if op == "reduce":
        tree = repair_reduction_tree(root, healthy, faults, w, h, rule)
        if semantics == "ina":
            _plan_reduce_ina(prog, tree, payload_bits, cfg, vc=vc, chunk=0,
                             tag="reduce")
        else:
            _plan_reduce_eject_inject(prog, tree, payload_bits, cfg, vc=vc,
                                      chunk=0, tag="reduce",
                                      path_of=up_path(tree))
        return prog

    if op == "broadcast":
        tree = repair_multicast_tree(root, healthy, faults, w, h, rule)
        if semantics == "ina":
            _plan_multicast_ina(prog, tree, payload_bits, cfg, vc=vc,
                                chunk=0, tag="bcast",
                                contribs=frozenset({root}), deps=())
        else:
            _plan_multicast_unicast(prog, tree, payload_bits, cfg, vc=vc,
                                    chunk=0, tag="bcast",
                                    contribs=frozenset({root}), deps=(),
                                    path_of=down_path(tree))
        return prog

    if op == "gather":
        tree = repair_reduction_tree(root, healthy, faults, w, h, rule)
        if semantics == "ina":
            _plan_gather_ina(prog, tree, payload_bits, cfg, vc=vc, chunk=0,
                             tag="gather")
        else:
            _plan_gather_unicast(prog, tree, payload_bits, cfg, vc=vc,
                                 chunk=0, tag="gather",
                                 path_of=up_path(tree))
        return prog

    # allreduce
    assert algorithm in ALLREDUCE_ALGORITHMS, algorithm
    if algorithm == "reduce_bcast":
        rtree = repair_reduction_tree(root, healthy, faults, w, h, rule)
        if semantics == "ina":
            final = _plan_reduce_ina(prog, rtree, payload_bits, cfg, vc=vc,
                                     chunk=0, tag="ar:reduce")
        else:
            final = _plan_reduce_eject_inject(prog, rtree, payload_bits,
                                              cfg, vc=vc, chunk=0,
                                              tag="ar:reduce",
                                              path_of=up_path(rtree))
        btree = repair_multicast_tree(root, healthy, faults, w, h, rule)
        if semantics == "ina":
            _plan_multicast_ina(prog, btree, payload_bits, cfg, vc=vc,
                                chunk=0, tag="ar:bcast",
                                contribs=frozenset(healthy), deps=(final,))
        else:
            _plan_multicast_unicast(prog, btree, payload_bits, cfg, vc=vc,
                                    chunk=0, tag="ar:bcast",
                                    contribs=frozenset(healthy),
                                    deps=(final,), path_of=down_path(btree))
        return prog

    # rs_ag over the *healthy* set: chunk c reduces on a repaired tree
    # rooted at healthy participant c, then all-gathers from that root.
    chunk_bits = payload_bits / len(healthy)
    for c, r in enumerate(healthy):
        rtree = repair_reduction_tree(r, healthy, faults, w, h, rule)
        if semantics == "ina":
            final = _plan_reduce_ina(prog, rtree, chunk_bits, cfg, vc=vc,
                                     chunk=c, tag=f"rs[{c}]")
        else:
            final = _plan_reduce_eject_inject(prog, rtree, chunk_bits, cfg,
                                              vc=vc, chunk=c, tag=f"rs[{c}]",
                                              path_of=up_path(rtree))
        btree = repair_multicast_tree(r, healthy, faults, w, h, rule)
        if semantics == "ina":
            _plan_multicast_ina(prog, btree, chunk_bits, cfg, vc=vc,
                                chunk=c, tag=f"ag[{c}]",
                                contribs=frozenset(healthy), deps=(final,))
        else:
            _plan_multicast_unicast(prog, btree, chunk_bits, cfg, vc=vc,
                                    chunk=c, tag=f"ag[{c}]",
                                    contribs=frozenset(healthy),
                                    deps=(final,), path_of=down_path(btree))
    return prog


# --------------------------------------------------------------------------- #
# Verification helpers (algebraic, no simulation)
# --------------------------------------------------------------------------- #
def delivered_contribs(prog: Sequence[PacketOp]) -> dict[Coord, dict[int, frozenset]]:
    """For every node that receives payload: chunk -> union of participant
    contributions delivered.  An allreduce is correct iff every participant
    maps every chunk to the full participant set."""
    out: dict[Coord, dict[int, frozenset]] = {}
    for op in prog:
        for node in op.delivers:
            cur = out.setdefault(node, {})
            cur[op.chunk] = cur.get(op.chunk, frozenset()) | op.contribs
    return out


def program_reduce_words(prog: Sequence[PacketOp]) -> int:
    return sum(op.reduce_words for op in prog)


def program_pe_adds(prog: Sequence[PacketOp]) -> int:
    return sum(op.pe_adds for op in prog)


# --------------------------------------------------------------------------- #
# The paper's WS dataflow as planner-emitted schedules (Figs. 4a/4b).
# --------------------------------------------------------------------------- #
def ws_round_program(cfg: NocConfig, mode: str, window: int, *, g: int,
                     p: int, gather_flits: int, unicast_flits: int,
                     e_pes: int = 1) -> list[PacketOp]:
    """Emit ``window`` back-to-back WS accumulation/gather rounds.

    This is the paper's fixed per-column flow expressed as a collective
    program: ``ws_ina`` / ``os_gather`` rounds are one south-riding column
    gather packet per column (with in-network accumulation of every chain
    for ``ws_ina``); ``ws_noina`` rounds run the Fig. 4(a) eject->add->
    inject relay chains first and collect the results per
    ``cfg.baseline_collection``.  Op order matches the legacy traffic
    generator exactly so link arbitration (and therefore latency/energy)
    is reproduced cycle-for-cycle.

    Rectangular meshes (mapper search space): columns are ``cfg.width``
    gather flows of ``cfg.height`` routers each; chain placement requires
    ``g * p <= cfg.height`` (the traffic planner guarantees it).
    """
    width = cfg.width
    port_row = cfg.height - 1          # per-column memory port at south edge
    prog: list[PacketOp] = []

    def gather_op(x: int, deps: tuple[int, ...]) -> PacketOp:
        ina = mode == "ws_ina"
        # Result words enter the gather payload through the tails' NIs in
        # both modes; chain operands additionally reach the INA block
        # through the local NI in the INA mode.
        extra = float(gather_flits - 1)
        if ina:
            words = g * (p - 1) * e_pes
            extra += words * cfg.gather_payload_bits / cfg.flit_bits
        return PacketOp((x, 0), (x, port_row), gather_flits, vc=1,
                        reduce_words=g * (p - 1) if ina else 0,
                        extra_ni_flits=extra, deps=deps, tag="ws:gather")

    for _ in range(window):
        for x in range(width):
            if mode == "ws_noina" and p > 1:
                tails = []
                for gi in range(g):
                    chain = [(x, gi * p + r) for r in range(p)]
                    prev: Optional[int] = None
                    for s, d in zip(chain[:-1], chain[1:]):
                        idx = len(prog)
                        prog.append(PacketOp(
                            s, d, unicast_flits, vc=0, pe_adds=1,
                            deps=(prev,) if prev is not None else (),
                            delay=cfg.pe_add_cycles if prev is not None else 0,
                            tag="ws:chain"))
                        prev = idx
                    tails.append(prev)
                deps = tuple(t for t in tails if t is not None)
                # A chain completes pe_add_cycles after its last relay
                # packet lands (the tail PE's final add); the collection
                # departs only then.
                if cfg.baseline_collection == "per_chain_unicast":
                    for gi in range(g):
                        tail = (x, gi * p + p - 1)
                        prog.append(PacketOp(tail, (x, port_row),
                                             unicast_flits, vc=1, deps=deps,
                                             delay=cfg.pe_add_cycles,
                                             tag="ws:unicast"))
                else:
                    op = gather_op(x, deps)
                    op.delay = cfg.pe_add_cycles
                    prog.append(op)
            else:
                prog.append(gather_op(x, ()))
    return prog
