"""Reduction / multicast trees over the 2D mesh (dimension-ordered).

A collective on a mesh NoC is shaped by a *tree* embedded in the topology:
reduce flows leaf->root, multicast/broadcast root->leaf, gather leaf->root
without combining.  With deterministic dimension-ordered routing the union
of the per-participant routes is always a tree:

* **reduction tree** — every participant routes to the root with XY (or YX)
  routing; because the next hop toward a fixed destination is a function of
  the current node only, each node has a unique parent.
* **multicast tree** — the root routes to every participant; paths from a
  single source under deterministic routing share prefixes and never rejoin
  after diverging.

Mesh nodes that lie on a route but are not participants become pure
forwarders (they relay/merge but contribute no operand).  The paper's WS
gather chain is the special case of a single-column participant set.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional

from ..topology import route

Coord = tuple[int, int]


@dataclass(frozen=True)
class CollectiveTree:
    """A routing tree over the mesh.

    ``parent`` maps every non-root tree node to its next hop toward the
    root; for a multicast tree the data flows against these edges.  The
    structure is shared by both directions — the scheduler decides flow.
    """

    root: Coord
    participants: frozenset[Coord]
    parent: dict[Coord, Coord] = field(hash=False)
    order: str = "xy"

    @property
    def nodes(self) -> frozenset[Coord]:
        return frozenset(self.parent) | {self.root}

    def children(self) -> dict[Coord, list[Coord]]:
        """Child lists (deterministic order: sorted by coordinate)."""
        out: dict[Coord, list[Coord]] = {v: [] for v in sorted(self.nodes)}
        for child, par in sorted(self.parent.items()):
            out[par].append(child)
        return out

    def leaves(self) -> list[Coord]:
        ch = self.children()
        return sorted(v for v in self.nodes if not ch[v])

    def depth(self, v: Coord) -> int:
        d = 0
        while v != self.root:
            v = self.parent[v]
            d += 1
        return d

    def path_to_root(self, v: Coord) -> list[Coord]:
        out = [v]
        while v != self.root:
            v = self.parent[v]
            out.append(v)
        return out

    def validate(self) -> None:
        """Tree invariants: connected, acyclic, participants covered."""
        nodes = self.nodes
        assert self.root in nodes
        assert self.root not in self.parent, "root must have no parent"
        for p in sorted(self.participants):
            assert p in nodes, f"participant {p} not reached"
        for v in self.parent:
            seen = {v}
            w = v
            while w != self.root:
                w = self.parent[w]
                assert w not in seen, f"cycle through {w}"
                seen.add(w)
        assert len(self.parent) == len(nodes) - 1


def _build(root: Coord, participants: Iterable[Coord], order: str,
           toward_root: bool) -> CollectiveTree:
    parts = frozenset(participants)
    parent: dict[Coord, Coord] = {}
    for p in sorted(parts):
        if p == root:
            continue
        # Route orientation decides the embedding: reduce uses each
        # participant's own route to the root (merging corridors), multicast
        # uses the root's route to each participant (forking corridors).
        path = route(p, root, order) if toward_root else \
            list(reversed(route(root, p, order)))
        for child, par in zip(path[:-1], path[1:]):
            prev = parent.setdefault(child, par)
            if prev != par:
                raise AssertionError(
                    f"routing produced two parents for {child}: {prev}, {par}")
    tree = CollectiveTree(root=root, participants=parts, parent=parent,
                          order=order)
    tree.validate()
    return tree


def reduction_tree(root: Coord, participants: Iterable[Coord],
                   order: str = "xy") -> CollectiveTree:
    """Dimension-ordered reduction tree: participants route *to* the root."""
    return _build(root, participants, order, toward_root=True)


def multicast_tree(root: Coord, participants: Iterable[Coord],
                   order: str = "xy") -> CollectiveTree:
    """Dimension-ordered multicast tree: the root routes to each participant."""
    return _build(root, participants, order, toward_root=False)


# --------------------------------------------------------------------------- #
# Participant-set helpers (DSE sweeps use these)
# --------------------------------------------------------------------------- #
def full_mesh(n: int) -> list[Coord]:
    return [(x, y) for y in range(n) for x in range(n)]


def mesh_row(n: int, y: int) -> list[Coord]:
    return [(x, y) for x in range(n)]


def mesh_column(n: int, x: int) -> list[Coord]:
    return [(x, y) for y in range(n)]


def segments(tree: CollectiveTree) -> list[list[Coord]]:
    """Maximal non-branching paths of the tree, listed in leaf->root node
    order.  Collective packets travel one segment at a time: they are
    combined (reduce/gather) or forked (multicast) at segment boundaries,
    which are exactly the merge nodes (>= 2 children) and the root.

    Every leaf and every merge node heads exactly one segment; a segment
    runs toward the root until the next merge node or the root (inclusive).
    """
    ch = tree.children()
    breaks = {v for v, c in ch.items() if len(c) >= 2}
    heads = (set(tree.leaves()) | breaks) - {tree.root}
    segs = []
    for h in sorted(heads):
        seg = [h]
        v = h
        while v != tree.root:
            v = tree.parent[v]
            seg.append(v)
            if v in breaks:
                break
        segs.append(seg)
    return segs
