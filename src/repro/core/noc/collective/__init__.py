"""Collective-capable NoC: in-network reduce/multicast trees as a subsystem.

Layers:

* :mod:`trees`    — XY-/YX-ordered reduction & multicast trees over the mesh
  for any participant set (full mesh, row, column, arbitrary subset).
* :mod:`schedule` — lowers reduce / broadcast / gather / allreduce into
  time-stamped packet programs under in-network-accumulate or
  eject->add->inject router semantics; also emits the paper's WS rounds.
* :mod:`engine`   — replays programs on the discrete-event simulator with
  dependency resolution; returns latency + energy.
* :mod:`cost`     — cached cost facade consumed by ``core.collectives`` and
  ``parallel.tp`` (simulated-mesh PsumMode selection).
"""
from .cost import CollectiveCost, choose_psum_mode, collective_cost, psum_mode_costs
from .engine import ProgramResult, run_program
from .schedule import (ALLREDUCE_ALGORITHMS, COLLECTIVE_OPS, SEMANTICS,
                       PacketOp, delivered_contribs, plan_collective,
                       ws_round_program)
from .trees import (CollectiveTree, full_mesh, mesh_column, mesh_row,
                    multicast_tree, reduction_tree, segments)

__all__ = [
    "ALLREDUCE_ALGORITHMS", "COLLECTIVE_OPS", "SEMANTICS",
    "CollectiveCost", "CollectiveTree", "PacketOp", "ProgramResult",
    "choose_psum_mode", "collective_cost", "delivered_contribs",
    "full_mesh", "mesh_column", "mesh_row", "multicast_tree",
    "plan_collective", "psum_mode_costs", "reduction_tree", "run_program",
    "segments", "ws_round_program",
]
