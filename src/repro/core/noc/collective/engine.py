"""Replay collective packet programs on the discrete-event NoC simulator.

The engine resolves :class:`~.schedule.PacketOp` dependencies at run time:
an op is enqueued when all its ``deps`` have completed, at ``max(op.t,
latest dep completion + op.delay)``.  Dependency-free ops are enqueued in
program order, so two programs that list the same packets in the same order
arbitrate identically (heap ties break by enqueue sequence) — this is what
lets the WS+INA schedule emitted by the planner reproduce the legacy
traffic generator cycle-for-cycle.

Virtual ops (``flits == 0``, no inject/eject) are synchronisation points:
they complete at their issue time without touching the network.

Three executors share these semantics (DESIGN.md S10/S16): the
closure-based heap engine below (the ground truth, fully general), the
compiled flat-array replay of :mod:`repro.core.noc.compiled`, and the
vectorized wavefront kernel of :mod:`repro.core.noc.vectorized`
(contention-free DAG programs only).  ``run_program`` dispatches
vectorized -> compiled -> heap when the program is encodable and no
external simulator was supplied; results are bit-identical (latency,
done times, deliveries, and the full ledger), enforced by
``tests/test_perf_layer.py`` and ``tests/test_vectorized.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from ..compiled import (UncompilableProgram, compile_program,
                        compiled_enabled)
from ..router import EnergyLedger, NocConfig
from ..simulator import NocSim
from ..vectorized import (UnvectorizableProgram, run_vectorized,
                          vectorized_enabled)
from .schedule import PacketOp


@dataclass
class ProgramResult:
    """Outcome of one program replay."""

    latency_cycles: int            # completion time of the last op
    ledger: EnergyLedger           # event counts (shared with the sim)
    done: list                     # per-op completion times
    delivered: dict                # node -> cycle its payload landed (the
                                   # earliest tail arrival; mid-segment
                                   # multicast drops land before segment end)

    def network_energy_pj(self, cfg: NocConfig) -> float:
        return self.ledger.network_energy_pj(cfg)


def run_program(prog: Sequence[PacketOp], cfg: Optional[NocConfig] = None,
                *, sim: Optional[NocSim] = None, t0: int = 0,
                engine: str = "auto", verify: bool = False) -> ProgramResult:
    """Execute ``prog`` on ``sim`` (or a fresh simulator) and return the
    makespan, per-op completion times, and the energy ledger.

    ``engine`` selects the executor: ``"auto"`` tries the vectorized
    wavefront kernel, then the compiled flat-array path (both
    bit-identical, no per-op closures); ``"heap"`` forces the
    ground-truth engine below.  A caller supplied ``sim`` always uses the
    heap engine (the caller owns the simulator's ledger and resource
    state).  ``verify=True`` runs the static checks (``repro.analysis``:
    DAG/route/CDG) first and raises ``VerificationError`` instead of
    simulating a broken program.
    """
    if verify:
        from repro.analysis.verify import check_program
        check_program(prog, cfg)
    if sim is None and engine == "auto" and compiled_enabled():
        if vectorized_enabled():
            try:
                latency, ledger, done, delivered = run_vectorized(
                    prog, cfg if cfg is not None else NocConfig())
                return ProgramResult(latency_cycles=latency, ledger=ledger,
                                     done=done, delivered=delivered)
            except UnvectorizableProgram:
                pass                    # attributed in VECTOR_STATS
        try:
            cp = compile_program(prog, cfg if cfg is not None else NocConfig())
        except UncompilableProgram:
            cp = None
        if cp is not None:
            latency, ledger, done, delivered = cp.run(t0)
            return ProgramResult(latency_cycles=latency, ledger=ledger,
                                 done=done, delivered=delivered)
    if sim is None:
        sim = NocSim(cfg if cfg is not None else NocConfig())
    n = len(prog)
    children: list[list[int]] = [[] for _ in range(n)]
    remaining = [len(op.deps) for op in prog]
    for i, op in enumerate(prog):
        for d in op.deps:
            assert 0 <= d < i, f"op {i} depends on non-prior op {d}"
            children[d].append(i)
    done: list[Optional[int]] = [None] * n
    delivered: dict = {}

    def deliver(node, t: int) -> None:
        if node not in delivered or t < delivered[node]:
            delivered[node] = t

    def issue(i: int, t: int) -> None:
        op = prog[i]
        sim.ledger.pe_adds += op.pe_adds
        sim.ledger.ni_flits += op.extra_ni_flits
        if op.flits == 0 and not op.inject and not op.eject:
            complete(i, t)                     # virtual synchronisation op
            return
        # In-passing deliveries (multicast drops at participant routers)
        # land when the packet tail clears the router, before the segment
        # completes; the per-hop hook timestamps them.
        midway = set(op.delivers) - {op.dst}
        on_hop = (lambda node, th, f=op.flits:
                  deliver(node, th + f - 1) if node in midway else None) \
            if midway else None
        sim.enqueue(t, op.src, op.dst, op.flits, vc=op.vc,
                    inject=op.inject, eject=op.eject,
                    reduce_words=op.reduce_words, path=op.path,
                    on_hop=on_hop,
                    on_done=lambda td, i=i: complete(i, td))

    def complete(i: int, td: int) -> None:
        done[i] = td
        for node in prog[i].delivers:
            if node == prog[i].dst or prog[i].flits == 0:
                deliver(node, td)
        for j in children[i]:
            remaining[j] -= 1
            if remaining[j] == 0:
                op = prog[j]
                t = max([t0 + op.t] + [done[d] for d in op.deps]) + op.delay
                issue(j, t)

    for i, op in enumerate(prog):
        if not op.deps:
            issue(i, t0 + op.t)
    makespan = sim.run()
    stuck = [i for i, d in enumerate(done) if d is None]
    assert not stuck, f"deadlocked ops (circular/unmet deps): {stuck}"
    return ProgramResult(latency_cycles=max([makespan] + done),
                         ledger=sim.ledger, done=done, delivered=delivered)
