"""Cost-model facade: simulated mesh latency/energy for collectives.

This is the bridge between the NoC subsystem and the JAX side:
``core.collectives`` / ``parallel.tp`` ask *"what would this psum cost on
the mesh?"* and get numbers from the same event-driven simulator that
reproduces the paper's Figs. 7-12, instead of hand-derived per-link traffic
formulas.  Results are cached — programs for a given (op, participants,
payload, semantics) are deterministic.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Iterable, Optional

from ..router import EnergyLedger, NocConfig
from ..simcache import SIM_CACHE
from .engine import run_program
from .schedule import plan_collective
from .trees import full_mesh, mesh_row

Coord = tuple[int, int]

#: How each JAX-side psum mode maps onto a mesh collective.
#:
#: ``"xla"`` deliberately aliases ``"ina"``: XLA's native ``psum`` lowers to
#: the same in-network reduce+broadcast schedule on the wire — the only
#: difference is whether the algorithm is visible in the HLO.  The alias
#: means ``mode="auto"`` can never *prefer* XLA over INA on simulated cost
#: (their costs are identical by construction), which is why
#: :data:`AUTO_CANDIDATES` drops ``"xla"`` from the argmin entirely instead
#: of comparing four candidates.  ``tests/test_plan.py`` pins both the
#: alias and the candidate set.
PSUM_MODE_LOWERING = {
    "eject_inject": ("reduce_bcast", "eject_inject"),
    "ina_ring": ("rs_ag", "ina"),
    "ina": ("reduce_bcast", "ina"),
    "xla": ("reduce_bcast", "ina"),
}

#: The strategies ``mode="auto"`` actually compares (tie-break order).
#: ``"xla"`` is excluded: it shares ``"ina"``'s lowering (see above), so
#: including it would only shadow the INA fast path with an equal-cost
#: duplicate that hides the algorithm from the HLO.
AUTO_CANDIDATES = ("ina", "ina_ring", "eject_inject")

#: Observable simulation effort, in the style of ``topology.ROUTE_STATS``:
#: ``engine_runs`` counts actual event-driven program executions (the
#: expensive part), ``store_hits`` counts runs avoided by the
#: :data:`~repro.core.noc.simcache.SIM_CACHE` store (in-memory or
#: persistent), ``memo_hits`` counts per-process ``lru_cache`` returns
#: (tracked by :func:`collective_cost` — the lru layer never re-enters
#: ``_simulate``'s body).  Regression tests assert on deltas of these.
COST_STATS = {"engine_runs": 0, "store_hits": 0, "memo_hits": 0}


@dataclass(frozen=True)
class CollectiveCost:
    """Simulated cost of one collective on the mesh."""

    op: str
    algorithm: str
    semantics: str
    n: int                      # mesh dimension
    participants: int
    payload_bits: float
    latency_cycles: int
    energy_pj: float
    packets: int
    #: Per-event breakdown (a private copy).  Excluded from eq/hash: the
    #: ledger is mutable and fully determined by the other fields, and
    #: CollectiveCost instances must stay hashable (set/dict-key use).
    ledger: Optional[EnergyLedger] = dataclasses.field(default=None,
                                                       compare=False)

    @property
    def power_pj_per_cycle(self) -> float:
        return self.energy_pj / max(self.latency_cycles, 1)


@lru_cache(maxsize=4096)
def _simulate(op: str, parts: tuple[Coord, ...], payload_bits: float,
              cfg: NocConfig, root: Optional[Coord], algorithm: str,
              semantics: str, order: str,
              ) -> tuple[int, float, int, EnergyLedger]:
    # Planning (cheap, O(program ops)) runs even on a store hit: the
    # packets count is derived from the program, and the store's value
    # shape is fixed at (latency, ledger).  Bounded cost — the lru above
    # means once per distinct signature per process.
    prog = plan_collective(op, parts, payload_bits, cfg, root=root,
                           algorithm=algorithm, semantics=semantics,
                           order=order)
    packets = sum(1 for o in prog if o.flits)
    # The event-driven run (the expensive part) rides the PR-4 persistent
    # window store: collective signatures key ``SIM_CACHE`` under a
    # ``"collective"`` tag, so repeated processes (dry-run, plan builds,
    # sweeps) replay nothing the store already holds.  Latency and energy
    # reconstruct exactly from the stored (latency, ledger) pair — energy is
    # a pure function of ledger counts and ``cfg`` constants.
    key = ("collective", op, parts, payload_bits, cfg, root, algorithm,
           semantics, order)
    hit = SIM_CACHE.get(key)
    if hit is not None:
        COST_STATS["store_hits"] += 1
        latency, ledger = hit
        return (int(latency), ledger.network_energy_pj(cfg), packets, ledger)
    COST_STATS["engine_runs"] += 1
    res = run_program(prog, cfg)
    SIM_CACHE.put(key, float(res.latency_cycles), res.ledger)
    # Keep a private EnergyLedger.copy(): the cached tuple must never alias
    # a ledger a caller can mutate.
    return (res.latency_cycles, res.network_energy_pj(cfg),
            packets, res.ledger.copy())


@lru_cache(maxsize=2048)
def _simulate_faulted(op: str, parts: tuple[Coord, ...], payload_bits: float,
                      cfg: NocConfig, root: Optional[Coord], algorithm: str,
                      semantics: str, order: str, faults,
                      ) -> tuple[int, float, int, EnergyLedger]:
    """Fault-repaired twin of :func:`_simulate` under a distinct store tag.

    The FaultModel is frozen/hashable so it rides the lru key directly, and
    its normalized ``key()`` joins the SIM_CACHE signature — one fault set
    can never replay another's (or the clean mesh's) stored runs.
    """
    prog = plan_collective(op, parts, payload_bits, cfg, root=root,
                           algorithm=algorithm, semantics=semantics,
                           order=order, faults=faults)
    packets = sum(1 for o in prog if o.flits)
    key = ("collective-faulted", op, parts, payload_bits, cfg, root,
           algorithm, semantics, order, faults.key())
    hit = SIM_CACHE.get(key)
    if hit is not None:
        COST_STATS["store_hits"] += 1
        latency, ledger = hit
        return (int(latency), ledger.network_energy_pj(cfg), packets, ledger)
    COST_STATS["engine_runs"] += 1
    res = run_program(prog, cfg)
    SIM_CACHE.put(key, float(res.latency_cycles), res.ledger)
    return (res.latency_cycles, res.network_energy_pj(cfg),
            packets, res.ledger.copy())


def collective_cost(op: str, payload_bits: float,
                    cfg: NocConfig = NocConfig(), *,
                    participants: Optional[Iterable[Coord]] = None,
                    root: Optional[Coord] = None,
                    algorithm: str = "reduce_bcast",
                    semantics: str = "ina",
                    order: str = "xy", faults=None) -> CollectiveCost:
    """Plan + simulate one collective; ``participants`` defaults to the
    full ``cfg.n`` x ``cfg.n`` mesh.  ``payload_bits`` is per participant.

    ``faults`` (an optional :class:`~repro.core.noc.faults.FaultModel`)
    prices the fault-repaired program instead; ``None`` or an empty model
    takes the exact unfaulted path — same memo, same store keys.
    """
    parts = tuple(sorted(participants)) if participants is not None \
        else tuple(full_mesh(cfg.n))
    if faults is not None and not faults.empty:
        memo_before = _simulate_faulted.cache_info().hits
        lat, energy, packets, ledger = _simulate_faulted(
            op, parts, float(payload_bits), cfg, root, algorithm,
            semantics, order, faults)
        if _simulate_faulted.cache_info().hits > memo_before:
            COST_STATS["memo_hits"] += 1
    else:
        memo_before = _simulate.cache_info().hits
        lat, energy, packets, ledger = _simulate(op, parts,
                                                 float(payload_bits),
                                                 cfg, root, algorithm,
                                                 semantics, order)
        if _simulate.cache_info().hits > memo_before:
            COST_STATS["memo_hits"] += 1
    return CollectiveCost(op=op, algorithm=algorithm, semantics=semantics,
                          n=cfg.n, participants=len(parts),
                          payload_bits=float(payload_bits),
                          latency_cycles=lat, energy_pj=energy,
                          packets=packets, ledger=ledger.copy())


# --------------------------------------------------------------------------- #
# psum-mode facade for the JAX side (a TP axis modelled as one mesh row)
# --------------------------------------------------------------------------- #
def _row_cfg(p: int, cfg: NocConfig) -> NocConfig:
    return cfg if cfg.n >= p else dataclasses.replace(cfg, n=p)


def psum_mode_costs(p: int, nbytes: int,
                    cfg: NocConfig = NocConfig()) -> dict[str, CollectiveCost]:
    """Simulated allreduce cost for every PsumMode over a ``p``-device TP
    axis, embedded as one mesh row (the ring of the paper's datacenter
    analogue laid out on NoC links)."""
    if p <= 1:
        zero = CollectiveCost("allreduce", "none", "none", cfg.n, 1,
                              nbytes * 8, 0, 0.0, 0)
        return {m: zero for m in PSUM_MODE_LOWERING}
    rcfg = _row_cfg(p, cfg)
    parts = mesh_row(p, 0)[:p]
    out = {}
    for mode, (algorithm, semantics) in PSUM_MODE_LOWERING.items():
        out[mode] = collective_cost(
            "allreduce", nbytes * 8, rcfg, participants=parts,
            algorithm=algorithm, semantics=semantics)
    return out


def choose_psum_mode(p: int, nbytes: int, cfg: NocConfig = NocConfig(),
                     objective: str = "latency") -> str:
    """Pick the PsumMode with the best simulated mesh cost.

    ``objective`` is ``"latency"`` or ``"energy"``.  The argmin runs over
    :data:`AUTO_CANDIDATES` only — ``"xla"`` is excluded because its
    lowering *is* ``"ina"``'s (see :data:`PSUM_MODE_LOWERING`): simulating
    it would compare two identical schedules and could only ever shadow the
    INA fast path.  Ties resolve toward the INA fast path (candidate
    order).
    """
    if p <= 1:
        return "ina"
    costs = psum_mode_costs(p, nbytes, cfg)
    key = (lambda c: c.latency_cycles) if objective == "latency" \
        else (lambda c: c.energy_pj)
    return min(AUTO_CANDIDATES,
               key=lambda m: (key(costs[m]), AUTO_CANDIDATES.index(m)))
