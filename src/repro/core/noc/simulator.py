"""Discrete-event wormhole mesh simulator with time-ordered link arbitration.

Each directed link (and each node's per-VC injection/ejection port) is a
resource with a busy-until time.  Packets are processed as events ordered by
ready time (a heap), so arbitration between flows happens in *time* order —
a late-issued gather packet cannot retroactively block an earlier relay
packet of the next round, matching real router behaviour.  A packet of
``flits`` flits holds each traversed link for ``flits`` cycles (wormhole
serialization); the head flit pays ``router_cycles + link_cycles`` per hop
plus contention wait; the tail arrives ``flits - 1`` cycles after the head.
The two VCs of the paper's Table III are modeled as separate injection/
ejection port resources (gather rides VC1, unicast/relay VC0).

Energy is counted per event into an :class:`EnergyLedger` (Orion-style):
router traversals (buffer write/read + crossbar) per flit per router
(links + 1 routers per path), links per flit per link, NI crossings per flit,
and packet (dis)assembly per endpoint.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

from .router import EnergyLedger, NocConfig
from .topology import links_of, xy_route

Coord = tuple[int, int]


@dataclass
class _Packet:
    src: Coord
    dst: Coord
    flits: int
    vc: int
    inject: bool
    eject: bool
    reduce_words: int
    on_hop: Optional[Callable[[Coord, int], None]]
    on_done: Optional[Callable[[int], None]]
    links: list = field(default_factory=list)
    stage: int = -1          # -1 = inject, 0..len(links)-1 = hop i, len = eject
    head: int = 0


class NocSim:
    """Event-driven simulator; create, enqueue packets, then ``run()``."""

    def __init__(self, cfg: NocConfig):
        self.cfg = cfg
        self.link_free: dict[tuple[Coord, Coord], int] = {}
        self.port_free: dict[tuple[str, int, Coord], int] = {}
        self.ledger = EnergyLedger()
        self._heap: list = []
        self._seq = itertools.count()
        self.now = 0

    # ------------------------------------------------------------------ #
    def enqueue(self, t: int, src: Coord, dst: Coord, flits: int, *,
                vc: int = 0, inject: bool = True, eject: bool = True,
                reduce_words: int = 0,
                on_hop: Optional[Callable[[Coord, int], None]] = None,
                on_done: Optional[Callable[[int], None]] = None,
                path: Optional[list] = None) -> None:
        """Schedule a packet to become ready at time ``t``.

        ``reduce_words`` is the generic in-network reduce count: the number
        of operand words folded into this packet by router ALUs along its
        path (the INA block of the paper, the gather/reduce units of
        collective-capable routers).  ``on_hop(node, t_head)`` fires as the
        head flit enters each traversed router — the collective engine uses
        it to timestamp in-passing payload deliveries (multicast drops).
        ``path`` overrides the XY route (must start at ``src`` and end at
        ``dst``).
        """
        pkt = _Packet(src, dst, flits, vc, inject, eject, reduce_words,
                      on_hop, on_done)
        pkt.links = links_of(path if path is not None else xy_route(src, dst))
        pkt.stage = -1 if inject else 0
        pkt.head = t
        # Energy that is path-determined (independent of contention):
        self.ledger.flit_routers += flits * (len(pkt.links) + 1)
        self.ledger.flit_links += flits * len(pkt.links)
        self.ledger.packet_hops += len(pkt.links)
        self.ledger.router_adds += reduce_words
        if inject:
            self.ledger.ni_flits += flits
            self.ledger.packets_built += 1
        if eject:
            self.ledger.ni_flits += flits
            self.ledger.packets_built += 1
        self._push(t, pkt)

    def _push(self, t: int, pkt: _Packet) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), pkt))

    # ------------------------------------------------------------------ #
    def run(self) -> int:
        """Process all events; returns the makespan (last completion time)."""
        cfg = self.cfg
        makespan = 0
        while self._heap:
            t, _, pkt = heapq.heappop(self._heap)
            self.now = max(self.now, t)

            if pkt.stage == -1:                          # injection port
                key = ("inj", pkt.vc, pkt.src)
                free = self.port_free.get(key, 0)
                if free > t:
                    self._push(free, pkt)
                    continue
                self.port_free[key] = t + pkt.flits
                pkt.head = t + cfg.ni_cycles
                pkt.stage = 0
                self._push(pkt.head, pkt)
                continue

            if pkt.stage < len(pkt.links):               # link hop
                link = pkt.links[pkt.stage]
                ready = pkt.head + cfg.router_cycles
                free = self.link_free.get(link, 0)
                if free > ready:
                    pkt.head = free - cfg.router_cycles
                    self._push(free, pkt)
                    continue
                self.link_free[link] = ready + pkt.flits
                pkt.head = ready + cfg.link_cycles
                pkt.stage += 1
                if pkt.on_hop is not None:
                    pkt.on_hop(link[1], pkt.head)
                self._push(pkt.head, pkt)
                continue

            # ejection (or in-router completion when eject=False)
            if pkt.eject:
                key = ("ej", pkt.vc, pkt.dst)
                ready = pkt.head + cfg.router_cycles
                free = self.port_free.get(key, 0)
                if free > ready:
                    pkt.head = free - cfg.router_cycles
                    self._push(free, pkt)
                    continue
                self.port_free[key] = ready + pkt.flits
                done = ready + cfg.ni_cycles + pkt.flits - 1
            else:
                done = pkt.head + pkt.flits - 1
            makespan = max(makespan, done)
            if pkt.on_done is not None:
                pkt.on_done(done)
        return makespan

    # ------------------------------------------------------------------ #
    def chain_eject_inject(self, t: int, chain: list[Coord], flits: int,
                           on_done: Optional[Callable[[int], None]] = None,
                           ) -> None:
        """Fig. 4(a): psum relayed PE->PE, ejected/added/re-injected per stop.

        ``on_done(t)`` fires when the accumulated psum rests in the tail PE.
        """
        cfg = self.cfg
        hops = list(zip(chain[:-1], chain[1:]))

        def launch(i: int, t_ready: int) -> None:
            if i == len(hops):
                if on_done:
                    on_done(t_ready)
                return
            src, dst = hops[i]
            self.ledger.pe_adds += 1
            self.enqueue(t_ready, src, dst, flits, vc=0, inject=True,
                         eject=True,
                         on_done=lambda td: launch(i + 1, td + cfg.pe_add_cycles))

        launch(0, t)
