"""Discrete-event wormhole mesh simulator with time-ordered link arbitration.

Each directed link (and each node's per-VC injection/ejection port) is a
resource with a busy-until time.  Packets are processed as events ordered by
ready time (a heap), so arbitration between flows happens in *time* order —
a late-issued gather packet cannot retroactively block an earlier relay
packet of the next round, matching real router behaviour.  A packet of
``flits`` flits holds each traversed link for ``flits`` cycles (wormhole
serialization); the head flit pays ``router_cycles + link_cycles`` per hop
plus contention wait; the tail arrives ``flits - 1`` cycles after the head.
The two VCs of the paper's Table III are modeled as separate injection/
ejection port resources (gather rides VC1, unicast/relay VC0).

Energy is counted per event into an :class:`EnergyLedger` (Orion-style):
router traversals (buffer write/read + crossbar) per flit per router
(links + 1 routers per path), links per flit per link, NI crossings per flit,
and packet (dis)assembly per endpoint.

Resource state is held in int-indexed flat arrays sized from the
:class:`NocConfig` mesh (4 directed links per node, ``2 * vcs`` ports per
node) rather than tuple-keyed dicts, and per-packet routes/link ids are
memoized per ``(width, height, src, dst)`` — ``enqueue`` no longer derives
a route or allocates per packet (DESIGN.md S10).  Coordinates outside the
configured mesh (or non-unit path steps) transparently fall back to a
keyed overflow dict, preserving the pre-PR-4 "any coordinate" semantics.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional

from .router import EnergyLedger, NocConfig
from .topology import route_links

Coord = tuple[int, int]

#: Direction codes for the 4 outgoing links of a node (E, W, S, N).
_DIRS = {(1, 0): 0, (-1, 0): 1, (0, 1): 2, (0, -1): 3}

#: Per-mesh-shape link-id memo: ``(width, height) -> {(src, dst) | path:
#: (strict_ids, mixed_ids, links)}``.  Keying per shape keeps a multi-chip
#: hierarchy sweep (many shapes alive at once: chip meshes, package grids)
#: from evicting the flat mesh's hot set, and gives per-shape derivation
#: stats the hierarchy regression tests assert on.  Each shape's table is
#: FIFO-bounded at :data:`LINK_ID_CACHE_MAX` entries.
_LINK_ID_CACHE: dict = {}

#: Per-shape observability: ``(width, height) -> {"derived", "evicted"}``.
LINK_ID_STATS: dict = {}

LINK_ID_CACHE_MAX = 1 << 15


def _shape_cache(width: int, height: int) -> dict:
    shape = (width, height)
    cache = _LINK_ID_CACHE.get(shape)
    if cache is None:
        cache = _LINK_ID_CACHE[shape] = {}
        LINK_ID_STATS.setdefault(shape, {"derived": 0, "evicted": 0})
    return cache


def _shape_put(width: int, height: int, cache: dict, key, value):
    stats = LINK_ID_STATS[(width, height)]
    stats["derived"] += 1
    cache[key] = value
    while len(cache) > LINK_ID_CACHE_MAX:
        del cache[next(iter(cache))]          # FIFO: dict keeps insert order
        stats["evicted"] += 1
    return value


def clear_link_caches() -> None:
    """Drop every shape's link-id table (stats are cumulative)."""
    _LINK_ID_CACHE.clear()


def encode_links_mixed(links, width: int, height: int) -> tuple:
    """Per-link encoding: the flat int id for in-mesh unit steps, the raw
    coord-pair key for anything else.  Encoding per *link* (not per
    packet) keeps contention exact when exotic and in-mesh packets share
    a physical link — the same link always resolves to the same resource
    slot, whichever packet traverses it."""
    out = []
    for link in links:
        (ax, ay), (bx, by) = link
        d = _DIRS.get((bx - ax, by - ay))
        if d is None or not (0 <= ax < width and 0 <= ay < height
                             and 0 <= bx < width and 0 <= by < height):
            out.append(link)
        else:
            out.append((ay * width + ax) * 4 + d)
    return tuple(out)


def encode_links(links, width: int, height: int) -> Optional[tuple[int, ...]]:
    """All-flat int ids for directed links; None if any link is exotic
    (the strict form the compiled engine requires)."""
    mixed = encode_links_mixed(links, width, height)
    return mixed if all(type(x) is int for x in mixed) else None


def route_link_ids(width: int, height: int, src: Coord, dst: Coord):
    """Memoized ``(strict_ids, mixed_ids, links)`` of the XY route on a
    W x H mesh.  ``strict_ids`` is None when any hop is unencodable (the
    compiled engine falls back to heap); ``mixed_ids`` always resolves,
    per link, to either a flat index or an overflow key."""
    cache = _shape_cache(width, height)
    key = (src, dst)
    hit = cache.get(key)
    if hit is None:
        hit = _shape_put(width, height, cache, key,
                         _encode_entry(route_links(src, dst), width, height))
    return hit


def path_link_ids(width: int, height: int, path: tuple[Coord, ...]):
    """Memoized ``(strict_ids, mixed_ids, links)`` of a path override."""
    cache = _shape_cache(width, height)
    # Tagged key: a two-node override (src, dst) must not alias the XY
    # route entry for the same endpoints (express links are non-XY).
    key = ("path", path)
    hit = cache.get(key)
    if hit is None:
        hit = _shape_put(
            width, height, cache, key,
            _encode_entry(tuple(zip(path[:-1], path[1:])), width, height))
    return hit


def _encode_entry(links, width: int, height: int) -> tuple:
    mixed = encode_links_mixed(links, width, height)
    strict = mixed if all(type(x) is int for x in mixed) else None
    return (strict, mixed, links)


def port_index(kind: int, vc: int, node: Coord, width: int, height: int,
               vcs: int) -> Optional[int]:
    """Flat index of an injection (kind 0) / ejection (kind 1) port.

    The single definition both engines share — the compiled executor's
    bit-identity contract requires the heap simulator and
    :mod:`repro.core.noc.compiled` to agree on the port/link layout.
    Returns None when the node/VC falls outside the configured mesh.
    """
    x, y = node
    if 0 <= x < width and 0 <= y < height and 0 <= vc < vcs:
        return (kind * vcs + vc) * (width * height) + y * width + x
    return None


def effective_vcs(cfg: NocConfig) -> int:
    """Port-array VC dimension (>= 2: gather always rides VC1)."""
    return max(cfg.vcs, 2)


def link_array_size(cfg: NocConfig) -> int:
    """4 directed links per node (E/W/S/N)."""
    return 4 * cfg.width * cfg.height


def port_array_size(cfg: NocConfig) -> int:
    """2 (inj/ej) x VCs ports per node."""
    return 2 * effective_vcs(cfg) * cfg.width * cfg.height


class _Packet:
    __slots__ = ("src", "dst", "flits", "vc", "inject", "eject",
                 "reduce_words", "on_hop", "on_done", "links", "link_ids",
                 "inj_port", "ej_port", "stage", "head")

    def __init__(self, src, dst, flits, vc, inject, eject, reduce_words,
                 on_hop, on_done):
        self.src = src
        self.dst = dst
        self.flits = flits
        self.vc = vc
        self.inject = inject
        self.eject = eject
        self.reduce_words = reduce_words
        self.on_hop = on_hop
        self.on_done = on_done
        self.links = ()
        self.link_ids: tuple = ()   # per link: flat int id or overflow key
        self.inj_port = None     # int index, or tuple key in the overflow dict
        self.ej_port = None
        self.stage = -1          # -1 = inject, 0..len(links)-1 = hop i, len = eject
        self.head = 0


class NocSim:
    """Event-driven simulator; create, enqueue packets, then ``run()``."""

    def __init__(self, cfg: NocConfig):
        self.cfg = cfg
        self._w, self._h = cfg.width, cfg.height
        self._nodes = self._w * self._h
        self._vcs = effective_vcs(cfg)
        #: Flat busy-until arrays: 4 directed links per node, 2 (inj/ej)
        #: x vcs ports per node.  See ``_overflow`` for out-of-mesh keys.
        self.link_free: list[int] = [0] * link_array_size(cfg)
        self.port_free: list[int] = [0] * port_array_size(cfg)
        self._overflow: dict = {}
        self.ledger = EnergyLedger()
        self._heap: list = []
        self._seq = itertools.count()
        self.now = 0

    # ------------------------------------------------------------------ #
    def _port_id(self, kind: int, vc: int, node: Coord):
        """Flat port index (kind 0 = inject, 1 = eject); tuple key when the
        node/VC falls outside the configured mesh (overflow dict)."""
        pid = port_index(kind, vc, node, self._w, self._h, self._vcs)
        if pid is not None:
            return pid
        return ("inj" if kind == 0 else "ej", vc, node)

    def enqueue(self, t: int, src: Coord, dst: Coord, flits: int, *,
                vc: int = 0, inject: bool = True, eject: bool = True,
                reduce_words: int = 0,
                on_hop: Optional[Callable[[Coord, int], None]] = None,
                on_done: Optional[Callable[[int], None]] = None,
                path: Optional[list] = None) -> None:
        """Schedule a packet to become ready at time ``t``.

        ``reduce_words`` is the generic in-network reduce count: the number
        of operand words folded into this packet by router ALUs along its
        path (the INA block of the paper, the gather/reduce units of
        collective-capable routers).  ``on_hop(node, t_head)`` fires as the
        head flit enters each traversed router — the collective engine uses
        it to timestamp in-passing payload deliveries (multicast drops).
        ``path`` overrides the XY route (must start at ``src`` and end at
        ``dst``).
        """
        pkt = _Packet(src, dst, flits, vc, inject, eject, reduce_words,
                      on_hop, on_done)
        if path is not None:
            _, pkt.link_ids, pkt.links = path_link_ids(self._w, self._h,
                                                       tuple(path))
        else:
            _, pkt.link_ids, pkt.links = route_link_ids(self._w, self._h,
                                                        src, dst)
        if inject:
            pkt.inj_port = self._port_id(0, vc, src)
        if eject:
            pkt.ej_port = self._port_id(1, vc, dst)
        pkt.stage = -1 if inject else 0
        pkt.head = t
        # Energy that is path-determined (independent of contention):
        n_links = len(pkt.links)
        self.ledger.flit_routers += flits * (n_links + 1)
        self.ledger.flit_links += flits * n_links
        self.ledger.packet_hops += n_links
        self.ledger.router_adds += reduce_words
        if inject:
            self.ledger.ni_flits += flits
            self.ledger.packets_built += 1
        if eject:
            self.ledger.ni_flits += flits
            self.ledger.packets_built += 1
        self._push(t, pkt)

    def _push(self, t: int, pkt: _Packet) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), pkt))

    # ------------------------------------------------------------------ #
    def run(self) -> int:
        """Process all events; returns the makespan (last completion time)."""
        cfg = self.cfg
        link_free = self.link_free
        port_free = self.port_free
        overflow = self._overflow
        makespan = 0
        while self._heap:
            t, _, pkt = heapq.heappop(self._heap)
            self.now = max(self.now, t)

            if pkt.stage == -1:                          # injection port
                pid = pkt.inj_port
                if type(pid) is int:
                    free = port_free[pid]
                else:
                    free = overflow.get(pid, 0)
                if free > t:
                    self._push(free, pkt)
                    continue
                if type(pid) is int:
                    port_free[pid] = t + pkt.flits
                else:
                    overflow[pid] = t + pkt.flits
                pkt.head = t + cfg.ni_cycles
                pkt.stage = 0
                self._push(pkt.head, pkt)
                continue

            if pkt.stage < len(pkt.links):               # link hop
                ready = pkt.head + cfg.router_cycles
                lid = pkt.link_ids[pkt.stage]
                flat = type(lid) is int
                free = link_free[lid] if flat else overflow.get(lid, 0)
                if free > ready:
                    pkt.head = free - cfg.router_cycles
                    self._push(free, pkt)
                    continue
                if flat:
                    link_free[lid] = ready + pkt.flits
                else:
                    overflow[lid] = ready + pkt.flits
                pkt.head = ready + cfg.link_cycles
                pkt.stage += 1
                if pkt.on_hop is not None:
                    pkt.on_hop(pkt.links[pkt.stage - 1][1], pkt.head)
                self._push(pkt.head, pkt)
                continue

            # ejection (or in-router completion when eject=False)
            if pkt.eject:
                pid = pkt.ej_port
                ready = pkt.head + cfg.router_cycles
                if type(pid) is int:
                    free = port_free[pid]
                else:
                    free = overflow.get(pid, 0)
                if free > ready:
                    pkt.head = free - cfg.router_cycles
                    self._push(free, pkt)
                    continue
                if type(pid) is int:
                    port_free[pid] = ready + pkt.flits
                else:
                    overflow[pid] = ready + pkt.flits
                done = ready + cfg.ni_cycles + pkt.flits - 1
            else:
                done = pkt.head + pkt.flits - 1
            makespan = max(makespan, done)
            if pkt.on_done is not None:
                pkt.on_done(done)
        return makespan

    # ------------------------------------------------------------------ #
    def chain_eject_inject(self, t: int, chain: list[Coord], flits: int,
                           on_done: Optional[Callable[[int], None]] = None,
                           ) -> None:
        """Fig. 4(a): psum relayed PE->PE, ejected/added/re-injected per stop.

        ``on_done(t)`` fires when the accumulated psum rests in the tail PE.
        """
        cfg = self.cfg
        hops = list(zip(chain[:-1], chain[1:]))

        def launch(i: int, t_ready: int) -> None:
            if i == len(hops):
                if on_done:
                    on_done(t_ready)
                return
            src, dst = hops[i]
            self.ledger.pe_adds += 1
            self.enqueue(t_ready, src, dst, flits, vc=0, inject=True,
                         eject=True,
                         on_done=lambda td: launch(i + 1, td + cfg.pe_add_cycles))

        launch(0, t)
