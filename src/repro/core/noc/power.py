"""Power/energy reporting helpers (Orion-3.0-style, ratio-oriented).

The paper reports *improvement ratios* (Figs 7-12): latency ratio
latency(baseline)/latency(INA) and power ratio power(baseline)/power(INA),
where power = network energy / runtime.  Absolute pJ constants live in
:class:`repro.core.noc.router.NocConfig`; ratios are robust to their scale.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..ina_model import ConvLayer
from .router import NocConfig
from .traffic import simulate_network


@dataclass(frozen=True)
class Improvement:
    workload: str
    e_pes: int
    latency_x: float      # baseline_latency / ina_latency   (>1 = INA better)
    power_x: float        # baseline_power   / ina_power
    energy_x: float       # baseline_energy  / ina_energy


def ws_ina_improvement(name: str, layers: list[ConvLayer], e_pes: int,
                       cfg: NocConfig = NocConfig(), sim_rounds: int = 32,
                       ) -> Improvement:
    """Fig. 7-9: WS+INA vs WS-without-INA.

    Both flows are schedules emitted by the collective planner
    (``collective.schedule.ws_round_program``) and replayed on the program
    engine; ``tests/test_noc_collective.py`` pins the results to the
    pre-planner traffic generator cycle-exactly.
    """
    base = simulate_network(layers, "ws_noina", cfg, e_pes, sim_rounds)
    ina = simulate_network(layers, "ws_ina", cfg, e_pes, sim_rounds)
    return Improvement(
        workload=name, e_pes=e_pes,
        latency_x=base["latency_cycles"] / ina["latency_cycles"],
        power_x=base["network_power"] / ina["network_power"],
        energy_x=base["total_energy_pj"] / ina["total_energy_pj"],
    )


def ws_vs_os_improvement(name: str, layers: list[ConvLayer], e_pes: int,
                         cfg: NocConfig = NocConfig(), sim_rounds: int = 32,
                         ) -> Improvement:
    """Fig. 10-12: WS+INA vs OS-with-gather."""
    base = simulate_network(layers, "os_gather", cfg, e_pes, sim_rounds)
    ina = simulate_network(layers, "ws_ina", cfg, e_pes, sim_rounds)
    return Improvement(
        workload=name, e_pes=e_pes,
        latency_x=base["latency_cycles"] / ina["latency_cycles"],
        power_x=base["network_power"] / ina["network_power"],
        energy_x=base["total_energy_pj"] / ina["total_energy_pj"],
    )
