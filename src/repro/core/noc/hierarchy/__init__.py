"""Mesh-of-meshes hierarchy: multi-chip topology, collectives, and costs.

See DESIGN.md S14.  Public surface:

* :class:`~.topology.HierarchicalMesh` — chips of W x H PEs on a package
  grid, ``(chip, x, y)`` addressing, composed routing, mesh/express
  package variants;
* :func:`~.collective.plan_hier_collective` /
  :func:`~.collective.run_hier_schedule` — per-level lowering onto the
  flat collective machinery, replayed by both engines unchanged;
* :func:`~.cost.hier_collective_cost` /
  :func:`~.cost.hier_psum_mode_costs` — SIM_CACHE-riding cost facade the
  plan builder and mapper price multi-chip placements with.
"""
from .collective import (HIER_OPS, HierarchicalSchedule, HierLane,
                         HierLevel, HierResult, flat_hier_schedule,
                         plan_hier_collective, run_hier_schedule)
from .cost import (HierCost, chip_round_cost, choose_hier_psum_mode,
                   hier_collective_cost, hier_psum_mode_costs,
                   square_hier_mesh)
from .topology import (PACKAGE_VARIANTS, HierarchicalMesh, group_by_chip)

__all__ = [
    "HIER_OPS", "HierarchicalMesh", "PACKAGE_VARIANTS", "group_by_chip",
    "HierarchicalSchedule", "HierLane", "HierLevel", "HierResult",
    "plan_hier_collective", "run_hier_schedule", "flat_hier_schedule",
    "HierCost", "hier_collective_cost", "hier_psum_mode_costs",
    "choose_hier_psum_mode", "chip_round_cost", "square_hier_mesh",
]
