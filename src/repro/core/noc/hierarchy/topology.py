"""Two-level mesh-of-meshes topology: chips of W x H PEs on a package grid.

A :class:`HierarchicalMesh` joins ``chips_x * chips_y`` identical W x H PE
meshes through a package-level network.  Nodes are addressed ``(chip, x,
y)`` — ``chip`` is a flat index into the CX x CY chip grid, ``(x, y)`` the
PE coordinate inside that chip.  Cross-chip traffic enters and leaves a
chip only through its *chip root* PE (the NI that fronts the package
link), so every composed route is per-chip XY inside the endpoints' chips
plus package-level hops between chip roots.

Two package variants (DESIGN.md S14):

* ``"mesh"`` — the chips themselves form a CX x CY mesh with XY routing;
  the package network is an ordinary :class:`~repro.core.noc.router.
  NocConfig` whose nodes are chips, so the whole collective stack (trees,
  schedules, compiled engine) applies unchanged at the package level.
* ``"express"`` — dedicated point-to-point express channels from every
  chip root to the package root chip (a star).  Express links are
  non-unit steps in the package plane; the heap engine models each as its
  own overflow-dict resource (dedicated channel, contention only at the
  shared root NI) and the compiled engine falls back per DESIGN.md S10.

The package :class:`NocConfig` carries its own link timing
(``pkg_link_cycles``) and width (``pkg_flit_bits``): inter-chip links are
slower and often narrower than on-die wires (Guirado et al., PAPERS.md),
and the hierarchy experiments sweep exactly this ratio.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

from ..router import NocConfig
from ..topology import xy_route

Coord = tuple[int, int]
HierCoord = tuple[int, int, int]            # (chip, x, y)

PACKAGE_VARIANTS = ("mesh", "express")


@dataclass(frozen=True)
class HierarchicalMesh:
    """CX x CY chips, each a ``chip_w`` x ``chip_h`` PE mesh."""

    chip_w: int = 8
    chip_h: int = 8
    chips_x: int = 1
    chips_y: int = 1
    package: str = "mesh"
    #: Package-link timing/width relative to the on-die NocConfig; the
    #: defaults model a 4x slower, same-width interposer link.
    pkg_link_cycles: int = 4
    pkg_flit_bits: Optional[int] = None     # None = inherit the chip's

    def __post_init__(self):
        assert self.chip_w >= 1 and self.chip_h >= 1, "empty chip mesh"
        assert self.chips_x >= 1 and self.chips_y >= 1, "empty chip grid"
        assert self.package in PACKAGE_VARIANTS, self.package

    # ------------------------------------------------------------------ #
    # chip indexing
    # ------------------------------------------------------------------ #
    @property
    def num_chips(self) -> int:
        return self.chips_x * self.chips_y

    @property
    def num_pes(self) -> int:
        return self.num_chips * self.chip_w * self.chip_h

    def chip_coord(self, chip: int) -> Coord:
        """Chip-grid coordinate of a flat chip index."""
        assert 0 <= chip < self.num_chips, chip
        return chip % self.chips_x, chip // self.chips_x

    def chip_id(self, cx: int, cy: int) -> int:
        assert 0 <= cx < self.chips_x and 0 <= cy < self.chips_y, (cx, cy)
        return cy * self.chips_x + cx

    #: The PE fronting the package link: cross-chip traffic ejects from /
    #: injects into the package network here (fixed, deterministic).
    chip_root_xy: Coord = (0, 0)

    def chip_root(self, chip: int) -> HierCoord:
        return (chip, *self.chip_root_xy)

    def nodes(self) -> Iterator[HierCoord]:
        for chip in range(self.num_chips):
            for y in range(self.chip_h):
                for x in range(self.chip_w):
                    yield (chip, x, y)

    # ------------------------------------------------------------------ #
    # per-level NocConfigs
    # ------------------------------------------------------------------ #
    def chip_cfg(self, base: NocConfig = NocConfig()) -> NocConfig:
        """The on-die NocConfig of one chip (base timing/energy, chip shape).

        A 1-chip hierarchy whose chip shape equals ``base``'s mesh shape
        returns ``base`` itself — the degenerate-equivalence guarantee
        starts here (identical config hash, identical cache keys).
        """
        if (base.width, base.height) == (self.chip_w, self.chip_h):
            return base
        rows = None if self.chip_h == self.chip_w else self.chip_h
        return dataclasses.replace(base, n=self.chip_w, rows=rows)

    def package_cfg(self, base: NocConfig = NocConfig()) -> NocConfig:
        """The package-level NocConfig: nodes are chips, links are the
        inter-chip channels (slower/narrower per ``pkg_link_cycles`` /
        ``pkg_flit_bits``)."""
        rows = None if self.chips_y == self.chips_x else self.chips_y
        return dataclasses.replace(
            base, n=self.chips_x, rows=rows,
            link_cycles=self.pkg_link_cycles,
            flit_bits=self.pkg_flit_bits or base.flit_bits)

    # ------------------------------------------------------------------ #
    # composed routing
    # ------------------------------------------------------------------ #
    def route(self, src: HierCoord, dst: HierCoord) -> list[HierCoord]:
        """Composed route ``src -> dst``: per-chip XY inside the endpoint
        chips, package-level hops between chip roots in between.  Package
        hops are XY over the chip grid (``"mesh"``) or one direct express
        hop (``"express"``)."""
        (sc, sx, sy), (dc, dx, dy) = src, dst
        if sc == dc:
            return [(sc, x, y) for x, y in xy_route((sx, sy), (dx, dy))]
        rx, ry = self.chip_root_xy
        path = [(sc, x, y) for x, y in xy_route((sx, sy), (rx, ry))]
        if self.package == "express":
            hops = [self.chip_coord(sc), self.chip_coord(dc)]
        else:
            hops = xy_route(self.chip_coord(sc), self.chip_coord(dc))
        for cx, cy in hops[1:]:
            path.append((self.chip_id(cx, cy), rx, ry))
        path += [(dc, x, y) for x, y in xy_route((rx, ry), (dx, dy))[1:]]
        return path

    def is_package_hop(self, a: HierCoord, b: HierCoord) -> bool:
        """True when ``a -> b`` is a legal package-link traversal: both
        endpoints are chip roots of *different* chips that the package
        network actually joins."""
        if a[0] == b[0]:
            return False
        if (a[1], a[2]) != self.chip_root_xy or \
                (b[1], b[2]) != self.chip_root_xy:
            return False
        if self.package == "express":
            return True                      # dedicated any-to-any channels
        (ax, ay), (bx, by) = self.chip_coord(a[0]), self.chip_coord(b[0])
        return abs(ax - bx) + abs(ay - by) == 1

    def label(self) -> str:
        tag = "" if self.package == "mesh" else "e"
        return (f"{self.chips_x}x{self.chips_y}{tag}c"
                f"{self.chip_w}x{self.chip_h}")


def group_by_chip(participants) -> dict[int, list[Coord]]:
    """Split ``(chip, x, y)`` participants into per-chip ``(x, y)`` sets."""
    out: dict[int, list[Coord]] = {}
    for chip, x, y in sorted(set(participants)):
        out.setdefault(chip, []).append((x, y))
    return out
