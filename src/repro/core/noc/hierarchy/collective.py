"""Hierarchical collectives: per-level lowering onto the flat machinery.

A hierarchical collective is a *sequence of levels*; each level is a set of
*lanes* that run concurrently on disjoint networks (one lane per chip, or
one package-level lane).  Every lane is an ordinary flat
:class:`~repro.core.noc.collective.schedule.PacketOp` program under its own
:class:`~repro.core.noc.router.NocConfig` — both engines replay it
unchanged, which is the whole point of the lowering:

* ``reduce``    -> [intra-chip reduce to each chip root] ; [package reduce
  over chip roots]
* ``broadcast`` -> [package multicast to chip roots] ; [intra-chip
  broadcast from each chip root]
* ``allreduce`` -> [intra-chip reduce] ; [package allreduce (either
  algorithm)] ; [intra-chip broadcast]

With a single populated chip there is nothing to lower: the plan is one
level whose one lane is *exactly* the flat ``plan_collective`` program on
the chip's config — bit-identical latency and energy ledgers by
construction (the degenerate-equivalence guard of ``tests/
test_hierarchy.py`` pins this for both engines).

Package lanes on the ``"mesh"`` variant come from ``plan_collective`` on
the package config (chips are just nodes).  The ``"express"`` variant
plans over a *star* tree whose edges are the dedicated chip-root ->
package-root channels: INA semantics reuse the flat segment planners
(star segments are single express edges, carried as path overrides the
heap engine resolves to per-channel overflow resources); eject-inject
semantics emit the star's unicasts explicitly with the same path
overrides.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional

from ..router import EnergyLedger, NocConfig
from ..collective.engine import run_program
from ..collective.schedule import (ALLREDUCE_ALGORITHMS, PacketOp, SEMANTICS,
                                   _payload_flits, _plan_multicast_ina,
                                   _plan_reduce_ina, _words, plan_collective)
from ..collective.trees import CollectiveTree
from .topology import Coord, HierCoord, HierarchicalMesh, group_by_chip

HIER_OPS = ("reduce", "broadcast", "allreduce")


@dataclass(frozen=True)
class HierLane:
    """One flat program on one physical network (a chip, or the package)."""

    label: str                    # "chip3" / "package"
    scope: str                    # "chip" | "package"
    cfg: NocConfig
    prog: tuple = ()              # tuple[PacketOp, ...]
    chip: Optional[int] = None    # chip index for chip-scope lanes


@dataclass(frozen=True)
class HierLevel:
    """Concurrent lanes; the level completes when its slowest lane does."""

    name: str                     # "flat" / "intra-reduce" / "package" / ...
    lanes: tuple = ()             # tuple[HierLane, ...]


@dataclass(frozen=True)
class HierarchicalSchedule:
    """A lowered hierarchical collective: levels run in sequence."""

    hmesh: HierarchicalMesh
    op: str
    semantics: str
    algorithm: str
    payload_bits: float
    levels: tuple = ()            # tuple[HierLevel, ...]

    def all_lanes(self):
        for level in self.levels:
            for lane in level.lanes:
                yield level, lane


@dataclass
class HierResult:
    """Replay outcome: levels are serialized, lanes within a level are
    concurrent (max), energy sums over every lane under its own config."""

    latency_cycles: int
    energy_pj: float
    ledger: EnergyLedger          # combined event counts across all lanes
    level_latency: dict = field(default_factory=dict)


# --------------------------------------------------------------------------- #
# express-star package programs
# --------------------------------------------------------------------------- #
def star_tree(root: Coord, participants: Iterable[Coord]) -> CollectiveTree:
    """The express package tree: every chip root is a direct child of the
    package root — each edge one dedicated express channel."""
    parts = frozenset(participants)
    parent = {p: root for p in sorted(parts) if p != root}
    tree = CollectiveTree(root=root, participants=parts | {root},
                          parent=parent, order="xy")
    tree.validate()
    return tree


def _express_reduce(prog: list, tree: CollectiveTree, payload_bits: float,
                    cfg: NocConfig, *, tag: str) -> int:
    """Express reduce without router support: every chip unicasts its
    operand over its own channel; the root PE folds arrivals."""
    flits = _payload_flits(cfg, payload_bits)
    words = _words(payload_bits)
    kids = sorted(tree.participants - {tree.root})
    deps = []
    for p in kids:
        prog.append(PacketOp(p, tree.root, flits, path=[p, tree.root],
                             tag=tag, contribs=frozenset({p})))
        deps.append(len(prog) - 1)
    prog.append(PacketOp(
        tree.root, tree.root, 0, inject=False, eject=False,
        pe_adds=len(deps) * words, deps=tuple(deps),
        delay=cfg.pe_add_cycles, tag=tag + ":root",
        contribs=frozenset(tree.participants), delivers=(tree.root,)))
    return len(prog) - 1


def _express_multicast(prog: list, tree: CollectiveTree,
                       payload_bits: float, cfg: NocConfig, *, tag: str,
                       contribs: frozenset, deps: tuple) -> list:
    """Express multicast without router support: one unicast per channel."""
    flits = _payload_flits(cfg, payload_bits)
    out = []
    for p in sorted(tree.participants - {tree.root}):
        prog.append(PacketOp(tree.root, p, flits, path=[tree.root, p],
                             deps=deps, tag=tag, contribs=contribs,
                             delivers=(p,)))
        out.append(len(prog) - 1)
    return out


def _package_program(op: str, chips: list[Coord], payload_bits: float,
                     pkg_cfg: NocConfig, root: Coord, *, express: bool,
                     algorithm: str, semantics: str) -> list[PacketOp]:
    """The package-level lane: a flat collective over chip-grid coords."""
    if not express:
        return plan_collective(op, chips, payload_bits, pkg_cfg, root=root,
                               algorithm=algorithm, semantics=semantics)
    tree = star_tree(root, chips)
    prog: list[PacketOp] = []
    if op == "reduce":
        if semantics == "ina":
            _plan_reduce_ina(prog, tree, payload_bits, pkg_cfg, vc=0,
                             chunk=0, tag="reduce")
        else:
            _express_reduce(prog, tree, payload_bits, pkg_cfg, tag="reduce")
        return prog
    if op == "broadcast":
        if semantics == "ina":
            _plan_multicast_ina(prog, tree, payload_bits, pkg_cfg, vc=0,
                                chunk=0, tag="bcast",
                                contribs=frozenset({root}), deps=())
        else:
            _express_multicast(prog, tree, payload_bits, pkg_cfg,
                               tag="bcast", contribs=frozenset({root}),
                               deps=())
        return prog
    # allreduce over the star: reduce to the package root, multicast back
    # (the star has no ring to scatter over — rs_ag degenerates to this).
    parts = frozenset(chips)
    if semantics == "ina":
        final = _plan_reduce_ina(prog, tree, payload_bits, pkg_cfg, vc=0,
                                 chunk=0, tag="ar:reduce")
        _plan_multicast_ina(prog, tree, payload_bits, pkg_cfg, vc=0,
                            chunk=0, tag="ar:bcast", contribs=parts,
                            deps=(final,))
    else:
        final = _express_reduce(prog, tree, payload_bits, pkg_cfg,
                                tag="ar:reduce")
        _express_multicast(prog, tree, payload_bits, pkg_cfg,
                           tag="ar:bcast", contribs=parts, deps=(final,))
    return prog


# --------------------------------------------------------------------------- #
# the hierarchical planner
# --------------------------------------------------------------------------- #
def _chip_faults(faults, chip: int):
    """Resolve the on-die fault model for one chip: ``faults`` is either a
    single FaultModel every chip shares or a ``{chip: FaultModel}``
    mapping (missing chips are clean)."""
    if faults is None:
        return None
    if hasattr(faults, "get"):
        return faults.get(chip)
    return faults


def plan_hier_collective(op: str, hmesh: HierarchicalMesh,
                         payload_bits: float,
                         cfg: NocConfig = NocConfig(), *,
                         participants: Optional[Iterable[HierCoord]] = None,
                         root: Optional[HierCoord] = None,
                         algorithm: str = "reduce_bcast",
                         semantics: str = "ina",
                         faults=None,
                         failed_chips: Iterable[int] = (),
                         ) -> HierarchicalSchedule:
    """Lower a collective over ``(chip, x, y)`` participants into levels.

    ``participants`` defaults to every PE of the hierarchy; ``root``
    defaults to the first participant.  With all participants on one chip
    the result is a single ``"flat"`` level carrying exactly the flat
    ``plan_collective`` program (degenerate equivalence).

    ``faults`` injects *on-die* faults into every chip-scope lane (a
    shared FaultModel or a per-chip mapping; see :func:`_chip_faults`) —
    each chip's trees are repaired on its own fabric while the package
    lane, whose express/mesh channels are a separate network, stays
    clean.  ``failed_chips`` models whole-chip loss: their PEs drop out
    of the participant set (and the package lane, since it only spans
    populated chips); a root on a failed chip remaps to the first
    surviving participant.
    """
    assert op in HIER_OPS, op
    assert semantics in SEMANTICS, semantics
    assert algorithm in ALLREDUCE_ALGORITHMS, algorithm
    parts = sorted(set(participants)) if participants is not None \
        else sorted(hmesh.nodes())
    failed = frozenset(failed_chips)
    if failed:
        parts = [p for p in parts if p[0] not in failed]
        if root is not None and root[0] in failed:
            root = None
    assert parts, "empty participant set"
    root = parts[0] if root is None else root
    assert root in parts, f"root {root} is not a participant"
    by_chip = group_by_chip(parts)
    chip_cfg = hmesh.chip_cfg(cfg)

    def sched(levels):
        return HierarchicalSchedule(hmesh=hmesh, op=op, semantics=semantics,
                                    algorithm=algorithm,
                                    payload_bits=float(payload_bits),
                                    levels=tuple(levels))

    if len(by_chip) == 1:
        chip, xy = next(iter(by_chip.items()))
        prog = plan_collective(op, xy, payload_bits, chip_cfg,
                               root=(root[1], root[2]),
                               algorithm=algorithm, semantics=semantics,
                               faults=_chip_faults(faults, chip))
        lane = HierLane(label=f"chip{chip}", scope="chip", cfg=chip_cfg,
                        prog=tuple(prog), chip=chip)
        return sched([HierLevel(name="flat", lanes=(lane,))])

    pkg_cfg = hmesh.package_cfg(cfg)
    express = hmesh.package == "express"
    root_chip = root[0]
    chip_coords = sorted(hmesh.chip_coord(c) for c in by_chip)
    rxy = hmesh.chip_root_xy

    def chip_lanes(cop: str, tag_chips) -> tuple:
        lanes = []
        for chip in tag_chips:
            prog = plan_collective(cop, by_chip[chip], payload_bits,
                                   chip_cfg, root=rxy, semantics=semantics,
                                   faults=_chip_faults(faults, chip))
            lanes.append(HierLane(label=f"chip{chip}", scope="chip",
                                  cfg=chip_cfg, prog=tuple(prog), chip=chip))
        return tuple(lanes)

    def package_lane(pop: str) -> HierLane:
        prog = _package_program(pop, chip_coords, payload_bits, pkg_cfg,
                                hmesh.chip_coord(root_chip),
                                express=express, algorithm=algorithm,
                                semantics=semantics)
        return HierLane(label="package", scope="package", cfg=pkg_cfg,
                        prog=tuple(prog))

    chips = sorted(by_chip)
    if op == "reduce":
        return sched([
            HierLevel("intra-reduce", chip_lanes("reduce", chips)),
            HierLevel("package", (package_lane("reduce"),)),
        ])
    if op == "broadcast":
        return sched([
            HierLevel("package", (package_lane("broadcast"),)),
            HierLevel("intra-bcast", chip_lanes("broadcast", chips)),
        ])
    return sched([                           # allreduce
        HierLevel("intra-reduce", chip_lanes("reduce", chips)),
        HierLevel("package", (package_lane("allreduce"),)),
        HierLevel("intra-bcast", chip_lanes("broadcast", chips)),
    ])


def flat_hier_schedule(hmesh: HierarchicalMesh, prog: Iterable[PacketOp],
                       cfg: NocConfig = NocConfig(), *,
                       chip: int = 0, op: str = "flat") -> HierarchicalSchedule:
    """Wrap an arbitrary flat program (e.g. a fig7-12 WS round program) as
    a single-level hierarchical schedule on one chip — the facade the
    degenerate-equivalence tests replay on both engines."""
    lane = HierLane(label=f"chip{chip}", scope="chip",
                    cfg=hmesh.chip_cfg(cfg), prog=tuple(prog), chip=chip)
    return HierarchicalSchedule(hmesh=hmesh, op=op, semantics="ina",
                                algorithm="reduce_bcast", payload_bits=0.0,
                                levels=(HierLevel("flat", (lane,)),))


# --------------------------------------------------------------------------- #
# replay
# --------------------------------------------------------------------------- #
def run_hier_schedule(sched: HierarchicalSchedule, *,
                      engine: str = "auto") -> HierResult:
    """Replay every lane on its own simulator; levels serialize, lanes
    within a level overlap (disjoint networks).  Energy is priced per lane
    under that lane's config — package links may cost differently than
    on-die wires."""
    total = 0
    energy = 0.0
    combined = EnergyLedger()
    level_latency: dict = {}
    for level in sched.levels:
        worst = 0
        for lane in level.lanes:
            res = run_program(list(lane.prog), lane.cfg, engine=engine)
            worst = max(worst, res.latency_cycles)
            energy += res.ledger.network_energy_pj(lane.cfg)
            combined.add(res.ledger)
        level_latency[level.name] = worst
        total += worst
    return HierResult(latency_cycles=total, energy_pj=energy,
                      ledger=combined, level_latency=level_latency)
