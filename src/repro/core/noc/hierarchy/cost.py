"""Hierarchical collective costs, riding the persistent SIM_CACHE.

Every lane of a :class:`~.collective.HierarchicalSchedule` is a flat
collective on its own config, so lane costs reuse the flat
:func:`~repro.core.noc.collective.cost.collective_cost` facade — same
``("collective", ...)`` SIM_CACHE keys, same COST_STATS accounting, same
persistence.  A 2-chip sweep therefore re-simulates *nothing* a warm
store already holds (the plan-store acceptance test pins engine_runs == 0
on re-plan), and identical chips dedup through the lru/store layers for
free.  Express-star package lanes are the one shape ``plan_collective``
cannot emit; they get their own ``("hier-express", ...)`` store key with
identical semantics.

The psum facade mirrors ``collective/cost.psum_mode_costs``: a TP axis of
``p`` devices over ``chips`` chips is embedded as one PE row per chip
(contiguous split, so uneven tails are priced exactly) plus the chip
roots as one package row.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

from ..collective.cost import (AUTO_CANDIDATES, COST_STATS, CollectiveCost,
                               PSUM_MODE_LOWERING, _row_cfg, collective_cost)
from ..collective.engine import run_program
from ..collective.trees import mesh_row
from ..router import NocConfig
from ..simcache import SIM_CACHE
from .collective import _package_program
from .topology import Coord, HierarchicalMesh


@dataclass(frozen=True)
class HierCost:
    """Simulated cost of one hierarchical collective."""

    op: str
    algorithm: str
    semantics: str
    chips: int
    participants: int
    payload_bits: float
    latency_cycles: int
    energy_pj: float
    packets: int
    #: per-level (name, latency_cycles) in execution order
    level_latency: tuple = ()


@lru_cache(maxsize=4096)
def _simulate_express(op: str, chips: tuple[Coord, ...],
                      payload_bits: float, pkg_cfg: NocConfig, root: Coord,
                      algorithm: str, semantics: str) -> tuple[int, float, int]:
    """Run (or recall) one express-star package lane.  Same store protocol
    as ``collective/cost._simulate`` under a distinct leading tag — the
    schema-hashed persistent store replays these across processes too."""
    prog = _package_program(op, list(chips), payload_bits, pkg_cfg, root,
                            express=True, algorithm=algorithm,
                            semantics=semantics)
    packets = sum(1 for o in prog if o.flits)
    key = ("hier-express", op, chips, payload_bits, pkg_cfg, root,
           algorithm, semantics)
    hit = SIM_CACHE.get(key)
    if hit is not None:
        COST_STATS["store_hits"] += 1
        latency, ledger = hit
        return (int(latency), ledger.network_energy_pj(pkg_cfg), packets)
    COST_STATS["engine_runs"] += 1
    res = run_program(prog, pkg_cfg)
    SIM_CACHE.put(key, float(res.latency_cycles), res.ledger)
    return (res.latency_cycles, res.network_energy_pj(pkg_cfg), packets)


def _package_cost(op: str, chips: list[Coord], payload_bits: float,
                  hmesh: HierarchicalMesh, cfg: NocConfig, *,
                  algorithm: str, semantics: str) -> tuple[int, float, int]:
    """(latency, energy_pj, packets) of the package-level lane."""
    pkg_cfg = hmesh.package_cfg(cfg)
    root = hmesh.chip_coord(min(hmesh.chip_id(cx, cy) for cx, cy in chips))
    if hmesh.package == "express":
        return _simulate_express(op, tuple(sorted(chips)),
                                 float(payload_bits), pkg_cfg, root,
                                 algorithm, semantics)
    c = collective_cost(op, payload_bits, pkg_cfg,
                        participants=chips, root=root,
                        algorithm=algorithm, semantics=semantics)
    return (c.latency_cycles, c.energy_pj, c.packets)


# --------------------------------------------------------------------------- #
# whole-hierarchy collectives
# --------------------------------------------------------------------------- #
def hier_collective_cost(op: str, hmesh: HierarchicalMesh,
                         payload_bits: float,
                         cfg: NocConfig = NocConfig(), *,
                         algorithm: str = "reduce_bcast",
                         semantics: str = "ina") -> HierCost:
    """Cost of a collective over *every* PE of ``hmesh``: per-level lane
    costs from the flat facade (identical chips priced once), levels
    summed, concurrent lanes maxed."""
    chip_cfg = hmesh.chip_cfg(cfg)
    chip_parts = [(x, y) for y in range(hmesh.chip_h)
                  for x in range(hmesh.chip_w)]
    chips = sorted(hmesh.chip_coord(c) for c in range(hmesh.num_chips))
    n_chips = hmesh.num_chips
    if n_chips == 1:
        c = collective_cost(op, payload_bits, chip_cfg,
                            participants=chip_parts,
                            root=hmesh.chip_root_xy,
                            algorithm=algorithm, semantics=semantics)
        return HierCost(op, algorithm, semantics, 1, len(chip_parts),
                        float(payload_bits), c.latency_cycles, c.energy_pj,
                        c.packets, (("flat", c.latency_cycles),))

    def chip_level(cop: str) -> tuple[int, float, int]:
        c = collective_cost(cop, payload_bits, chip_cfg,
                            participants=chip_parts,
                            root=hmesh.chip_root_xy, semantics=semantics)
        return (c.latency_cycles, n_chips * c.energy_pj, n_chips * c.packets)

    levels: list[tuple[str, tuple[int, float, int]]] = []
    if op in ("reduce", "allreduce"):
        levels.append(("intra-reduce", chip_level("reduce")))
    pkg_op = op if op != "broadcast" else "broadcast"
    levels.append(("package", _package_cost(
        pkg_op, chips, payload_bits, hmesh, cfg,
        algorithm=algorithm, semantics=semantics)))
    if op in ("broadcast", "allreduce"):
        levels.append(("intra-bcast", chip_level("broadcast")))
    latency = sum(lat for _, (lat, _, _) in levels)
    energy = sum(e for _, (_, e, _) in levels)
    packets = sum(p for _, (_, _, p) in levels)
    return HierCost(op, algorithm, semantics, n_chips,
                    n_chips * len(chip_parts), float(payload_bits),
                    latency, energy, packets,
                    tuple((name, lat) for name, (lat, _, _) in levels))


# --------------------------------------------------------------------------- #
# psum facade: a TP axis of p devices over `chips` chips
# --------------------------------------------------------------------------- #
def _chip_spans(p: int, chips: int) -> list[int]:
    """Contiguous split of ``p`` TP ranks over ``chips`` chips (the tail
    chips run one rank short when the split is uneven)."""
    c = max(1, min(chips, p))
    base, rem = divmod(p, c)
    return [base + (1 if i < rem else 0) for i in range(c)]


def hier_psum_mode_costs(p: int, nbytes: int,
                         cfg: NocConfig = NocConfig(), *,
                         chips: int = 1, package: str = "mesh",
                         pkg_link_cycles: int = 4,
                         pkg_flit_bits: Optional[int] = None,
                         ) -> dict[str, CollectiveCost]:
    """Allreduce cost for every PsumMode over a ``p``-rank TP axis split
    across ``chips`` chips.  ``chips <= 1`` delegates to the flat
    :func:`~repro.core.noc.collective.cost.psum_mode_costs` embedding —
    identical keys, identical numbers (degenerate equivalence)."""
    from ..collective.cost import psum_mode_costs
    if chips <= 1 or p <= 1:
        return psum_mode_costs(p, nbytes, cfg)
    spans = _chip_spans(p, chips)
    c_eff = len(spans)
    hmesh = HierarchicalMesh(
        chip_w=max(cfg.n, max(spans)), chip_h=cfg.height,
        chips_x=c_eff, chips_y=1, package=package,
        pkg_link_cycles=pkg_link_cycles, pkg_flit_bits=pkg_flit_bits)
    payload_bits = nbytes * 8
    chip_coords = mesh_row(c_eff, 0)
    out: dict[str, CollectiveCost] = {}
    for mode, (algorithm, semantics) in PSUM_MODE_LOWERING.items():
        latency = 0
        energy = 0.0
        packets = 0
        # intra-chip reduce + broadcast-back, one lane shape per distinct
        # span (lanes overlap: latency is the worst span, energy sums all)
        for phase in ("reduce", "broadcast"):
            worst = 0
            for span in sorted(set(spans)):
                if span <= 1:
                    continue
                rcfg = _row_cfg(span, cfg)
                c = collective_cost(phase, payload_bits, rcfg,
                                    participants=mesh_row(span, 0)[:span],
                                    root=(0, 0), semantics=semantics)
                worst = max(worst, c.latency_cycles)
                k = sum(1 for s in spans if s == span)
                energy += k * c.energy_pj
                packets += k * c.packets
            latency += worst
        pkg_lat, pkg_e, pkg_p = _package_cost(
            "allreduce", chip_coords, payload_bits, hmesh, cfg,
            algorithm=algorithm, semantics=semantics)
        latency += pkg_lat
        energy += pkg_e
        packets += pkg_p
        out[mode] = CollectiveCost(
            op="allreduce", algorithm=algorithm, semantics=semantics,
            n=cfg.n, participants=p, payload_bits=float(payload_bits),
            latency_cycles=latency, energy_pj=energy, packets=packets)
    return out


def choose_hier_psum_mode(p: int, nbytes: int,
                          cfg: NocConfig = NocConfig(), *,
                          chips: int = 1, package: str = "mesh",
                          objective: str = "latency") -> str:
    """Argmin over :data:`AUTO_CANDIDATES` of the hierarchical psum cost
    (ties resolve toward the INA fast path, as in the flat chooser)."""
    if p <= 1:
        return "ina"
    costs = hier_psum_mode_costs(p, nbytes, cfg, chips=chips,
                                 package=package)
    key = (lambda c: c.latency_cycles) if objective == "latency" \
        else (lambda c: c.energy_pj)
    return min(AUTO_CANDIDATES,
               key=lambda m: (key(costs[m]), AUTO_CANDIDATES.index(m)))


def chip_round_cost(payload_bits: float, chips: int,
                    cfg: NocConfig = NocConfig(), *, package: str = "mesh",
                    pkg_link_cycles: int = 4,
                    semantics: str = "ina") -> tuple[int, float]:
    """(latency, energy) of shipping one round's operands to every chip
    over the package network — the mapper's per-round multi-chip surcharge
    (a package broadcast from the feeding chip's root)."""
    if chips <= 1:
        return (0, 0.0)
    hmesh = HierarchicalMesh(chips_x=chips, chips_y=1, package=package,
                             pkg_link_cycles=pkg_link_cycles)
    lat, e, _ = _package_cost("broadcast", mesh_row(chips, 0), payload_bits,
                              hmesh, cfg, algorithm="reduce_bcast",
                              semantics=semantics)
    return (lat, e)


def hier_cache_key_count() -> int:
    """Observable footprint for tests: distinct express-lane signatures
    memoized this process."""
    return _simulate_express.cache_info().currsize


def square_hier_mesh(chips: int, chip_w: int = 8, chip_h: int = 8, *,
                     package: str = "mesh",
                     pkg_link_cycles: int = 4) -> HierarchicalMesh:
    """A near-square chip grid for ``chips`` chips (sweep helper)."""
    cx = int(math.sqrt(chips))
    while chips % cx:
        cx -= 1
    return HierarchicalMesh(chip_w=chip_w, chip_h=chip_h,
                            chips_x=chips // cx, chips_y=cx,
                            package=package,
                            pkg_link_cycles=pkg_link_cycles)
