"""Seeded fault model + deadlock-safe detour routing + tree repair.

A :class:`FaultModel` is a frozen, hashable description of a mesh's broken
hardware: permanently failed *links* (undirected — both directions die),
failed *routers* (the node cannot forward, and its PE is unreachable),
failed *PEs* (the router still forwards, the local core is dead), and
*transient* per-window link faults (``(window, link)`` pairs a caller folds
in with :meth:`FaultModel.at_window` before planning).  Instances come from
:func:`seeded_faults` — one ``random.Random(seed)`` stream, so the same
seed always yields the same fault set ("same seed, same bytes", the
serving-layer contract).

Routing around faults uses the **west-first turn model**: every westward
(-x) hop must precede all other hops, which prohibits the N->W / S->W
turns and makes any set of such routes deadlock-free by the Dally/Seitz
channel-dependency argument (``analysis/verify.py`` re-proves this on every
faulted corpus shape via ``_cdg_findings``).  Plain XY routes are
west-first-legal, so a clean XY path is always preferred and an empty
fault model degenerates to the exact memoized XY machinery — bit-identical
routes, cache keys and all (the zero-fault equivalence guard in
``tests/test_faults.py``).

Fault-aware routes are memoized in :data:`~.topology._ROUTE_CACHE` under
``(src, dst, fault_key)`` — a fault set can never serve another fault
set's (or the clean mesh's) entries.

Collective *tree repair* rebuilds reduce/multicast trees over the healthy
fabric: a single BFS from the root assigns every reachable node one parent
such that each node's full path to (reduce) or from (multicast) the root
is west-first legal by induction; the tree is then pruned to the union of
the participants' root paths, so every leaf is a participant.  Dead PEs
are excluded from the participant set and their contributions *remapped*
to the nearest healthy participant (:func:`remap_participants`) — the
fold-exactly-once algebra then runs over the healthy set.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from functools import lru_cache
from typing import Iterable, Optional

from .topology import memo_route, xy_route_tuple

Coord = tuple[int, int]
Link = tuple[Coord, Coord]

#: West — the direction the turn model restricts.
_W = (-1, 0)
#: Deterministic neighbor-expansion order: W, E, N(-y), S(+y).
_DIRS = ((-1, 0), (1, 0), (0, -1), (0, 1))


class UnroutableError(RuntimeError):
    """No west-first-legal fault-free path exists under this fault set."""


def _norm_link(a: Coord, b: Coord) -> Link:
    return (a, b) if a <= b else (b, a)


def mesh_links(width: int, height: int) -> list[Link]:
    """Every undirected mesh link, in deterministic scan order."""
    out: list[Link] = []
    for y in range(height):
        for x in range(width):
            if x + 1 < width:
                out.append(((x, y), (x + 1, y)))
            if y + 1 < height:
                out.append(((x, y), (x, y + 1)))
    return out


@dataclass(frozen=True)
class FaultModel:
    """Immutable fault set.  Hashable — joins sim-cache keys directly."""

    links: frozenset = frozenset()     # undirected, normalized (a <= b)
    routers: frozenset = frozenset()   # failed routers (PE dies with it)
    pes: frozenset = frozenset()       # failed PEs (router still forwards)
    transient: tuple = ()              # sorted ((window, link), ...)
    seed: Optional[int] = None         # provenance only (reporting)

    def __post_init__(self):
        object.__setattr__(self, "links", frozenset(
            _norm_link(a, b) for a, b in self.links))
        object.__setattr__(self, "routers", frozenset(self.routers))
        object.__setattr__(self, "pes", frozenset(self.pes))
        object.__setattr__(self, "transient", tuple(sorted(
            (int(w), _norm_link(a, b)) for w, (a, b) in self.transient)))

    # ------------------------------------------------------------------ #
    @property
    def empty(self) -> bool:
        return not (self.links or self.routers or self.pes or self.transient)

    def key(self) -> tuple:
        """Canonical sorted signature — the route/sim cache key component."""
        return (tuple(sorted(self.links)), tuple(sorted(self.routers)),
                tuple(sorted(self.pes)), self.transient)

    def link_ok(self, a: Coord, b: Coord) -> bool:
        return _norm_link(a, b) not in self.links

    def router_ok(self, n: Coord) -> bool:
        return n not in self.routers

    def pe_ok(self, n: Coord) -> bool:
        """A live PE needs both its core and its router."""
        return n not in self.pes and n not in self.routers

    def at_window(self, window: int) -> "FaultModel":
        """Permanent faults plus this window's transient link outages,
        as a transient-free model (what planners accept)."""
        if not self.transient:
            return self
        extra = frozenset(l for w, l in self.transient if w == window)
        return FaultModel(links=self.links | extra, routers=self.routers,
                          pes=self.pes, transient=(), seed=self.seed)

    def path_clear(self, path: Iterable[Coord]) -> bool:
        """True iff every router and link along ``path`` is healthy."""
        path = list(path)
        return (all(self.router_ok(v) for v in path)
                and all(self.link_ok(a, b)
                        for a, b in zip(path[:-1], path[1:])))


#: The canonical clean mesh (``detour_route`` degenerates to XY on it).
EMPTY_FAULTS = FaultModel()


def seeded_faults(width: int, height: int, *, link_rate: float = 0.0,
                  router_rate: float = 0.0, pe_rate: float = 0.0,
                  transient_rate: float = 0.0, windows: int = 0,
                  seed: int = 0) -> FaultModel:
    """Deterministic fault set: one ``random.Random(seed)`` stream drawn in
    a fixed order (links, routers, PEs, then per-window transients)."""
    rng = random.Random(seed)
    all_links = mesh_links(width, height)
    nodes = [(x, y) for y in range(height) for x in range(width)]
    links = [l for l in all_links if rng.random() < link_rate]
    routers = [n for n in nodes if rng.random() < router_rate]
    pes = [n for n in nodes if rng.random() < pe_rate]
    transient = [(w, l) for w in range(windows) for l in all_links
                 if rng.random() < transient_rate]
    return FaultModel(links=frozenset(links), routers=frozenset(routers),
                      pes=frozenset(pes), transient=tuple(transient),
                      seed=seed)


# --------------------------------------------------------------------------- #
# west-first turn model
# --------------------------------------------------------------------------- #
def allowed_turn(d1: Coord, d2: Coord) -> bool:
    """West-first legality of consecutive hop directions: no U-turns, and
    a west hop may only follow a west hop (all W hops come first)."""
    if d2 == (-d1[0], -d1[1]):
        return False
    return d2 != _W or d1 == _W


def path_is_west_first(path: Iterable[Coord]) -> bool:
    """True iff ``path`` uses unit mesh steps whose turn sequence the
    west-first model allows (XY paths always qualify)."""
    path = list(path)
    dirs = [(b[0] - a[0], b[1] - a[1])
            for a, b in zip(path[:-1], path[1:])]
    if any(d not in _DIRS for d in dirs):
        return False
    return all(allowed_turn(d1, d2) for d1, d2 in zip(dirs, dirs[1:]))


# --------------------------------------------------------------------------- #
# up*/down* routing (the any-connected-fault-pattern fallback)
# --------------------------------------------------------------------------- #
#: The detour rules, in the order the planner tries them.  West-first is
#: only *partially* adaptive (a destination whose westward corridor is cut
#: can become unreachable — all W hops must come first); up*/down* routes
#: any connected healthy fabric at the price of non-minimal paths.  A
#: program never mixes rules: the deadlock argument holds per rule, and
#: the union of one program's paths must follow a single relation.
DETOUR_RULES = ("west_first", "updown")


@lru_cache(maxsize=256)
def updown_keys(faults: FaultModel, width: int,
                height: int) -> dict[Coord, tuple[int, int]]:
    """Up*/down* link orientation: BFS spanning tree of the healthy fabric
    from the first healthy node in scan order; each node's key is
    ``(bfs_level, scan_id)`` and a hop is *up* iff it moves to a strictly
    smaller key.  Channel dependencies then order strictly (up hops
    decrease the key, down hops increase it, down never precedes up), so
    any route set under one key map is deadlock-free — the Autonet
    argument, re-proved per corpus shape by the CDG checker."""
    nodes = [(x, y) for y in range(height) for x in range(width)
             if faults.router_ok((x, y))]
    if not nodes:
        raise UnroutableError("every router failed")
    root = min(nodes, key=lambda n: (n[1], n[0]))
    level = {root: 0}
    frontier = [root]
    while frontier:
        nxt = []
        for v in frontier:
            for delta in _DIRS:
                u = (v[0] + delta[0], v[1] + delta[1])
                if not (0 <= u[0] < width and 0 <= u[1] < height):
                    continue
                if u in level or not faults.router_ok(u) \
                        or not faults.link_ok(u, v):
                    continue
                level[u] = level[v] + 1
                nxt.append(u)
        frontier = nxt
    return {n: (lvl, n[1] * width + n[0]) for n, lvl in level.items()}


def path_is_updown(path: Iterable[Coord], faults: FaultModel,
                   width: int, height: int) -> bool:
    """True iff ``path`` is up*/down*-legal under this fault set's
    canonical key map: unit steps, every hop up until the first down hop,
    only down hops after it."""
    path = list(path)
    keys = updown_keys(faults, width, height)
    if any(v not in keys for v in path):
        return False
    down = False
    for a, b in zip(path[:-1], path[1:]):
        if (b[0] - a[0], b[1] - a[1]) not in _DIRS:
            return False
        if keys[b] < keys[a]:            # up hop
            if down:
                return False
        else:
            down = True
    return True


# --------------------------------------------------------------------------- #
# detour routing
# --------------------------------------------------------------------------- #
def detour_route(src: Coord, dst: Coord, faults: FaultModel,
                 width: int, height: int,
                 rule: str = "west_first") -> tuple[Coord, ...]:
    """Shortest ``rule``-legal fault-free route (memoized per fault set).

    Under ``"west_first"`` clean XY paths are preferred (minimal
    perturbation); an empty fault model returns the exact memoized XY
    entry — same cache key, same tuple.  Raises
    :class:`UnroutableError` when the rule cannot reach ``dst``.
    """
    if faults.empty:
        return xy_route_tuple(src, dst)
    if faults.transient:
        raise ValueError("resolve transient faults with "
                         "FaultModel.at_window() before routing")
    assert rule in DETOUR_RULES, rule
    return memo_route(
        (src, dst, rule, faults.key()),
        lambda: _derive_detour(src, dst, faults, width, height, rule))


def _state_bfs(src: Coord, dst: Coord, start_state, step) -> tuple:
    """Deterministic shortest-path BFS over (node, state) pairs.  ``step``
    yields legal successor states; the first goal state found at the
    shallowest level (fixed expansion order) wins."""
    start = (src, start_state)
    parent: dict = {start: None}
    frontier = [start]
    goal = None
    while frontier and goal is None:
        nxt = []
        for state in frontier:
            for ns in step(state):
                if ns in parent:
                    continue
                parent[ns] = state
                if ns[0] == dst:
                    goal = ns
                    break
                nxt.append(ns)
            if goal is not None:
                break
        frontier = nxt
    if goal is None:
        return ()
    path = []
    s = goal
    while s is not None:
        path.append(s[0])
        s = parent[s]
    return tuple(reversed(path))


def _derive_detour(src: Coord, dst: Coord, faults: FaultModel,
                   width: int, height: int, rule: str) -> tuple[Coord, ...]:
    if not faults.router_ok(src) or not faults.router_ok(dst):
        raise UnroutableError(f"failed router at endpoint of {src}->{dst}")
    if src == dst:
        return (src,)
    xy = xy_route_tuple(src, dst)
    if rule == "west_first" and faults.path_clear(xy):
        return xy                         # XY is west-first-legal

    def in_mesh(v):
        return 0 <= v[0] < width and 0 <= v[1] < height

    if rule == "west_first":
        def step(state):
            (x, y), d = state
            for nd in _DIRS:
                if d is not None and not allowed_turn(d, nd):
                    continue
                v = (x + nd[0], y + nd[1])
                if in_mesh(v) and faults.router_ok(v) \
                        and faults.link_ok((x, y), v):
                    yield (v, nd)
        path = _state_bfs(src, dst, None, step)
    else:
        keys = updown_keys(faults, width, height)
        if src not in keys or dst not in keys:
            raise UnroutableError(
                f"{src}->{dst} disconnected from the healthy fabric")

        def step(state):
            (x, y), down = state
            for nd in _DIRS:
                v = (x + nd[0], y + nd[1])
                if not in_mesh(v) or v not in keys \
                        or not faults.link_ok((x, y), v):
                    continue
                up = keys[v] < keys[(x, y)]
                if up and down:
                    continue              # never up after down
                yield (v, down or not up)
        path = _state_bfs(src, dst, False, step)
    if not path:
        raise UnroutableError(
            f"no {rule} path {src}->{dst} under {len(faults.links)} "
            f"link / {len(faults.routers)} router faults")
    return path


# --------------------------------------------------------------------------- #
# collective tree repair
# --------------------------------------------------------------------------- #
def _neighbors(v: Coord, faults: FaultModel,
               width: int, height: int) -> list[Coord]:
    """Healthy-linked in-mesh neighbors of ``v`` in deterministic order."""
    out = []
    for delta in _DIRS:
        u = (v[0] + delta[0], v[1] + delta[1])
        if (0 <= u[0] < width and 0 <= u[1] < height
                and faults.router_ok(u) and faults.link_ok(u, v)):
            out.append(u)
    return out


def _west_first_parents(root: Coord, faults: FaultModel,
                        width: int, height: int,
                        toward_root: bool) -> dict[Coord, Coord]:
    """Greedy BFS parent assignment keeping every root path west-first
    legal in the packet-flow direction.  Greedy state-claiming can strand
    nodes a different parent choice would reach — on top of the turn
    model's own partial adaptivity — so callers fall back to the updown
    rule on failure."""
    parent: dict[Coord, Coord] = {}
    # hop direction adjacent to v on its root path (toward-root: v's
    # outgoing hop; multicast: the hop into v).
    state: dict[Coord, Optional[Coord]] = {root: None}
    frontier = [root]
    while frontier:
        nxt = []
        for v in frontier:
            for u in _neighbors(v, faults, width, height):
                if u in state:
                    continue
                delta = (u[0] - v[0], u[1] - v[1])
                prev = state[v]
                if toward_root:
                    hd = (-delta[0], -delta[1])       # packet hop u -> v
                    if prev is not None and not allowed_turn(hd, prev):
                        continue
                else:
                    hd = delta                        # packet hop v -> u
                    if prev is not None and not allowed_turn(prev, hd):
                        continue
                state[u] = hd
                parent[u] = v
                nxt.append(u)
        frontier = nxt
    return parent


def _updown_parents(root: Coord, faults: FaultModel,
                    width: int, height: int) -> dict[Coord, Coord]:
    """Two-phase parent assignment spanning the whole healthy connected
    component with up*/down*-legal root paths.

    Phase 1 grows the monotone region: children whose hop to their parent
    *increases* the updown key (so the leaf->root suffix below them is all
    downs).  Phase 2 extends it with key-*decreasing* attachments — an up
    hop composes with any legal path, in either flow direction, because
    reversing an ups-then-downs walk flips every hop and yields another
    ups-then-downs walk.  The same tree is therefore legal for reduce
    (leaf->root) and multicast (root->leaf), and phase 1 + phase 2
    together reach every node the updown spanning tree connects (up the
    BFS tree to its root, down to anywhere).
    """
    keys = updown_keys(faults, width, height)
    if root not in keys:
        raise UnroutableError(f"tree root {root} disconnected")
    parent: dict[Coord, Coord] = {}
    attached = {root}
    frontier = [root]
    while frontier:                       # phase 1: key-increasing chains
        nxt = []
        for v in frontier:
            for u in _neighbors(v, faults, width, height):
                if u in attached or u not in keys or keys[u] >= keys[v]:
                    continue
                attached.add(u)
                parent[u] = v
                nxt.append(u)
        frontier = nxt
    frontier = sorted(attached)           # phase 2: key-decreasing hops
    while frontier:
        nxt = []
        for v in frontier:
            for u in _neighbors(v, faults, width, height):
                if u in attached or u not in keys or keys[u] <= keys[v]:
                    continue
                attached.add(u)
                parent[u] = v
                nxt.append(u)
        frontier = nxt
    return parent


def _repair_tree(root: Coord, participants: Iterable[Coord],
                 faults: FaultModel, width: int, height: int, *,
                 toward_root: bool, rule: str = "west_first"):
    """BFS from the root over the healthy fabric assigning each node one
    parent such that every node's root path is ``rule``-legal in the
    packet-flow direction (leaf->root for reduce, root->leaf for
    multicast); pruned to the participants' root paths.

    Legality is inductive on the parent chain, so every tree *segment*
    (a contiguous subpath of some member's root path) inherits it — the
    property the per-segment INA packets need.
    """
    from .collective.trees import CollectiveTree
    assert rule in DETOUR_RULES, rule
    parts = frozenset(participants)
    if not faults.router_ok(root):
        raise UnroutableError(f"tree root {root} has a failed router")
    if rule == "updown":
        parent = _updown_parents(root, faults, width, height)
    else:
        parent = _west_first_parents(root, faults, width, height,
                                     toward_root)
    keep = {root}
    for p in sorted(parts):
        v = p
        chain = []
        while v not in keep:
            if v != root and v not in parent:
                raise UnroutableError(
                    f"participant {p} unreachable from root {root} "
                    f"under the {rule} rule")
            chain.append(v)
            v = parent[v]
        keep.update(chain)
    pruned = {u: parent[u] for u in sorted(keep) if u != root}
    tree = CollectiveTree(root=root, participants=parts, parent=pruned,
                          order="xy")
    tree.validate()
    return tree


def repair_reduction_tree(root: Coord, participants: Iterable[Coord],
                          faults: FaultModel, width: int, height: int,
                          rule: str = "west_first"):
    """Fault-avoiding reduction tree (packets flow leaf -> root)."""
    return _repair_tree(root, participants, faults, width, height,
                        toward_root=True, rule=rule)


def repair_multicast_tree(root: Coord, participants: Iterable[Coord],
                          faults: FaultModel, width: int, height: int,
                          rule: str = "west_first"):
    """Fault-avoiding multicast tree (packets flow root -> leaf)."""
    return _repair_tree(root, participants, faults, width, height,
                        toward_root=False, rule=rule)


# --------------------------------------------------------------------------- #
# participant remapping (dead PEs hand their shard to a healthy neighbor)
# --------------------------------------------------------------------------- #
def remap_participants(participants: Iterable[Coord], faults: FaultModel,
                       width: Optional[int] = None,
                       height: Optional[int] = None,
                       ) -> tuple[list[Coord], dict[Coord, Coord]]:
    """``(usable participants sorted, {dead -> nearest usable})``.

    A participant is usable when its PE survives *and* (given the mesh
    shape) its router sits in the fabric's main connected component — a
    healthy PE whose links all failed is as stranded as a dead one.  The
    nearest usable participant (Manhattan distance, coordinate tie-break)
    takes over each dead participant's operand — it holds or recomputes
    the shard, so the collective's algebra closes over the usable set
    exactly once per original contribution owner.
    """
    parts = sorted(set(participants))
    if width is not None and height is not None:
        keys = updown_keys(faults, width, height)
        usable = lambda p: faults.pe_ok(p) and p in keys
    else:
        usable = faults.pe_ok
    healthy = [p for p in parts if usable(p)]
    if not healthy:
        raise UnroutableError("no healthy participants left")
    mapping: dict[Coord, Coord] = {}
    for dead in parts:
        if usable(dead):
            continue
        mapping[dead] = min(
            healthy,
            key=lambda h: (abs(h[0] - dead[0]) + abs(h[1] - dead[1]), h))
    return healthy, mapping


def remap_root(root: Coord, healthy: list[Coord],
               faults: FaultModel) -> Coord:
    """The collective root after faults: unchanged when it survives as a
    usable participant, otherwise the nearest healthy participant
    (deterministic)."""
    if root in healthy:
        return root
    return min(healthy,
               key=lambda h: (abs(h[0] - root[0]) + abs(h[1] - root[1]), h))
