"""Event-driven wormhole NoC simulator reproducing the paper's evaluation.

The paper evaluates INA with a cycle-accurate C++ mesh simulator [22] plus the
Orion-3.0 power model [24].  This package is a faithful Python port at packet
granularity: XY-routed wormhole traversal with per-link occupancy reservation
(contention + flit serialization are modeled cycle-exactly; flit-level credit
stalls are folded into link occupancy), the paper's 4-cycle router / 1-cycle
link / 128-bit flit configuration (Table III), and an event-count energy model
with Orion-style per-component energies.
"""
from .compiled import CompiledProgram, compile_program, compiled_disabled
from .router import EnergyLedger, NocConfig
from .simcache import (SIM_CACHE, SimCache, fresh_sim_cache,
                       sim_cache_disabled)
from .topology import Mesh, route, xy_route, yx_route
from .simulator import NocSim
from .traffic import (CompiledWindow, LayerResult, layer_plan,
                      simulate_layer, simulate_network)
from .vectorized import (VectorProgram, lower_program, run_vectorized,
                         vector_stats, vectorized_disabled)

__all__ = ["NocConfig", "EnergyLedger", "Mesh", "route", "xy_route",
           "yx_route", "NocSim", "LayerResult", "layer_plan",
           "simulate_layer", "simulate_network", "SIM_CACHE", "SimCache",
           "sim_cache_disabled", "fresh_sim_cache", "CompiledProgram",
           "CompiledWindow", "compile_program", "compiled_disabled",
           "VectorProgram", "lower_program", "run_vectorized",
           "vector_stats", "vectorized_disabled"]
