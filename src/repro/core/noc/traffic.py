"""WS(+/-INA) and OS dataflow traffic generation + per-layer simulation.

Mapping (paper Fig. 3): filters are split into P# parts distributed among P#
vertically-adjacent PEs of one column ("chains"); G = floor(N/P#) chains per
column; each router hosts E PEs, so one chain keeps E filters resident.  Per
accumulation round each chain finishes E output activations.

Architecture (paper [12], "two-way streaming architecture"): weights/inputs
are delivered over dedicated row streaming buses (cheap wires, no router
traversal); the mesh NoC proper carries psum-accumulation and gather traffic.
Hence the +/-INA comparison (Figs 7-9) is decided by NoC traffic and the
WS-vs-OS comparison (Figs 10-12) additionally by streaming volume/overlap.

Traffic per accumulation round:
  * WS without INA (Fig. 4a): every chain runs an eject->add->inject unicast
    relay over its P#-1 hops (2-3 flit packets, paper Table III); the final
    results are collected to the column's memory port (``baseline_collection``
    selects a shared column gather packet or per-chain result unicasts).
  * WS with INA (Fig. 4b): one gather packet per column rides south,
    accumulating each chain in-network (the INA block adds the local operand
    inside the router pipeline) and collecting tails - relay traffic is gone.
  * OS with gather [12]: psums accumulate locally (output-stationary), the
    same gather collects finished outputs; but weights are *not* stationary:
    weight (and input) streaming re-occurs continuously on the buses.

Latency: accumulation rounds are simulated back-to-back in a window of
``sim_rounds`` rounds through the event-driven NoC and extrapolated from the
measured marginal round period (rounds are homogeneous); energy is exact
(event counts scale linearly in rounds).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

from ..ina_model import DEFAULT_Q_BITS, ConvLayer, p_num
from .compiled import (CompiledProgram, UncompilableProgram, compile_program,
                       compiled_enabled)
from .router import EnergyLedger, NocConfig
from .simcache import SIM_CACHE
from .simulator import NocSim
from .vectorized import vectorized_enabled, window_result

MODES = ("ws_ina", "ws_noina", "os_gather")


@dataclass
class LayerResult:
    name: str
    mode: str
    e_pes: int
    rounds: int
    fills: int
    latency_cycles: float
    fill_cycles: float
    noc_energy_pj: float
    stream_energy_pj: float

    @property
    def total_energy_pj(self) -> float:
        return self.noc_energy_pj + self.stream_energy_pj

    @property
    def network_power(self) -> float:
        """Average network power (energy per cycle; pJ/cycle ~ mW at 1 GHz)."""
        return self.total_energy_pj / max(self.latency_cycles, 1.0)


@dataclass(frozen=True)
class _Plan:
    p: int                    # P#: PEs per chain (clamped to the column height)
    g: int                    # chains per column
    rounds: int               # accumulation/gather rounds for the whole layer
    fills: int                # weight (re)distribution phases
    passes: int               # sequential chain segments when P# > height
    unicast_flits: int
    gather_flits: int
    weight_bits: int              # whole-filter weight bits at the plan's q
    weight_bits_per_router: int   # per fill


@lru_cache(maxsize=None)
def _plan(layer: ConvLayer, cfg: NocConfig, e_pes: int, mode: str,
          q_bits: int = DEFAULT_Q_BITS, groups: Optional[int] = None) -> _Plan:
    """Lay ``layer`` onto the (possibly rectangular) mesh under ``mode``.

    Memoized: plans are pure functions of frozen inputs, and the mapper's
    analytic ranking re-plans the same (layer, mapping) pairs constantly.

    ``q_bits`` scales the weight precision through Eqs. (1)-(2); ``groups``
    overrides the chains-per-column count G (mapper search axis; clamped to
    the feasible 1..H//P# range).  Defaults reproduce the paper's fixed
    placement bit-for-bit.  When a filter's chain is taller than a column
    (P# > H — GEMM reductions, small meshes), the column accumulates it in
    ``ceil(P#/H)`` sequential passes of H chained PEs, matching the
    ``ina_rounds`` multi-row-chain model.
    """
    w, h = cfg.width, cfg.height
    weight_bits = layer.C * layer.R * layer.R * q_bits
    if mode.startswith("ws"):
        p_req = p_num(layer, q_bits=q_bits)
        p = min(p_req, h)
        passes = math.ceil(p_req / h)
        if passes > 1:
            g = 1
            rounds = passes * math.ceil((layer.F / (w * e_pes))
                                        * layer.outputs)
        else:
            g = h // p if groups is None else max(1, min(groups, h // p))
            rounds = math.ceil((layer.F / (w * e_pes)) * (layer.outputs / g))
        fills = passes * max(1, math.ceil(layer.F / (w * g * e_pes)))
        w_bits_router = math.ceil(weight_bits / p_req) * e_pes
    else:  # OS: whole filters per PE; re-streamed continuously (no stationarity).
        p, g, passes = 1, max(1, h), 1
        rounds = math.ceil(layer.F * layer.outputs / (w * h * e_pes))
        fills = 0
        w_bits_router = weight_bits * e_pes
    # Gather packet sized by the results it collects: one per chain (G) per
    # router-PE (E).  For P#=1 layers this reproduces Table III's static
    # 3/5/9(/17)-flit gather packets (8 nodes x E results on the 8x8 mesh).
    return _Plan(
        p=p, g=g, rounds=rounds, fills=fills, passes=passes,
        unicast_flits=cfg.unicast_flits(e_pes),
        gather_flits=cfg.gather_flits(g * e_pes),
        weight_bits=weight_bits,
        weight_bits_per_router=w_bits_router,
    )


def layer_plan(layer: ConvLayer, cfg: NocConfig, e_pes: int, mode: str,
               q_bits: int = DEFAULT_Q_BITS,
               groups: Optional[int] = None) -> _Plan:
    """Public planner entry point (the mapper prunes/replays from plans)."""
    return _plan(layer, cfg, e_pes, mode, q_bits, groups)


# --------------------------------------------------------------------------- #
# Streaming phases (two-way row buses; contention-free, analytic)
# --------------------------------------------------------------------------- #
def _fill_phase(plan: _Plan, cfg: NocConfig, ledger: EnergyLedger) -> float:
    """One WS weight-distribution barrier: all routers filled over row buses."""
    w, h = cfg.width, cfg.height
    flits_per_router = cfg.payload_flits(plan.weight_bits_per_router)
    # Each of the two bus directions serves half a row's routers, one flit
    # per cycle (rows are ``width`` routers long).
    cycles = (w // cfg.stream_buses_per_row) * flits_per_router
    # Bus energy: every flit drives on average half its direction's segment.
    ledger.stream_flit_segments += w * h * flits_per_router * max(1, w // 4)
    return float(cycles)


def _input_stream_round(plan: _Plan, cfg: NocConfig,
                        ledger: EnergyLedger) -> float:
    """Per-round input streaming (bus cycles per row); common to WS and OS."""
    bits = plan.weight_bits / (plan.p * cfg.ws_input_reuse)
    flits = bits / cfg.flit_bits
    ledger.stream_flit_segments += flits * cfg.width   # broadcast spans the row
    return flits / cfg.stream_buses_per_row


def _os_weight_stream_round(plan: _Plan, cfg: NocConfig,
                            ledger: EnergyLedger) -> float:
    """Per-round OS weight re-streaming (bus cycles per row).

    OS keeps outputs stationary, so weights flow continuously; a streamed
    weight word is only reused ``os_weight_reuse``-wide (one assignment
    wave), unlike WS where a distributed weight serves all output pixels.
    """
    flits = plan.weight_bits / (cfg.flit_bits * cfg.os_weight_reuse)
    ledger.stream_flit_segments += flits * cfg.width
    return flits / cfg.os_stream_bw


# --------------------------------------------------------------------------- #
# Accumulation + gather rounds (planner-emitted schedule, event-driven replay)
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class CompiledWindow:
    """One window's packet program, recorded once and replayed flat.

    The program (sources, destinations, flit counts, VCs, dependency
    edges) of a WS/OS window depends only on the plan-shape key, so it is
    compiled on first miss and replayed via
    :class:`~repro.core.noc.compiled.CompiledProgram` without rebuilding
    the PacketOps or the engine's per-op closures (DESIGN.md S10).
    """

    key: tuple
    program: CompiledProgram

    def replay(self) -> tuple[float, EnergyLedger]:
        latency, ledger, _, _ = self.program.run()
        return float(latency), ledger


#: Plan-shape key -> CompiledWindow.  Populated only while the result
#: cache is disabled (``--no-cache`` replays the same window repeatedly);
#: with the cache on, a window's first replay lands in SIM_CACHE and the
#: replicated program would be dead weight.
_WINDOW_PROGRAMS: dict = {}

#: Window-length-free key -> one compiled round; windows of any length
#: replicate it (rounds are dependency-disjoint by construction).
_ROUND_PROGRAMS: dict = {}


def clear_compiled_caches() -> None:
    """Forget every recorded program/plan (cold-start measurement aid).

    Never needed for correctness — programs are pure functions of their
    keys — only to measure genuinely cold runs (``bench_mapper``) or to
    bound memory.
    """
    from . import simulator, topology, vectorized

    _WINDOW_PROGRAMS.clear()
    _ROUND_PROGRAMS.clear()
    _plan.cache_clear()
    simulator.clear_link_caches()
    topology.clear_route_caches()
    vectorized.clear_vector_caches()


def _compiled_window(key: tuple, cfg: NocConfig, mode: str, window: int,
                     plan: _Plan, e_pes: int) -> Optional[CompiledWindow]:
    """Build (or fetch) the CompiledWindow for a plan-shape key."""
    from .collective.schedule import ws_round_program

    cw = _WINDOW_PROGRAMS.get(key)
    if cw is not None:
        return cw
    round_key = (cfg, mode, plan.g, plan.p, plan.gather_flits,
                 plan.unicast_flits, e_pes)
    base = _ROUND_PROGRAMS.get(round_key)
    if base is None:
        prog = ws_round_program(cfg, mode, 1, g=plan.g, p=plan.p,
                                gather_flits=plan.gather_flits,
                                unicast_flits=plan.unicast_flits,
                                e_pes=e_pes)
        try:
            base = compile_program(prog, cfg)
        except UncompilableProgram:     # exotic config: heap fallback
            return None
        _ROUND_PROGRAMS[round_key] = base
    cw = CompiledWindow(key, base.replicate(window))
    if not SIM_CACHE.enabled:
        _WINDOW_PROGRAMS[key] = cw
    return cw


def _sim_rounds_window(plan: _Plan, cfg: NocConfig, mode: str, window: int,
                       e_pes: int = 1) -> tuple[float, EnergyLedger]:
    """Simulate ``window`` back-to-back rounds; return (makespan, ledger).

    The per-round traffic — column gather packets with in-network
    accumulation (``ws_ina``/``os_gather``) or Fig. 4(a) relay chains gated
    before the collection (``ws_noina``) — is emitted by the collective
    planner (:func:`~repro.core.noc.collective.schedule.ws_round_program`)
    and replayed on the event-driven simulator: through a
    :class:`CompiledWindow` normally, or through the closure-based heap
    engine under :func:`~repro.core.noc.compiled.compiled_disabled`
    (ground truth; both are bit-identical, see tests/test_perf_layer.py).

    Results are memoized per plan shape in :data:`~repro.core.noc.simcache.
    SIM_CACHE` — the window program depends on the key below and not on the
    layer identity, so whole-network sweeps replay each distinct program
    once (see EXPERIMENTS.md for the cache design).
    """
    from .collective.engine import run_program
    from .collective.schedule import ws_round_program

    key = (cfg, mode, window, plan.g, plan.p, plan.gather_flits,
           plan.unicast_flits, e_pes)
    hit = SIM_CACHE.get(key)
    if hit is not None:
        return hit
    if compiled_enabled():
        if vectorized_enabled():
            vec = window_result(cfg, mode, window, plan.g, plan.p,
                                plan.gather_flits, plan.unicast_flits, e_pes)
            if vec is not None:
                latency, ledger = vec
                SIM_CACHE.put(key, latency, ledger)
                return latency, ledger
        cw = _compiled_window(key, cfg, mode, window, plan, e_pes)
        if cw is not None:
            latency, ledger = cw.replay()
            SIM_CACHE.put(key, latency, ledger)
            return latency, ledger
    sim = NocSim(cfg)
    prog = ws_round_program(cfg, mode, window, g=plan.g, p=plan.p,
                            gather_flits=plan.gather_flits,
                            unicast_flits=plan.unicast_flits, e_pes=e_pes)
    res = run_program(prog, cfg, sim=sim)
    SIM_CACHE.put(key, float(res.latency_cycles), sim.ledger)
    return float(res.latency_cycles), sim.ledger


def _accum_phase(plan: _Plan, cfg: NocConfig, mode: str,
                 sim_rounds: int, e_pes: int) -> tuple[float, EnergyLedger]:
    rounds = plan.rounds
    if rounds <= 0:
        return 0.0, EnergyLedger()
    w_big = min(rounds, max(1, sim_rounds))   # at least one simulated round
    t_big, led_big = _sim_rounds_window(plan, cfg, mode, w_big, e_pes)
    if rounds <= w_big:
        return t_big, led_big
    w_small = max(1, w_big // 2)
    if w_small == w_big:
        # Single-round window (sim_rounds=1): no second measurement point;
        # the whole window is one round, so it *is* the marginal period.
        marginal = t_big / w_big
    else:
        t_small, _ = _sim_rounds_window(plan, cfg, mode, w_small, e_pes)
        marginal = (t_big - t_small) / (w_big - w_small)
    return t_big + (rounds - w_big) * marginal, led_big.scaled(rounds / w_big)


# --------------------------------------------------------------------------- #
def simulate_layer(layer: ConvLayer, mode: str, cfg: NocConfig = NocConfig(),
                   e_pes: int = 1, sim_rounds: int = 32,
                   q_bits: int = DEFAULT_Q_BITS,
                   groups: Optional[int] = None) -> LayerResult:
    """Simulate one CONV/GEMM layer under a dataflow mode.

    ``q_bits``/``groups`` are mapper search axes (see :func:`_plan`); the
    defaults reproduce the paper's fixed placement.
    """
    assert mode in MODES, mode
    plan = _plan(layer, cfg, e_pes, mode, q_bits, groups)
    stream_ledger = EnergyLedger()

    noc_cycles, noc_ledger = _accum_phase(plan, cfg, mode, sim_rounds, e_pes)

    # Per-round input streaming paces the steady state together with the NoC
    # (whichever is slower); its energy scales with rounds.
    in_round = _input_stream_round(plan, cfg, stream_ledger)
    stream_ledger.stream_flit_segments *= max(plan.rounds, 1)

    if mode.startswith("ws"):
        # Weight barrier: distribution must finish before MACs/psums start.
        # One fill is computed and accumulated ``fills`` times (alexnet's FC
        # tail alone runs thousands of identical fills per layer); the
        # repeated float adds are kept so the ledger stays bit-identical to
        # the historical per-fill loop, but the phase itself is derived once.
        fill_cycles = 0
        if plan.fills:
            tmp = EnergyLedger()
            one = _fill_phase(plan, cfg, tmp)
            seg = stream_ledger.stream_flit_segments
            for _ in range(plan.fills):
                seg += tmp.stream_flit_segments
            stream_ledger.stream_flit_segments = seg
            fill_cycles = one * plan.fills
        latency = fill_cycles + max(noc_cycles, in_round * plan.rounds)
    else:
        # OS overlaps weight+input distribution with execution (paper SIV.B):
        # the layer is paced by the slower of streaming and the gather NoC.
        tmp = EnergyLedger()
        w_round = _os_weight_stream_round(plan, cfg, tmp)
        stream_ledger.stream_flit_segments += tmp.stream_flit_segments * plan.rounds
        fill_cycles = (w_round + in_round) * plan.rounds
        latency = max(fill_cycles, noc_cycles)

    return LayerResult(
        name=layer.name, mode=mode, e_pes=e_pes,
        rounds=plan.rounds, fills=plan.fills,
        latency_cycles=latency, fill_cycles=fill_cycles,
        noc_energy_pj=noc_ledger.network_energy_pj(cfg),
        stream_energy_pj=stream_ledger.energy_pj(cfg),
    )


def simulate_network(layers: list[ConvLayer], mode: str,
                     cfg: NocConfig = NocConfig(), e_pes: int = 1,
                     sim_rounds: int = 32,
                     q_bits: int = DEFAULT_Q_BITS) -> dict:
    """Whole-network totals (layers execute back-to-back, as in the paper)."""
    results = [simulate_layer(l, mode, cfg, e_pes, sim_rounds, q_bits)
               for l in layers]
    latency = sum(r.latency_cycles for r in results)
    noc_e = sum(r.noc_energy_pj for r in results)
    stream_e = sum(r.stream_energy_pj for r in results)
    return {
        "mode": mode, "e_pes": e_pes, "layers": results,
        "latency_cycles": latency,
        "noc_energy_pj": noc_e,
        "stream_energy_pj": stream_e,
        "total_energy_pj": noc_e + stream_e,
        "network_power": (noc_e + stream_e) / max(latency, 1.0),
    }
