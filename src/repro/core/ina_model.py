"""Analytical model of In-Network Accumulation (INA) — Eqs. (1)-(4) of the paper.

The paper models a Weight-Stationary (WS) dataflow on an N x N mesh NoC with
1 PE per router and M bits of scratch memory per PE.  For a CONV layer with
R x R kernels, C input channels, F filters, O x O output feature map and q-bit
precision:

  Eq. (1)  INA is needed   iff  C*R*R*q > M
  Eq. (2)  P#   = ceil(C*R*R*q / M)            PEs sharing one filter
  Eq. (3)  INA# = ceil( (F/N) * (O*O / floor(N/P#)) )   accumulation rounds
  Eq. (4)  INA#E = ceil( (F/(N*E)) * (O*O / floor(N/P#)) )  for E PEs/router

Note (paper anomaly, see DESIGN.md S7): Tables I/II say "M = 32KB" but only
reproduce with M = 32 Kbit = 32768 bits; we default to 32768.

The Eq. (1)-(4) helpers only touch a layer's R/C/F and output count, so they
accept any shape exposing that interface — :class:`ConvLayer` here, and the
GEMM shapes of :mod:`repro.core.ops` (R=1, C=K, F=N, outputs=M), which is
what lets the mapper search FC and transformer layers with the same model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

#: Paper default parameters (Tables I & II footnotes).
DEFAULT_M_BITS = 32 * 1024   # 32 Kbit scratch memory per PE (see DESIGN.md S7)
DEFAULT_Q_BITS = 32          # psum / weight precision


@dataclass(frozen=True)
class ConvLayer:
    """One CONV layer as parameterised by the paper: R, C, F, O (+stride for traces)."""

    name: str
    R: int          # kernel spatial size (R x R)
    C: int          # input channels
    F: int          # number of filters (output channels)
    O: int          # output feature map spatial size (O x O)
    stride: int = 1

    @property
    def outputs(self) -> int:
        """Output activations per filter (the O x O pixels)."""
        return self.O * self.O

    @property
    def macs(self) -> int:
        """MAC count for the layer (one input image)."""
        return self.R * self.R * self.C * self.F * self.outputs

    @property
    def weight_bits(self) -> int:
        return self.C * self.R * self.R * DEFAULT_Q_BITS


def needs_ina(layer: ConvLayer, m_bits: int = DEFAULT_M_BITS,
              q_bits: int = DEFAULT_Q_BITS) -> bool:
    """Eq. (1): INA is required iff one filter's weights exceed PE memory."""
    return layer.C * layer.R * layer.R * q_bits > m_bits


def p_num(layer: ConvLayer, m_bits: int = DEFAULT_M_BITS,
          q_bits: int = DEFAULT_Q_BITS) -> int:
    """Eq. (2): number of PEs a single filter's weights are split across."""
    return math.ceil(layer.C * layer.R * layer.R * q_bits / m_bits)


def ina_rounds(layer: ConvLayer, n: int, e_pes_per_router: int = 1,
               m_bits: int = DEFAULT_M_BITS, q_bits: int = DEFAULT_Q_BITS,
               force: bool = False) -> Optional[int]:
    """Eqs. (3)/(4): rounds of INA to complete one CONV layer on an N x N mesh.

    Returns ``None`` ("NA" in the paper's tables) when the layer does not need
    INA per Eq. (1) — unless ``force`` is set (used to reproduce the VGG-16
    CONV3 row, which the paper lists despite P#=1; DESIGN.md S7).
    """
    if not force and not needs_ina(layer, m_bits, q_bits):
        return None
    p = p_num(layer, m_bits, q_bits)
    groups = n // p                      # floor(N / P#): filter groups per mesh row
    if groups == 0:
        # A filter's chain is taller than the mesh (P# > N): the paper's
        # tables never hit this case, but the mapper's search space (GEMM
        # reductions, small mesh columns) does.  The column accumulates the
        # filter in ceil(P#/N) sequential passes of N chained PEs each
        # (partial results parked at the port PE between passes), so every
        # output costs that many gather rounds — clamping to one group, as
        # the old fallback did, undercounts rounds by the pass factor.
        passes = math.ceil(p / n)
        return passes * math.ceil((layer.F / (n * e_pes_per_router))
                                  * layer.outputs)
    return math.ceil((layer.F / (n * e_pes_per_router))
                     * (layer.outputs / groups))


def ina_table(layers: list[ConvLayer], n: int, e_pes_per_router: int = 1,
              m_bits: int = DEFAULT_M_BITS, q_bits: int = DEFAULT_Q_BITS,
              ) -> list[dict]:
    """Reproduce a Table-I/II-style table: one row per layer."""
    rows = []
    for layer in layers:
        rows.append({
            "layer": layer.name,
            "R": layer.R, "C": layer.C, "F": layer.F, "O": layer.O,
            "P#": p_num(layer, m_bits, q_bits),
            "INA#": ina_rounds(layer, n, e_pes_per_router, m_bits, q_bits),
        })
    return rows


def total_ina_rounds(layers: list[ConvLayer], n: int, e: int = 1,
                     m_bits: int = DEFAULT_M_BITS,
                     q_bits: int = DEFAULT_Q_BITS) -> int:
    """Total accumulation rounds for a whole network (NA layers contribute 0).

    ``q_bits`` is forwarded to :func:`ina_rounds` like every other Eq. (1)-(4)
    helper, so mixed-precision sweeps (q=8/16) flip Eq. (1) consistently.
    """
    return sum(ina_rounds(l, n, e, m_bits, q_bits) or 0 for l in layers)
