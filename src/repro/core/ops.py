"""GEMM layer shapes and lowerings — the non-CNN front-end of the mapper.

The paper only maps CONV layers (Eqs. 1-4), but its WS placement is really a
statement about *reductions*: split a filter's C*R*R-long dot product across
P# chained PEs and accumulate over the NoC.  A GEMM ``C[M,N] = A[M,K] @
B[K,N]`` is the same computation with R=1 — the reduction dim K plays the
input channels C, the N output columns play the filters F, and the M rows
play the O*O output pixels.  :class:`GemmLayer` exposes exactly the shape
interface the analytical model (:mod:`repro.core.ina_model`) and the traffic
planner (:mod:`repro.core.noc.traffic`) consume, so FC layers, im2col-lowered
CONVs and transformer projections flow through the simulator unchanged.

Two lowerings are provided:

* :func:`im2col` — a CONV layer as the equivalent GEMM (M=O*O, K=C*R*R,
  N=F); preserves MACs, P# and INA round counts exactly.
* :func:`transformer_gemms` — one decoder block's projection/MLP GEMMs
  derived from a :class:`repro.configs.base.ModelConfig` (attention q/k/v/o
  plus gate/up/down).  Whole-model totals scale linearly in depth, so
  mapper ratios over one block are depth-invariant.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Union

from .ina_model import DEFAULT_Q_BITS, ConvLayer

if TYPE_CHECKING:                       # pure typing; configs import no jax
    from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class GemmLayer:
    """One GEMM ``C[M,N] = A[M,K] @ B[K,N]`` under the paper's WS mapping."""

    name: str
    M: int          # output rows (tokens / batch pixels)
    K: int          # reduction (contraction) dimension
    N: int          # output columns (weight matrix width)

    # ---- Eq. (1)-(4) shape interface (shared with ConvLayer) -------------
    @property
    def R(self) -> int:
        return 1

    @property
    def C(self) -> int:
        return self.K

    @property
    def F(self) -> int:
        return self.N

    @property
    def outputs(self) -> int:
        """Output activations per filter (the M rows)."""
        return self.M

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N

    @property
    def weight_bits(self) -> int:
        return self.K * DEFAULT_Q_BITS


#: Any layer shape the analytical model / traffic planner accepts.
LayerShape = Union[ConvLayer, GemmLayer]


def im2col(conv: ConvLayer) -> GemmLayer:
    """Lower a CONV layer to its im2col GEMM (exact WS-mapping equivalent)."""
    return GemmLayer(f"{conv.name}.im2col", M=conv.O * conv.O,
                     K=conv.C * conv.R * conv.R, N=conv.F)


def transformer_gemms(cfg: "ModelConfig", tokens: int = 256) -> list[GemmLayer]:
    """One decoder block's GEMMs for a ``configs/`` model shape.

    ``tokens`` is the token tile mapped per pass (the M dimension).  GQA
    models get narrower K/V projections (n_kv_heads); the MLP emits the
    gate/up/down trio used by every SwiGLU config in the registry.
    """
    d, hd = cfg.d_model, cfg.resolved_head_dim
    name = cfg.name
    return [
        GemmLayer(f"{name}.wq", M=tokens, K=d, N=cfg.n_heads * hd),
        GemmLayer(f"{name}.wk", M=tokens, K=d, N=cfg.n_kv_heads * hd),
        GemmLayer(f"{name}.wv", M=tokens, K=d, N=cfg.n_kv_heads * hd),
        GemmLayer(f"{name}.wo", M=tokens, K=cfg.n_heads * hd, N=d),
        GemmLayer(f"{name}.w_gate", M=tokens, K=d, N=cfg.d_ff),
        GemmLayer(f"{name}.w_up", M=tokens, K=d, N=cfg.d_ff),
        GemmLayer(f"{name}.w_down", M=tokens, K=cfg.d_ff, N=d),
    ]
