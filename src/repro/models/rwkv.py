"""RWKV6 (Finch) language model: time-mix + channel-mix stacks."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import _dtype, remat_policy
from repro.parallel.tp import ParallelCtx, constrain_acts


def init_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,)),
        "tmix": S.init_rwkv_tmix(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,)),
        "cmix": S.init_rwkv_cmix(k2, cfg),
    }


def init(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 2)
    return {
        "embed": L.dense_init(keys[-2], (cfg.vocab, cfg.d_model)),
        "ln_in": jnp.ones((cfg.d_model,)),
        "layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[init_layer(keys[i], cfg) for i in range(cfg.n_layers)]),
        "ln_f": jnp.ones((cfg.d_model,)),
        "lm_head": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab),
                                in_dim=cfg.d_model),
    }


def layer_fwd(lp, x, cfg, pctx, caches=None):
    """caches: None (train/prefill from scratch) or dict for decode."""
    if caches is None:
        y, _, _ = S.rwkv_tmix(lp["tmix"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                              cfg, pctx)
        x = x + y
        y, _ = S.rwkv_cmix(lp["cmix"], L.rms_norm(x, lp["ln2"], cfg.norm_eps),
                           cfg, pctx)
        return constrain_acts(x + y, pctx), None
    y, state, tprev = S.rwkv_tmix(
        lp["tmix"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, pctx,
        state=caches["state"], prev=caches["tprev"], single_step=True)
    x = x + y
    y, cprev = S.rwkv_cmix(lp["cmix"], L.rms_norm(x, lp["ln2"], cfg.norm_eps),
                           cfg, pctx, prev=caches["cprev"])
    return x + y, {"state": state, "tprev": tprev, "cprev": cprev}


def hidden_states(params, cfg: ModelConfig, tokens, pctx=None):
    x = L.embed(params["embed"], tokens, _dtype(cfg))
    x = L.rms_norm(x, params["ln_in"], cfg.norm_eps)

    def body(carry, lp):
        return layer_fwd(lp, carry, cfg, pctx)[0], None

    x = constrain_acts(x, pctx)
    x, _ = jax.lax.scan(jax.checkpoint(body, policy=remat_policy(cfg)),
                        x, params["layers"],
                        unroll=True if cfg.scan_unroll else 1)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def forward(params, cfg, batch, pctx=None):
    return L.logits_head(hidden_states(params, cfg, batch["tokens"], pctx),
                         params["lm_head"], pctx)


def loss(params, cfg, batch, pctx=None):
    return L.xent_loss(forward(params, cfg, batch, pctx), batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    h, hd = S.rwkv_dims(cfg)
    l = cfg.n_layers
    return {
        "state": jnp.zeros((l, batch, h, hd, hd), jnp.float32),
        "tprev": jnp.zeros((l, batch, 1, cfg.d_model), _dtype(cfg)),
        "cprev": jnp.zeros((l, batch, 1, cfg.d_model), _dtype(cfg)),
    }


def decode_step(params, cfg: ModelConfig, batch, cache, pctx=None):
    x = L.embed(params["embed"], batch["tokens"], _dtype(cfg))
    x = L.rms_norm(x, params["ln_in"], cfg.norm_eps)

    def body(x, lp_cache):
        lp, st, tp, cp = lp_cache
        x, new = layer_fwd(lp, x, cfg, pctx,
                           caches={"state": st, "tprev": tp, "cprev": cp})
        return x, (new["state"], new["tprev"], new["cprev"])

    x, (st, tp, cp) = jax.lax.scan(
        body, x, (params["layers"], cache["state"], cache["tprev"],
                  cache["cprev"]),
        unroll=True if cfg.scan_unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.logits_head(x, params["lm_head"], pctx), \
        {"state": st, "tprev": tp, "cprev": cp}
