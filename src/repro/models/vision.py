"""Llama-3.2-Vision backbone: dense decoder + gated cross-attention layers.

The ViT frontend is a stub: ``input_specs()`` supplies precomputed patch
embeddings [B, n_media, d_model].  Every ``cross_attn_every``-th layer is a
gated cross-attention block (tanh-gated, as in Llama-3.2), executed as an
outer scan over layer groups so the HLO stays one-group sized.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.transformer import _dtype, remat_policy
from repro.parallel.tp import ParallelCtx, col_linear, constrain_acts, row_linear


def _groups(cfg: ModelConfig) -> tuple[int, int]:
    per = cfg.cross_attn_every
    assert cfg.n_layers % per == 0
    return cfg.n_layers // per, per


def init_xattn_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "lnx": jnp.ones((cfg.d_model,)),
        "xattn": L.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.resolved_head_dim, qk_norm=True),
        "gate_attn": jnp.zeros(()),
        "ln2": jnp.ones((cfg.d_model,)),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff),
        "gate_mlp": jnp.zeros(()),
    }


def init(cfg: ModelConfig, key) -> dict:
    g, per = _groups(cfg)
    keys = jax.random.split(key, cfg.n_layers + g + 2)
    self_layers = [T.init_layer(keys[i], cfg) for i in range(cfg.n_layers - g)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *self_layers)
    stacked = jax.tree.map(
        lambda a: a.reshape(g, per - 1, *a.shape[1:]), stacked)
    xlayers = [init_xattn_layer(keys[cfg.n_layers - g + i], cfg)
               for i in range(g)]
    return {
        "embed": L.dense_init(keys[-2], (cfg.vocab, cfg.d_model)),
        "groups": stacked,
        "xlayers": jax.tree.map(lambda *xs: jnp.stack(xs), *xlayers),
        "ln_f": jnp.ones((cfg.d_model,)),
        "lm_head": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab),
                                in_dim=cfg.d_model),
    }


def xattn_fwd(xp, x, media, cfg, pctx, media_kv=None):
    """Gated cross-attention + MLP.  media: [B, M, D] patch embeddings."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h = L.rms_norm(x, xp["lnx"], cfg.norm_eps)
    q = col_linear(h, xp["xattn"]["wq"], pctx).reshape(b, s, cfg.n_heads, hd)
    q = L.rms_norm(q, xp["xattn"]["q_norm"], cfg.norm_eps)
    if media_kv is None:
        k = col_linear(media, xp["xattn"]["wk"], pctx).reshape(
            b, media.shape[1], cfg.n_kv_heads, hd)
        k = L.rms_norm(k, xp["xattn"]["k_norm"], cfg.norm_eps)
        v = col_linear(media, xp["xattn"]["wv"], pctx).reshape(
            b, media.shape[1], cfg.n_kv_heads, hd)
    else:
        k, v = media_kv
    o = L.attn_full(q, k, v, causal=False)
    o = row_linear(o.reshape(b, s, cfg.n_heads * hd), xp["xattn"]["wo"], pctx)
    x = x + jnp.tanh(xp["gate_attn"]).astype(x.dtype) * o
    y = L.mlp_block(xp["mlp"], L.rms_norm(x, xp["ln2"], cfg.norm_eps), pctx)
    x = x + jnp.tanh(xp["gate_mlp"]).astype(x.dtype) * y
    return x


def hidden_states(params, cfg: ModelConfig, tokens, media, pctx=None):
    x = L.embed(params["embed"], tokens, _dtype(cfg))
    media = media.astype(x.dtype)
    cos, sin = L.rope_cos_sin(jnp.arange(tokens.shape[1]),
                              cfg.resolved_head_dim, cfg.rope_theta)

    def gbody(carry, g):
        gp, xp = g
        def sbody(c, lp):
            return T.layer_fwd(lp, c, cfg, cos, sin, pctx), None
        carry, _ = jax.lax.scan(sbody, carry, gp,
                                unroll=True if cfg.scan_unroll else 1)
        carry = constrain_acts(xattn_fwd(xp, carry, media, cfg, pctx), pctx)
        return carry, None

    x = constrain_acts(x, pctx)
    x, _ = jax.lax.scan(jax.checkpoint(gbody, policy=remat_policy(cfg)),
                        x, (params["groups"], params["xlayers"]),
                        unroll=True if cfg.scan_unroll else 1)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def forward(params, cfg, batch, pctx=None):
    x = hidden_states(params, cfg, batch["tokens"], batch["media"], pctx)
    return L.logits_head(x, params["lm_head"], pctx)


def loss(params, cfg, batch, pctx=None):
    return L.xent_loss(forward(params, cfg, batch, pctx), batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    g, per = _groups(cfg)
    hd = cfg.resolved_head_dim
    dt = _dtype(cfg)
    m = cfg.num_media_tokens
    return {
        "k": jnp.zeros((g, per - 1, batch, max_seq, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((g, per - 1, batch, max_seq, cfg.n_kv_heads, hd), dt),
        # cross-attention K/V over the media tokens (computed once)
        "mk": jnp.zeros((g, batch, m, cfg.n_kv_heads, hd), dt),
        "mv": jnp.zeros((g, batch, m, cfg.n_kv_heads, hd), dt),
    }


def prefill_media_kv(params, cfg: ModelConfig, media, cache, pctx=None):
    """Populate the cross-attn K/V cache from media embeddings."""
    def body(_, xp):
        k = col_linear(media, xp["xattn"]["wk"], pctx).reshape(
            media.shape[0], media.shape[1], cfg.n_kv_heads,
            cfg.resolved_head_dim)
        k = L.rms_norm(k, xp["xattn"]["k_norm"], cfg.norm_eps)
        v = col_linear(media, xp["xattn"]["wv"], pctx).reshape(
            media.shape[0], media.shape[1], cfg.n_kv_heads,
            cfg.resolved_head_dim)
        return None, (k, v)

    _, (mk, mv) = jax.lax.scan(body, None, params["xlayers"])
    cache = dict(cache)
    cache["mk"], cache["mv"] = mk.astype(cache["mk"].dtype), \
        mv.astype(cache["mv"].dtype)
    return cache


def decode_step(params, cfg: ModelConfig, batch, cache, pctx=None):
    tokens, pos = batch["tokens"], batch["pos"]
    hd = cfg.resolved_head_dim
    x = L.embed(params["embed"], tokens, _dtype(cfg))
    cos, sin = L.rope_cos_sin(pos[None], hd, cfg.rope_theta)

    def gbody(x, g):
        gp, xp, ck, cv, mk, mv = g

        def sbody(x, lp_kv):
            lp, k, v = lp_kv
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, k, v = L.attn_block_decode(lp["attn"], h, k, v, pos,
                                          n_heads=cfg.n_heads,
                                          n_kv=cfg.n_kv_heads, head_dim=hd,
                                          cos=cos, sin=sin, eps=cfg.norm_eps,
                                          pctx=pctx)
            x = x + y
            x = x + L.mlp_block(lp["mlp"],
                                L.rms_norm(x, lp["ln2"], cfg.norm_eps), pctx)
            return x, (k, v)

        x, (ck, cv) = jax.lax.scan(sbody, x, (gp, ck, cv),
                                   unroll=True if cfg.scan_unroll else 1)
        x = xattn_fwd(xp, x, None, cfg, pctx,
                      media_kv=(mk.astype(x.dtype), mv.astype(x.dtype)))
        return x, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        gbody, x, (params["groups"], params["xlayers"], cache["k"],
                   cache["v"], cache["mk"], cache["mv"]),
        unroll=True if cfg.scan_unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ck, cv
    return L.logits_head(x, params["lm_head"], pctx), new_cache
