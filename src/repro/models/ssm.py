"""State-space blocks: Mamba2 (chunked SSD) and RWKV6 (Finch, chunked WKV).

Both use chunked-parallel forms: ``lax.scan`` over sequence chunks carrying a
constant-size recurrent state, so training memory is O(chunk) and decode is a
single-step state update — which is why these archs run the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.tp import ParallelCtx, col_linear, row_linear



# =========================================================================== #
# Mamba2
# =========================================================================== #
def mamba2_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.d_state, s.head_dim, s.conv_kernel


def init_mamba2(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, h, n, hd, ck = mamba2_dims(cfg)
    ks = jax.random.split(key, 4)
    conv_dim = d_inner + 2 * n
    return {
        # order: [z, x, B, C, dt]
        "w_in": L.dense_init(ks[0], (d, 2 * d_inner + 2 * n + h)),
        "conv_w": L.dense_init(ks[1], (ck, conv_dim)) * 0.1,
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.zeros((h,)),
        "D": jnp.ones((h,)),
        "dt_bias": jnp.zeros((h,)),
        "gate_norm": jnp.ones((d_inner,)),
        "w_out": L.dense_init(ks[2], (d_inner, d)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None):
    """Depthwise causal conv. x: [B,S,C], w: [K,C]. Returns (y, last K-1)."""
    k = w.shape[0]
    pad = prev if prev is not None else jnp.zeros(
        (x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None].astype(x.dtype)
            for i in range(k))
    y = jax.nn.silu(y + b.astype(x.dtype))
    return y, xp[:, -(k - 1):, :]


def _ssd_chunk(state, xs, cfg: ModelConfig):
    """One SSD chunk. state: [B,H,hd,N]; xs = (x [B,C,H,hd], Bm/Cm [B,C,N],
    logdec [B,C,H], dt [B,C,H]).  Returns (new_state, y [B,C,H,hd])."""
    x, Bm, Cm, logdec, dt = xs
    sdt = jnp.dtype(cfg.ssm.scores_dtype)
    cum = jnp.cumsum(logdec, axis=1)                      # [B,C,H]
    # intra-chunk attention-like term (causal, strictly lower + diag)
    ratio = cum[:, :, None, :] - cum[:, None, :, :]       # [B,t,s,H]
    tpos = jnp.arange(x.shape[1])
    mask = (tpos[:, None] >= tpos[None, :])[None, :, :, None]
    dec = jnp.where(mask, jnp.exp(ratio), 0.0).astype(sdt)
    scores = (jnp.einsum("btn,bsn->bts", Cm, Bm).astype(sdt)[..., None]
              * dec * dt[:, None, :, :].astype(sdt))      # [B,t,s,H]
    y = jnp.einsum("btsh,bshd->bthd", scores.astype(x.dtype), x)
    # inter-chunk contribution from the carried state
    y = y + jnp.einsum("btn,bhdn,bth->bthd", Cm, state.astype(x.dtype),
                       jnp.exp(cum).astype(x.dtype))
    # state update
    tail = jnp.exp(cum[:, -1:, :] - cum)                  # [B,C,H]
    upd = jnp.einsum("bsh,bshd,bsn->bhdn", (tail * dt).astype(x.dtype), x, Bm)
    new_state = state * jnp.exp(cum[:, -1])[:, :, None, None] + upd
    return new_state, y


def mamba2_block(p: dict, x: jax.Array, cfg: ModelConfig,
                 pctx: Optional[ParallelCtx] = None,
                 state=None, conv_prev=None, single_step: bool = False):
    """x: [B,S,D] -> [B,S,D].  When single_step, S==1 and state/conv_prev are
    the decode caches; returns (y, state, conv_prev)."""
    b, s, d = x.shape
    d_inner, h, n, hd, ck = mamba2_dims(cfg)
    proj = col_linear(x, p["w_in"], pctx)
    z, xin, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, conv_prev = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                       conv_prev)
    xin, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # [B,S,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                  # [H]
    logdec = dt * a[None, None, :]                                # [B,S,H]
    xh = xin.reshape(b, s, h, hd)

    if state is None:
        state = jnp.zeros((b, h, hd, n), jnp.float32)

    if single_step:
        dec = jnp.exp(logdec[:, 0])                               # [B,H]
        upd = jnp.einsum("bh,bhd,bn->bhdn", dt[:, 0], xh[:, 0], Bm[:, 0])
        state = state * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhdn->bhd", Cm[:, 0], state)[:, None]  # [B,1,H,hd]
        y = y.astype(x.dtype)
    else:
        CHUNK = min(cfg.ssm.chunk, s)
        npad = (-s) % CHUNK
        def pad(t):
            return jnp.pad(t, [(0, 0), (0, npad)] + [(0, 0)] * (t.ndim - 2))
        nchunks = (s + npad) // CHUNK
        def reshape(t):
            return pad(t).reshape(b, nchunks, CHUNK, *t.shape[2:]) \
                         .swapaxes(0, 1)
        xs = (reshape(xh), reshape(Bm), reshape(Cm),
              reshape(logdec), reshape(dt))
        state, y = jax.lax.scan(
            lambda st, ch: _ssd_chunk(st, ch, cfg), state, xs,
            unroll=True if cfg.scan_unroll else 1)
        y = y.swapaxes(0, 1).reshape(b, nchunks * CHUNK, h, hd)[:, :s]
        y = y.astype(x.dtype)

    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_inner) * jax.nn.silu(z)
    y = L.rms_norm(y, p["gate_norm"], cfg.norm_eps)
    out = row_linear(y, p["w_out"], pctx)
    return out, state, conv_prev


# =========================================================================== #
# RWKV6 (Finch)
# =========================================================================== #
def rwkv_dims(cfg: ModelConfig):
    hd = cfg.ssm.head_dim
    return cfg.d_model // hd, hd


def init_rwkv_tmix(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, hd = rwkv_dims(cfg)
    ks = jax.random.split(key, 8)
    lora = 64
    return {
        "mu": 0.5 * jnp.ones((5, d)),            # token-shift mix for r,k,v,w,g
        "wr": L.dense_init(ks[0], (d, d)),
        "wk": L.dense_init(ks[1], (d, d)),
        "wv": L.dense_init(ks[2], (d, d)),
        "wg": L.dense_init(ks[3], (d, d)),
        "w0": -6.0 * jnp.ones((d,)),             # base log-decay
        "w_lora_a": L.dense_init(ks[4], (d, lora)),
        "w_lora_b": L.dense_init(ks[5], (lora, d)) * 0.1,
        "u": jnp.zeros((h, hd)),                 # per-head bonus
        "ln_x": jnp.ones((d,)),
        "wo": L.dense_init(ks[6], (d, d)),
    }


def init_rwkv_cmix(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, cfg.d_model)),
        "wk": L.dense_init(ks[0], (cfg.d_model, cfg.d_ff)),
        "wv": L.dense_init(ks[1], (cfg.d_ff, cfg.d_model)),
        "wr": L.dense_init(ks[2], (cfg.d_model, cfg.d_model)),
    }


def _shift(x: jax.Array, prev: Optional[jax.Array] = None):
    """Token shift: x[t-1]; prev is the last token of the previous segment.
    Returns (shifted, new_prev)."""
    last = x[:, -1:, :]
    if prev is None:
        prev = jnp.zeros_like(x[:, :1, :])
    return jnp.concatenate([prev, x[:, :-1, :]], axis=1), last


def _wkv_chunk(state, xs, u):
    """state: [B,H,hd,hd] (k x v). xs: r,k,v [B,C,H,hd], logw [B,C,H,hd];
    u: [H,hd] bonus (closed over).  Returns (new_state, y)."""
    r, k, v, logw = xs
    cum = jnp.cumsum(logw, axis=1)                     # [B,C,H,hd]
    cum_prev = cum - logw                              # cum through t-1
    re = (r * jnp.exp(cum_prev)).astype(jnp.float32)
    # exp(-cum) grows within the chunk; clamp keeps fp32 finite (exact while
    # per-chunk cumulative decay <= 80 nats; see kernels/wkv6.py note).
    kf = (k * jnp.exp(-jnp.maximum(cum, -80.0))).astype(jnp.float32)
    scores = jnp.einsum("bthc,bshc->bhts", re, kf)     # strictly lower part
    tpos = jnp.arange(r.shape[1])
    mask = (tpos[:, None] > tpos[None, :])[None, None]
    scores = jnp.where(mask, scores, 0.0)
    diag = jnp.einsum("bthc,hc,bthc->bth", r, u, k)    # u-bonus (s == t)
    y = jnp.einsum("bhts,bshd->bthd", scores, v) \
        + diag[..., None] * v
    y = y + jnp.einsum("bthc,bhcd->bthd", re, state)   # carried state
    tail = jnp.exp(cum[:, -1:] - cum)                  # [B,C,H,hd]
    new_state = state * jnp.exp(cum[:, -1])[..., None] \
        + jnp.einsum("bshc,bshd->bhcd", (k * tail).astype(jnp.float32),
                     v.astype(jnp.float32))
    return new_state, y


def rwkv_tmix(p: dict, x: jax.Array, cfg: ModelConfig,
              pctx: Optional[ParallelCtx] = None,
              state=None, prev=None, single_step: bool = False):
    b, s, d = x.shape
    h, hd = rwkv_dims(cfg)
    xs, new_prev = _shift(x, prev)
    mu = p["mu"].astype(x.dtype)
    lerp = lambda i: x + (xs - x) * mu[i][None, None]
    r = col_linear(lerp(0), p["wr"], pctx).reshape(b, s, h, hd)
    k = col_linear(lerp(1), p["wk"], pctx).reshape(b, s, h, hd)
    v = col_linear(lerp(2), p["wv"], pctx).reshape(b, s, h, hd)
    g = jax.nn.silu(col_linear(lerp(4), p["wg"], pctx))
    # data-dependent decay (lora)
    wx = jnp.tanh(lerp(3) @ p["w_lora_a"].astype(x.dtype)) \
        @ p["w_lora_b"].astype(x.dtype)
    logw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32)
                             + wx.astype(jnp.float32), -10.0, 2.0))
    logw = logw.reshape(b, s, h, hd)
    u = p["u"].astype(jnp.float32)

    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if single_step:
        y = jnp.einsum("bhc,bhcd->bhd", rf[:, 0], state) \
            + jnp.einsum("bhc,hc,bhc,bhd->bhd", rf[:, 0], u, kf[:, 0],
                         vf[:, 0])
        state = state * jnp.exp(logw[:, 0])[..., None] \
            + jnp.einsum("bhc,bhd->bhcd", kf[:, 0], vf[:, 0])
        y = y[:, None]
    else:
        CHUNK = min(cfg.ssm.chunk, s)
        npad = (-s) % CHUNK
        def reshape(t):
            t = jnp.pad(t, [(0, 0), (0, npad)] + [(0, 0)] * (t.ndim - 2))
            return t.reshape(b, -1, CHUNK, *t.shape[2:]).swapaxes(0, 1)
        state, y = jax.lax.scan(
            lambda st, ch: _wkv_chunk(st, ch, u), state,
            (reshape(rf), reshape(kf), reshape(vf), reshape(logw)),
            unroll=True if cfg.scan_unroll else 1)
        y = y.swapaxes(0, 1).reshape(b, -1, h, hd)[:, :s]

    y = y.astype(x.dtype).reshape(b, s, d)
    y = L.rms_norm(y, p["ln_x"], cfg.norm_eps) * g
    return row_linear(y, p["wo"], pctx), state, new_prev


def rwkv_cmix(p: dict, x: jax.Array, cfg: ModelConfig,
              pctx: Optional[ParallelCtx] = None, prev=None):
    xs, new_prev = _shift(x, prev)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xs - x) * mu[0][None, None]
    xr = x + (xs - x) * mu[1][None, None]
    k = jnp.square(jax.nn.relu(col_linear(xk, p["wk"], pctx)))
    out = row_linear(k, p["wv"], pctx)          # INA site (channel-mix)
    gate = jax.nn.sigmoid(col_linear(xr, p["wr"], pctx))
    return out * gate, new_prev
