"""Dense decoder-only transformer (phi3 / llama3 / qwen2 / qwen3 families).

Layers are stacked ([L, ...] leaves) and executed with ``lax.scan`` +
``jax.checkpoint`` so the 512-device dry-run compiles one layer's HLO.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.tp import ParallelCtx, constrain_acts

REMAT_POLICY = jax.checkpoint_policies.nothing_saveable


def remat_policy(cfg: "ModelConfig"):
    """Selectable activation-checkpoint policy (SSPerf hillclimb knob)."""
    return {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.checkpoint_dots,
        "dots_nb": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def init_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    return {
        "ln1": jnp.ones((cfg.d_model,)),
        "attn": L.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                            cfg.qk_norm, cfg.qkv_bias),
        "ln2": jnp.ones((cfg.d_model,)),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def init(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 2)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[init_layer(keys[i], cfg) for i in range(cfg.n_layers)])
    params = {
        "embed": L.dense_init(keys[-2], (cfg.vocab, cfg.d_model)),
        "layers": stacked,
        "ln_f": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[-1], (cfg.d_model, cfg.vocab),
                                         in_dim=cfg.d_model)
    return params


# --------------------------------------------------------------------------- #
# forward (train / prefill)
# --------------------------------------------------------------------------- #
def layer_fwd(lp: dict, x: jax.Array, cfg: ModelConfig, cos, sin,
              pctx: Optional[ParallelCtx]) -> jax.Array:
    hd = cfg.resolved_head_dim
    x = x + L.attn_block(lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                         n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
                         cos=cos, sin=sin, causal=True, chunk=cfg.attn_chunk,
                         eps=cfg.norm_eps, pctx=pctx, unroll=cfg.scan_unroll)
    x = x + L.mlp_block(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps), pctx)
    return constrain_acts(x, pctx)


def hidden_states(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  pctx: Optional[ParallelCtx] = None) -> jax.Array:
    dt = _dtype(cfg)
    x = L.embed(params["embed"], tokens, dt)
    pos = jnp.arange(tokens.shape[1])
    cos, sin = L.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)

    def body(carry, lp):
        return layer_fwd(lp, carry, cfg, cos, sin, pctx), None

    x = constrain_acts(x, pctx)
    x, _ = jax.lax.scan(jax.checkpoint(body, policy=remat_policy(cfg)),
                        x, params["layers"],
                        unroll=True if cfg.scan_unroll else 1)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def forward(params: dict, cfg: ModelConfig, batch: dict,
            pctx: Optional[ParallelCtx] = None) -> jax.Array:
    x = hidden_states(params, cfg, batch["tokens"], pctx)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return L.logits_head(x, head, pctx)


def loss(params: dict, cfg: ModelConfig, batch: dict,
         pctx: Optional[ParallelCtx] = None) -> jax.Array:
    logits = forward(params, cfg, batch, pctx)
    return L.xent_loss(logits, batch["labels"])


# --------------------------------------------------------------------------- #
# prefill: batched forward that also populates the KV cache
# --------------------------------------------------------------------------- #
def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict,
            pctx: Optional[ParallelCtx] = None, pos_offset=0):
    """Causal forward over a token chunk that writes K/V into the cache.

    ``batch["tokens"]``: [B, C] chunk starting at absolute position
    ``pos_offset`` (python int or traced scalar — one compile serves every
    chunk of a chunked prefill).  Attention runs over the *whole* cache with
    the causal mask anchored at ``pos_offset``, so each row reproduces
    exactly what a per-token ``decode_step`` loop would compute — this is
    the batched replacement for ``launch/serve.py``'s legacy prompt loop.
    Returns (logits [B, C, V], new cache).
    """
    dt = _dtype(cfg)
    tokens = batch["tokens"]
    b, c = tokens.shape
    hd = cfg.resolved_head_dim
    x = L.embed(params["embed"], tokens, dt)
    pos = jnp.arange(c) + pos_offset
    cos, sin = L.rope_cos_sin(pos, hd, cfg.rope_theta)

    def body(x, lp_and_cache):
        lp, ck, cv = lp_and_cache
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads, hd,
                             cos, sin, cfg.norm_eps, pctx)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos_offset, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos_offset, 0, 0))
        o = L.attn_full(q, ck.astype(q.dtype), cv.astype(q.dtype),
                        causal=True, q_offset=pos_offset)
        x = x + L.row_linear(o.reshape(b, c, cfg.n_heads * hd),
                             lp["attn"]["wo"], pctx)
        x = x + L.mlp_block(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps),
                            pctx)
        return x, (ck, cv)

    x, kv = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                         unroll=True if cfg.scan_unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return L.logits_head(x, head, pctx), {"k": kv[0], "v": kv[1]}


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd)
    dt = _dtype(cfg)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_step(params: dict, cfg: ModelConfig, batch: dict, cache: dict,
                pctx: Optional[ParallelCtx] = None):
    """One-token decode. batch: {tokens: [B,1], pos: scalar}; returns
    (logits [B,1,V], new cache)."""
    dt = _dtype(cfg)
    tokens, pos = batch["tokens"], batch["pos"]
    hd = cfg.resolved_head_dim
    x = L.embed(params["embed"], tokens, dt)
    cos, sin = L.rope_cos_sin(pos[None], hd, cfg.rope_theta)

    def body(x, lp_and_cache):
        lp, ck, cv = lp_and_cache
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, ck, cv = L.attn_block_decode(
            lp["attn"], h, ck, cv, pos, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=hd, cos=cos, sin=sin,
            eps=cfg.norm_eps, pctx=pctx)
        x = x + y
        x = x + L.mlp_block(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps),
                            pctx)
        return x, (ck, cv)

    x, kv = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                         unroll=True if cfg.scan_unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return L.logits_head(x, head, pctx), {"k": kv[0], "v": kv[1]}
