"""Whisper-style encoder-decoder backbone (conv frontend is a stub).

``input_specs()`` supplies precomputed log-mel frame *embeddings*
[B, n_frames, d_model] (the conv1d frontend is out of scope per the
assignment); the encoder is a non-causal transformer over frames, the
decoder a causal transformer with cross-attention.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import _dtype, remat_policy
from repro.parallel.tp import ParallelCtx, col_linear, constrain_acts, row_linear

N_FRAMES = 1500        # whisper 30 s window after conv stride 2


def init_cross_attn(key, cfg: ModelConfig) -> dict:
    return L.init_attn(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.resolved_head_dim)


def init_enc_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,)),
        "attn": L.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.resolved_head_dim),
        "ln2": jnp.ones((cfg.d_model,)),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False),
    }


def init_dec_layer(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,)),
        "attn": L.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.resolved_head_dim),
        "lnx": jnp.ones((cfg.d_model,)),
        "xattn": init_cross_attn(k2, cfg),
        "ln2": jnp.ones((cfg.d_model,)),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False),
    }


def init(cfg: ModelConfig, key) -> dict:
    ne = cfg.encoder_layers
    keys = jax.random.split(key, ne + cfg.n_layers + 3)
    return {
        "embed": L.dense_init(keys[-3], (cfg.vocab, cfg.d_model)),
        "pos_dec": L.dense_init(keys[-2], (cfg.max_seq, cfg.d_model)) * 0.02,
        "enc_layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[init_enc_layer(keys[i], cfg) for i in range(ne)]),
        "ln_enc": jnp.ones((cfg.d_model,)),
        "dec_layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[init_dec_layer(keys[ne + i], cfg) for i in range(cfg.n_layers)]),
        "ln_f": jnp.ones((cfg.d_model,)),
    }


def encode(params, cfg: ModelConfig, media: jax.Array, pctx=None) -> jax.Array:
    """media: [B, F, D] precomputed frame embeddings (stub frontend)."""
    hd = cfg.resolved_head_dim
    x = media.astype(_dtype(cfg))

    def body(carry, lp):
        h = L.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        carry = carry + L.attn_block(
            lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=hd, cos=None, sin=None, causal=False,
            chunk=cfg.attn_chunk, eps=cfg.norm_eps, pctx=pctx,
            unroll=cfg.scan_unroll)
        carry = carry + L.mlp_block(
            lp["mlp"], L.rms_norm(carry, lp["ln2"], cfg.norm_eps), pctx)
        return constrain_acts(carry, pctx), None

    x, _ = jax.lax.scan(jax.checkpoint(body, policy=remat_policy(cfg)),
                        x, params["enc_layers"],
                        unroll=True if cfg.scan_unroll else 1)
    return L.rms_norm(x, params["ln_enc"], cfg.norm_eps)


def cross_attn(p, x, enc, cfg, pctx):
    """Query from decoder x, keys/values from encoder output."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = col_linear(x, p["wq"], pctx).reshape(b, s, cfg.n_heads, hd)
    k = col_linear(enc, p["wk"], pctx).reshape(b, enc.shape[1],
                                               cfg.n_kv_heads, hd)
    v = col_linear(enc, p["wv"], pctx).reshape(b, enc.shape[1],
                                               cfg.n_kv_heads, hd)
    o = L.attn_full(q, k, v, causal=False)
    return row_linear(o.reshape(b, s, cfg.n_heads * hd), p["wo"], pctx)


def dec_layer_fwd(lp, x, enc, cfg, cos, sin, pctx, kv=None, pos=None):
    hd = cfg.resolved_head_dim
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kv is None:
        x = x + L.attn_block(lp["attn"], h, n_heads=cfg.n_heads,
                             n_kv=cfg.n_kv_heads, head_dim=hd, cos=cos,
                             sin=sin, causal=True, chunk=cfg.attn_chunk,
                             eps=cfg.norm_eps, pctx=pctx,
                             unroll=cfg.scan_unroll)
        new_kv = None
    else:
        y, ck, cv = L.attn_block_decode(lp["attn"], h, kv[0], kv[1], pos,
                                        n_heads=cfg.n_heads,
                                        n_kv=cfg.n_kv_heads, head_dim=hd,
                                        cos=cos, sin=sin, eps=cfg.norm_eps,
                                        pctx=pctx)
        x = x + y
        new_kv = (ck, cv)
    x = x + cross_attn(lp["xattn"], L.rms_norm(x, lp["lnx"], cfg.norm_eps),
                       enc, cfg, pctx)
    x = x + L.mlp_block(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps),
                        pctx)
    return constrain_acts(x, pctx), new_kv


def forward(params, cfg: ModelConfig, batch, pctx=None) -> jax.Array:
    tokens = batch["tokens"]
    enc = encode(params, cfg, batch["media"], pctx)
    s = tokens.shape[1]
    x = L.embed(params["embed"], tokens, _dtype(cfg))
    x = x + params["pos_dec"][:s][None].astype(x.dtype)
    cos, sin = L.rope_cos_sin(jnp.arange(s), cfg.resolved_head_dim,
                              cfg.rope_theta)

    def body(carry, lp):
        return dec_layer_fwd(lp, carry, enc, cfg, cos, sin, pctx)[0], None

    x, _ = jax.lax.scan(jax.checkpoint(body, policy=remat_policy(cfg)),
                        x, params["dec_layers"],
                        unroll=True if cfg.scan_unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.logits_head(x, params["embed"].T, pctx)


def loss(params, cfg, batch, pctx=None):
    return L.xent_loss(forward(params, cfg, batch, pctx), batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    hd = cfg.resolved_head_dim
    dt = _dtype(cfg)
    l = cfg.n_layers
    return {
        "k": jnp.zeros((l, batch, max_seq, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((l, batch, max_seq, cfg.n_kv_heads, hd), dt),
        # encoder output is recomputed per step from the stub embeddings at
        # decode time in this backbone (serve drivers cache it externally).
    }


def decode_step(params, cfg: ModelConfig, batch, cache, pctx=None):
    tokens, pos = batch["tokens"], batch["pos"]
    enc = encode(params, cfg, batch["media"], pctx)
    x = L.embed(params["embed"], tokens, _dtype(cfg))
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1
                                         )[None].astype(x.dtype)
    cos, sin = L.rope_cos_sin(pos[None], cfg.resolved_head_dim, cfg.rope_theta)

    def body(x, lp_kv):
        lp, ck, cv = lp_kv
        x, kv = dec_layer_fwd(lp, x, enc, cfg, cos, sin, pctx,
                              kv=(ck, cv), pos=pos)
        return x, kv

    x, (ck, cv) = jax.lax.scan(body, x,
                               (params["dec_layers"], cache["k"], cache["v"]),
                               unroll=True if cfg.scan_unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.logits_head(x, params["embed"].T, pctx), {"k": ck, "v": cv}
