"""Mixture-of-Experts decoder (llama4-scout family; MLA variant in mla.py).

Routing is Switch-style top-k with a fixed per-expert capacity so the
dispatch/combine are dense einsums (dry-run friendly, no ragged ops) and the
compiled FLOPs scale with *activated* parameters (tokens x top_k), not with
the total expert count.  Experts are sharded over the ``model`` axis (EP);
the combine contraction over experts is the paper's INA accumulation site
(see parallel/tp.py::combine_experts).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import _dtype, remat_policy
from repro.parallel.tp import ParallelCtx, constrain_acts


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def init_moe_mlp(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 5)
    e, d, f = m.num_experts, cfg.d_model, m.d_ff_expert
    p = {
        "router": L.dense_init(ks[0], (d, e)),
        "w_gate": L.dense_init(ks[1], (e, d, f), in_dim=d),
        "w_up": L.dense_init(ks[2], (e, d, f), in_dim=d),
        "w_down": L.dense_init(ks[3], (e, f, d), in_dim=f),
    }
    if m.num_shared:
        p["shared"] = L.init_mlp(ks[4], d, m.d_ff_expert * m.num_shared)
    return p


def init_layer(key, cfg: ModelConfig, dense: bool = False) -> dict:
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    return {
        "ln1": jnp.ones((cfg.d_model,)),
        "attn": L.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                            cfg.qk_norm, cfg.qkv_bias),
        "ln2": jnp.ones((cfg.d_model,)),
        "mlp": (L.init_mlp(k2, cfg.d_model, cfg.d_ff) if dense
                else init_moe_mlp(k2, cfg)),
    }


def init(cfg: ModelConfig, key) -> dict:
    nd = cfg.moe.first_dense_layers
    keys = jax.random.split(key, cfg.n_layers + 2)
    dense_layers = [init_layer(keys[i], cfg, dense=True) for i in range(nd)]
    moe_layers = [init_layer(keys[i], cfg) for i in range(nd, cfg.n_layers)]
    params = {
        "embed": L.dense_init(keys[-2], (cfg.vocab, cfg.d_model)),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *moe_layers),
        "ln_f": jnp.ones((cfg.d_model,)),
        "lm_head": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab),
                                in_dim=cfg.d_model),
    }
    if dense_layers:
        params["dense_layers"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                              *dense_layers)
    return params


# --------------------------------------------------------------------------- #
# MoE forward
# --------------------------------------------------------------------------- #
def _expert_partial(xt, gate_idx, pos, keep, gate_vals, wg, wu, wd,
                    e0, e_local: int, cap: int):
    """Dispatch -> FFN -> locally-combined partial output for experts
    [e0, e0+e_local).  Returns [T, D] partial sums (zero where no local
    expert contributed) — the WS psum that INA accumulates.
    """
    t, d = xt.shape
    k = gate_idx.shape[1]
    rel = gate_idx - e0
    local = (rel >= 0) & (rel < e_local) & keep
    rel_safe = jnp.where(local, rel, e_local)          # OOB -> dropped scatter

    # slot_token[e, c] = token index occupying capacity slot c of expert e.
    tids = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[:, None], (t, k))
    st = jnp.full((e_local, cap), t, jnp.int32)
    st = st.at[rel_safe, pos].set(tids, mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xt_pad[st]                                    # [e_local, C, D]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(xe.dtype))   # [e_local, C, D]

    contrib = ye[jnp.clip(rel_safe, 0, e_local - 1), pos]     # [T, k, D]
    w = (gate_vals * local.astype(gate_vals.dtype)).astype(xt.dtype)
    return jnp.einsum("tkd,tk->td", contrib, w)


def moe_mlp(p: dict, x: jax.Array, cfg: ModelConfig,
            pctx: Optional[ParallelCtx] = None) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss). x: [B, S, D].

    Experts are sharded over the model axis (EP).  Each device computes the
    partial combine owned by its local experts; the cross-device psum of
    those partials is the paper's INA accumulation site.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    n_tok = b * s
    cap = min(max(8, int(n_tok * k * m.capacity_factor / e)), n_tok)

    logits32 = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype)
                          ).astype(jnp.float32)
    probs = jax.nn.softmax(logits32, axis=-1)                    # [B,S,E]
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # [B,S,k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    gate_idx = gate_idx.reshape(n_tok, k)
    gate_vals = gate_vals.reshape(n_tok, k)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)      # [T,k,E]
    pos_in_expert = (jnp.cumsum(onehot.reshape(n_tok * k, e), axis=0)
                     .reshape(n_tok, k, e) - 1.0)
    pos = (pos_in_expert * onehot).sum(-1)                       # [T,k]
    keep = (pos < cap) & (gate_vals > 0)
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)
    xt = x.reshape(n_tok, d)

    if pctx is not None and pctx.manual:
        n_shards = pctx.mesh.shape[pctx.axis]
        e_local = e // n_shards
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        from repro.core.collectives import psum_with_mode

        def body(xt, gi, po, ke, gv, wg, wu, wd):
            i = jax.lax.axis_index(pctx.axis)
            dt_in = xt.dtype
            if jax.default_backend() == "cpu" and xt.dtype == jnp.bfloat16:
                # CPU-only: keep region tensors f32 so autodiff-generated
                # psums are f32 (XLA CPU AllReducePromotion crashes on bf16
                # all-reduce; see core/collectives._needs_f32_workaround)
                xt = xt.astype(jnp.float32)
            partial = _expert_partial(xt, gi, po, ke, gv, wg, wu, wd,
                                      i * e_local, e_local, cap)
            return psum_with_mode(partial, pctx.axis, pctx.psum_mode,
                                  scatter_axis=partial.ndim - 1,
                                  plan=pctx.plan).astype(dt_in)

        rep2 = P(None, None)
        out_flat = shard_map(
            body, mesh=pctx.mesh,
            in_specs=(rep2, rep2, rep2, rep2, rep2,
                      P(pctx.axis, None, None), P(pctx.axis, None, None),
                      P(pctx.axis, None, None)),
            out_specs=rep2, axis_names={pctx.axis}, check_vma=False,
        )(xt, gate_idx, pos, keep, gate_vals,
          p["w_gate"], p["w_up"], p["w_down"])
    else:
        out_flat = _expert_partial(xt, gate_idx, pos, keep, gate_vals,
                                   p["w_gate"], p["w_up"], p["w_down"],
                                   0, e, cap)

    out = out_flat.reshape(b, s, d)
    if "shared" in p:
        out = out + L.mlp_block(p["shared"], x, pctx)

    # Switch aux losses: load balance + router z-loss.
    me = probs.reshape(n_tok, e).mean(0)
    ce = (onehot * keep[..., None].astype(jnp.float32)).sum(1).mean(0)
    aux = m.aux_loss_coef * e * jnp.sum(me * ce) \
        + m.router_z_coef * jnp.mean(
            jnp.square(jax.nn.logsumexp(logits32, axis=-1)))
    return out, aux.astype(jnp.float32)


def layer_fwd(lp: dict, x: jax.Array, cfg: ModelConfig, cos, sin,
              pctx, dense: bool = False):
    hd = cfg.resolved_head_dim
    x = x + L.attn_block(lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                         n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
                         cos=cos, sin=sin, causal=True, chunk=cfg.attn_chunk,
                         eps=cfg.norm_eps, pctx=pctx, unroll=cfg.scan_unroll)
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if dense:
        return constrain_acts(x + L.mlp_block(lp["mlp"], h, pctx), pctx), \
            jnp.float32(0)
    y, aux = moe_mlp(lp["mlp"], h, cfg, pctx)
    return constrain_acts(x + y, pctx), aux


def hidden_states(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  pctx: Optional[ParallelCtx] = None):
    dt = _dtype(cfg)
    x = L.embed(params["embed"], tokens, dt)
    pos = jnp.arange(tokens.shape[1])
    cos, sin = L.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
    aux_total = jnp.float32(0)

    if "dense_layers" in params:
        def dbody(carry, lp):
            x, aux = carry
            x, a = layer_fwd(lp, x, cfg, cos, sin, pctx, dense=True)
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(
            jax.checkpoint(dbody, policy=remat_policy(cfg)),
            (x, aux_total), params["dense_layers"],
            unroll=True if cfg.scan_unroll else 1)

    def body(carry, lp):
        x, aux = carry
        x, a = layer_fwd(lp, x, cfg, cos, sin, pctx)
        return (x, aux + a), None

    (x, aux_total), _ = jax.lax.scan(
        jax.checkpoint(body, policy=remat_policy(cfg)),
        (x, aux_total), params["layers"],
        unroll=True if cfg.scan_unroll else 1)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), aux_total


def forward(params: dict, cfg: ModelConfig, batch: dict,
            pctx: Optional[ParallelCtx] = None) -> jax.Array:
    x, _ = hidden_states(params, cfg, batch["tokens"], pctx)
    return L.logits_head(x, params["lm_head"], pctx)


def loss(params: dict, cfg: ModelConfig, batch: dict,
         pctx: Optional[ParallelCtx] = None) -> jax.Array:
    x, aux = hidden_states(params, cfg, batch["tokens"], pctx)
    logits = L.logits_head(x, params["lm_head"], pctx)
    return L.xent_loss(logits, batch["labels"]) + aux


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    hd = cfg.resolved_head_dim
    dt = _dtype(cfg)
    nd = cfg.moe.first_dense_layers
    cache = {
        "k": jnp.zeros((cfg.n_layers - nd, batch, max_seq, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((cfg.n_layers - nd, batch, max_seq, cfg.n_kv_heads, hd), dt),
    }
    if nd:
        cache["dk"] = jnp.zeros((nd, batch, max_seq, cfg.n_kv_heads, hd), dt)
        cache["dv"] = jnp.zeros((nd, batch, max_seq, cfg.n_kv_heads, hd), dt)
    return cache


def decode_step(params: dict, cfg: ModelConfig, batch: dict, cache: dict,
                pctx: Optional[ParallelCtx] = None):
    dt = _dtype(cfg)
    tokens, pos = batch["tokens"], batch["pos"]
    hd = cfg.resolved_head_dim
    x = L.embed(params["embed"], tokens, dt)
    cos, sin = L.rope_cos_sin(pos[None], hd, cfg.rope_theta)

    def make_body(dense):
        def body(x, lp_ck_cv):
            lp, ck, cv = lp_ck_cv
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, ck, cv = L.attn_block_decode(
                lp["attn"], h, ck, cv, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=hd, cos=cos, sin=sin,
                eps=cfg.norm_eps, pctx=pctx)
            x = x + y
            h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            if dense:
                x = x + L.mlp_block(lp["mlp"], h, pctx)
            else:
                y, _ = moe_mlp(lp["mlp"], h, cfg, pctx)
                x = x + y
            return x, (ck, cv)
        return body

    new_cache = dict(cache)
    if "dk" in cache:
        x, kv = jax.lax.scan(make_body(True), x,
                             (params["dense_layers"], cache["dk"], cache["dv"]),
                             unroll=True if cfg.scan_unroll else 1)
        new_cache["dk"], new_cache["dv"] = kv
    x, kv = jax.lax.scan(make_body(False), x,
                         (params["layers"], cache["k"], cache["v"]),
                         unroll=True if cfg.scan_unroll else 1)
    new_cache["k"], new_cache["v"] = kv
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.logits_head(x, params["lm_head"], pctx), new_cache
