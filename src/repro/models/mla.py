"""DeepSeek-V2-Lite: Multi-head Latent Attention + MoE FFN.

MLA compresses K/V through a low-rank latent (kv_lora_rank) with a split
nope/rope head layout; the decode cache stores the compressed latent + the
shared rope key (per DeepSeek-V2).  FFN layers are the shared MoE machinery
from moe.py (64 routed top-6 + 2 shared experts for V2-Lite).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.transformer import _dtype, remat_policy
from repro.parallel.tp import ParallelCtx, col_linear, constrain_acts, row_linear


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def init_mla_attn(key, cfg: ModelConfig) -> dict:
    a = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    qk_dim = a.qk_nope_head_dim + a.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        # Q path (V2-Lite: no q compression)
        "wq": L.dense_init(ks[0], (d, h * qk_dim)),
        # KV latent compression + shared rope key
        "w_dkv": L.dense_init(ks[1], (d, a.kv_lora_rank + a.qk_rope_head_dim)),
        "kv_norm": jnp.ones((a.kv_lora_rank,)),
        # up-projections from the latent
        "w_uk": L.dense_init(ks[2], (a.kv_lora_rank, h * a.qk_nope_head_dim)),
        "w_uv": L.dense_init(ks[3], (a.kv_lora_rank, h * a.v_head_dim)),
        "wo": L.dense_init(ks[4], (h * a.v_head_dim, d)),
    }
    return p


def init_layer(key, cfg: ModelConfig, dense: bool = False) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,)),
        "attn": init_mla_attn(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,)),
        "mlp": (L.init_mlp(k2, cfg.d_model, cfg.d_ff) if dense
                else MOE.init_moe_mlp(k2, cfg)),
    }


def init(cfg: ModelConfig, key) -> dict:
    nd = cfg.moe.first_dense_layers
    keys = jax.random.split(key, cfg.n_layers + 2)
    params = {
        "embed": L.dense_init(keys[-2], (cfg.vocab, cfg.d_model)),
        "layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[init_layer(keys[i], cfg) for i in range(nd, cfg.n_layers)]),
        "ln_f": jnp.ones((cfg.d_model,)),
        "lm_head": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab),
                                in_dim=cfg.d_model),
    }
    if nd:
        params["dense_layers"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[init_layer(keys[i], cfg, dense=True) for i in range(nd)])
    return params


# --------------------------------------------------------------------------- #
# MLA attention
# --------------------------------------------------------------------------- #
def mla_qkv(p: dict, x: jax.Array, cfg: ModelConfig, cos, sin,
            pctx: Optional[ParallelCtx]):
    """Returns q, k [B,S,H,qk_dim] and v [B,S,H,v_dim]."""
    a = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_dim = a.qk_nope_head_dim + a.qk_rope_head_dim

    q = col_linear(x, p["wq"], pctx).reshape(b, s, h, qk_dim)
    q_nope, q_rope = jnp.split(q, [a.qk_nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, cos, sin)

    ckv = col_linear(x, p["w_dkv"], pctx)          # [B,S,rank+rope]
    latent, k_rope = jnp.split(ckv, [a.kv_lora_rank], axis=-1)
    latent = L.rms_norm(latent, p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], cos, sin)    # shared head

    k_nope = col_linear(latent, p["w_uk"], pctx).reshape(
        b, s, h, a.qk_nope_head_dim)
    v = col_linear(latent, p["w_uv"], pctx).reshape(b, s, h, a.v_head_dim)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, a.qk_rope_head_dim))],
        axis=-1)
    return q, k, v


def mla_block(p: dict, x: jax.Array, cfg: ModelConfig, cos, sin,
              pctx: Optional[ParallelCtx]) -> jax.Array:
    a = cfg.mla
    b, s, _ = x.shape
    q, k, v = mla_qkv(p, x, cfg, cos, sin, pctx)
    o = L.attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                    unroll=cfg.scan_unroll)
    return row_linear(o.reshape(b, s, cfg.n_heads * a.v_head_dim), p["wo"],
                      pctx)


def layer_fwd(lp, x, cfg, cos, sin, pctx, dense=False):
    x = x + mla_block(lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                      cfg, cos, sin, pctx)
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if dense:
        return constrain_acts(x + L.mlp_block(lp["mlp"], h, pctx), pctx), \
            jnp.float32(0)
    y, aux = MOE.moe_mlp(lp["mlp"], h, cfg, pctx)
    return constrain_acts(x + y, pctx), aux


def hidden_states(params, cfg: ModelConfig, tokens, pctx=None):
    dt = _dtype(cfg)
    x = L.embed(params["embed"], tokens, dt)
    pos = jnp.arange(tokens.shape[1])
    cos, sin = L.rope_cos_sin(pos, cfg.mla.qk_rope_head_dim, cfg.rope_theta)
    aux_total = jnp.float32(0)

    if "dense_layers" in params:
        def dbody(carry, lp):
            x, aux = carry
            x, a = layer_fwd(lp, x, cfg, cos, sin, pctx, dense=True)
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(
            jax.checkpoint(dbody, policy=remat_policy(cfg)),
            (x, aux_total), params["dense_layers"],
            unroll=True if cfg.scan_unroll else 1)

    def body(carry, lp):
        x, aux = carry
        x, a = layer_fwd(lp, x, cfg, cos, sin, pctx)
        return (x, aux + a), None

    (x, aux_total), _ = jax.lax.scan(
        jax.checkpoint(body, policy=remat_policy(cfg)),
        (x, aux_total), params["layers"],
        unroll=True if cfg.scan_unroll else 1)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), aux_total


def forward(params, cfg, batch, pctx=None):
    x, _ = hidden_states(params, cfg, batch["tokens"], pctx)
    return L.logits_head(x, params["lm_head"], pctx)


def loss(params, cfg, batch, pctx=None):
    x, aux = hidden_states(params, cfg, batch["tokens"], pctx)
    return L.xent_loss(L.logits_head(x, params["lm_head"], pctx),
                       batch["labels"]) + aux


# --------------------------------------------------------------------------- #
# decode: cache the compressed latent + shared rope key (MLA's memory win)
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    a = cfg.mla
    dt = _dtype(cfg)
    nd = cfg.moe.first_dense_layers
    n_moe = cfg.n_layers - nd
    mk = lambda n: {
        "latent": jnp.zeros((n, batch, max_seq, a.kv_lora_rank), dt),
        "k_rope": jnp.zeros((n, batch, max_seq, a.qk_rope_head_dim), dt),
    }
    cache = {"moe": mk(n_moe)}
    if nd:
        cache["dense"] = mk(nd)
    return cache


def _decode_attn(p, x, lat_c, kr_c, pos, cfg: ModelConfig, cos, sin, pctx):
    a = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    q = col_linear(x, p["wq"], pctx).reshape(
        b, 1, h, a.qk_nope_head_dim + a.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [a.qk_nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, cos, sin)

    ckv = col_linear(x, p["w_dkv"], pctx)
    latent, k_rope = jnp.split(ckv, [a.kv_lora_rank], axis=-1)
    latent = L.rms_norm(latent, p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    lat_c = jax.lax.dynamic_update_slice(lat_c, latent.astype(lat_c.dtype),
                                         (0, pos, 0))
    kr_c = jax.lax.dynamic_update_slice(kr_c, k_rope.astype(kr_c.dtype),
                                        (0, pos, 0))

    # expand cached latents for attention
    k_nope = col_linear(lat_c.astype(x.dtype), p["w_uk"], pctx).reshape(
        b, -1, h, a.qk_nope_head_dim)
    v = col_linear(lat_c.astype(x.dtype), p["w_uv"], pctx).reshape(
        b, -1, h, a.v_head_dim)
    s_k = k_nope.shape[1]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_c.astype(x.dtype)[:, :, None, :],
                                  (b, s_k, h, a.qk_rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # mask the zero-initialized latent-cache tail (positions > pos)
    o = L.attn_full(q, k, v, causal=True, q_offset=pos)
    y = row_linear(o.reshape(b, 1, h * a.v_head_dim), p["wo"], pctx)
    return y, lat_c, kr_c


def decode_step(params, cfg: ModelConfig, batch, cache, pctx=None):
    dt = _dtype(cfg)
    tokens, pos = batch["tokens"], batch["pos"]
    x = L.embed(params["embed"], tokens, dt)
    cos, sin = L.rope_cos_sin(pos[None], cfg.mla.qk_rope_head_dim,
                              cfg.rope_theta)

    def make_body(dense):
        def body(x, lp_cache):
            lp, lat_c, kr_c = lp_cache
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, lat_c, kr_c = _decode_attn(lp["attn"], h, lat_c, kr_c, pos,
                                          cfg, cos, sin, pctx)
            x = x + y
            h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            if dense:
                x = x + L.mlp_block(lp["mlp"], h, pctx)
            else:
                y, _ = MOE.moe_mlp(lp["mlp"], h, cfg, pctx)
                x = x + y
            return x, (lat_c, kr_c)
        return body

    new_cache = dict(cache)
    if "dense" in cache:
        x, (lc, kc) = jax.lax.scan(
            make_body(True), x,
            (params["dense_layers"], cache["dense"]["latent"],
             cache["dense"]["k_rope"]),
            unroll=True if cfg.scan_unroll else 1)
        new_cache["dense"] = {"latent": lc, "k_rope": kc}
    x, (lc, kc) = jax.lax.scan(
        make_body(False), x,
        (params["layers"], cache["moe"]["latent"], cache["moe"]["k_rope"]),
        unroll=True if cfg.scan_unroll else 1)
    new_cache["moe"] = {"latent": lc, "k_rope": kc}
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.logits_head(x, params["lm_head"], pctx), new_cache
