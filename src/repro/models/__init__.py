from .api import get_model

__all__ = ["get_model"]
