"""Uniform model API: every family exposes init/forward/loss/cache/decode.

``input_specs`` builds ShapeDtypeStruct stand-ins for the dry-run (no
allocation); media-frontend archs get precomputed embeddings per the stub
rule.  ``param_specs`` derives FSDP+TP PartitionSpecs from parameter names.
"""
from __future__ import annotations

import dataclasses
from types import ModuleType

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, mla, moe, rwkv, transformer, vision

_FAMILIES: dict[str, ModuleType] = {
    "dense": transformer,
    "moe": moe,
    "mla_moe": mla,
    "ssm": rwkv,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vision,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    mod: ModuleType

    def init(self, key):
        params = self.mod.init(self.cfg, key)
        pd = jnp.dtype(self.cfg.param_dtype)
        if pd != jnp.float32:
            # store matrix weights in the compute dtype (halves FSDP
            # all-gather traffic); norms/scalars stay fp32
            params = jax.tree.map(
                lambda p: p.astype(pd) if p.ndim >= 2 else p, params)
        return params

    def forward(self, params, batch, pctx=None):
        return self.mod.forward(params, self.cfg, batch, pctx)

    def loss(self, params, batch, pctx=None):
        return self.mod.loss(params, self.cfg, batch, pctx)

    def init_cache(self, batch: int, max_seq: int):
        return self.mod.init_cache(self.cfg, batch, max_seq)

    def decode_step(self, params, batch, cache, pctx=None):
        return self.mod.decode_step(params, self.cfg, batch, cache, pctx)

    @property
    def has_prefill(self) -> bool:
        """Does this family implement a batched cache-populating prefill?
        Families without one fall back to a per-token decode loop in the
        serving engine (repro.serve.engine)."""
        return hasattr(self.mod, "prefill")

    def prefill(self, params, batch, cache, pctx=None, pos_offset=0):
        """Batched causal forward over a chunk that writes into ``cache``
        at absolute positions ``pos_offset..pos_offset+C-1``; returns
        (logits [B, C, V], new cache)."""
        if not self.has_prefill:
            raise NotImplementedError(
                f"family {self.cfg.family!r} has no batched prefill; "
                "use a decode-step loop")
        return self.mod.prefill(params, self.cfg, batch, cache, pctx,
                                pos_offset)

    def gemm_layers(self, tokens: int = 256):
        """One decoder block's GEMMs (:func:`repro.core.ops.transformer_gemms`)
        — the unit the plan builder's mapper search and pallas tile planning
        operate on.  Whole-model totals scale linearly in depth, so
        per-block verdicts are depth-invariant."""
        from repro.core.ops import transformer_gemms
        return transformer_gemms(self.cfg, tokens)

    # ------------------------------------------------------------------ #
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for one (arch x shape) cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), tok),
            }
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, s), tok)
        else:   # decode: one new token against a cache of length s
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, 1), tok),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
            }
        if cfg.family in ("encdec", "vlm") and cfg.num_media_tokens:
            specs["media"] = jax.ShapeDtypeStruct(
                (b, cfg.num_media_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs

    def batch_specs(self, shape: ShapeConfig, data_axes=("pod", "data"),
                    ) -> dict:
        """PartitionSpecs matching input_specs (batch over data/pod axes)."""
        bspec = P(data_axes)
        specs = {"tokens": bspec}
        if shape.kind == "train":
            specs["labels"] = bspec
        if shape.kind == "decode":
            specs["pos"] = P()
        if self.cfg.family in ("encdec", "vlm") and self.cfg.num_media_tokens:
            specs["media"] = bspec
        return specs


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILIES:
        raise KeyError(f"unknown family {cfg.family!r}; "
                       f"have {sorted(_FAMILIES)}")
    return Model(cfg, _FAMILIES[cfg.family])


# --------------------------------------------------------------------------- #
# parameter sharding rules (FSDP over 'data', TP over 'model')
# --------------------------------------------------------------------------- #
_COL_NAMES = ("wq", "wk", "wv", "w_up", "w_gate", "w_in", "wr", "wg",
              "lm_head", "w_uk", "w_uv", "w_dkv")
_ROW_NAMES = ("wo", "w_down", "w_out")


def _leaf_spec(path: tuple, leaf, mesh_shape: dict | None) -> P:
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    # stacked-layer leading dims stay unsharded
    lead = 0
    for n in names:
        if n in ("layers", "dense_layers", "enc_layers", "dec_layers",
                 "xlayers"):
            lead += 1
        elif n == "groups":
            lead += 2
    pre = (None,) * lead
    nd = leaf.ndim - lead

    def guard(spec_tail: tuple) -> P:
        """Drop axes that do not evenly divide the dimension."""
        dims = leaf.shape[lead:]
        out = []
        for size, ax in zip(dims, spec_tail):
            if ax is None or mesh_shape is None:
                out.append(ax)
            else:
                span = mesh_shape.get(ax, 1)
                out.append(ax if size % span == 0 and size >= span else None)
        return P(*pre, *out)

    if nd < 2:
        return P(*pre)                                     # norms, biases, ...
    if name == "embed":
        return guard(("model", "data"))                    # [V, D]
    if name in ("w_gate", "w_up", "w_down") and nd == 3:
        # MoE experts [E, D, F] / [E, F, D]: EP over model, FSDP inner
        return guard(("model", "data", None))
    if parent == "cmix" and name == "wv":
        return guard(("model", "data"))                    # [F, D] row-parallel
    if name in _ROW_NAMES:
        return guard(("model", "data"))
    if name in _COL_NAMES or nd == 2:
        return guard(("data", "model"))                    # [D, F] col-parallel
    return P(*pre)


def param_specs(params, mesh=None) -> dict:
    """PartitionSpec pytree mirroring ``params`` (name-rule based).

    With ``mesh``, axes that do not evenly divide a dimension are dropped
    (GQA KV projections narrower than the TP span, odd vocab sizes, ...).
    """
    mesh_shape = dict(mesh.shape) if mesh is not None else None
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_spec(p, l, mesh_shape), params)


def cache_batch_axes(cfg: ModelConfig) -> dict:
    """Pytree (mirroring the decode cache) of each leaf's batch-axis index.

    Derived from :func:`cache_specs` by planting a sentinel where the batch
    axes go, so per-family axis knowledge lives in exactly one place.  The
    serving engine uses this to vmap a per-request decode over cache slots
    and to slice single requests out of a batched cache
    (``repro.serve.kvcache`` / ``parallel.steps.build_paged_serve_step``).
    """
    marker = ("__batch__",)

    def axis_of(spec: P) -> int:
        for i, e in enumerate(spec):
            if e == marker:
                return i
        raise ValueError(f"cache spec {spec} has no batch axis")

    return jax.tree.map(axis_of, cache_specs(cfg, batch_axes=marker),
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------- #
# decode-cache sharding intents (repaired against shapes by fit_specs)
# --------------------------------------------------------------------------- #
def cache_specs(cfg: ModelConfig, batch_axes=("pod", "data")) -> dict:
    """PartitionSpec intents matching init_cache's structure per family."""
    B = batch_axes
    kv5 = P(None, B, None, "model", None)          # [L, B, S, K, hd]
    if cfg.family in ("dense",):
        return {"k": kv5, "v": kv5}
    if cfg.family == "moe":
        out = {"k": kv5, "v": kv5}
        if cfg.moe.first_dense_layers:
            out["dk"] = kv5
            out["dv"] = kv5
        return out
    if cfg.family == "mla_moe":
        lat = {"latent": P(None, B, None, None),
               "k_rope": P(None, B, None, None)}
        out = {"moe": dict(lat)}
        if cfg.moe.first_dense_layers:
            out["dense"] = dict(lat)
        return out
    if cfg.family == "ssm":
        return {
            "state": P(None, B, "model", None, None),
            "tprev": P(None, B, None, "model"),
            "cprev": P(None, B, None, "model"),
        }
    if cfg.family == "hybrid":
        return {
            "ssm": P(None, None, B, "model", None, None),
            "conv": P(None, None, B, None, "model"),
            "k": P(None, B, None, "model", None),
            "v": P(None, B, None, "model", None),
        }
    if cfg.family == "encdec":
        return {"k": kv5, "v": kv5}
    if cfg.family == "vlm":
        kv6 = P(None, None, B, None, "model", None)
        mkv = P(None, B, None, "model", None)
        return {"k": kv6, "v": kv6, "mk": mkv, "mv": mkv}
    raise KeyError(cfg.family)
