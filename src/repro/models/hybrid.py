"""Zamba2: Mamba2 backbone with a weight-shared attention+MLP block.

The shared block (one set of weights) is invoked every ``shared_attn_every``
layers on concat(hidden, original_embedding) (2*d_model input, per the Zamba
papers), with per-invocation input-norm parameters.  Execution is an outer
scan over groups of ``shared_attn_every`` Mamba2 layers + one shared-block
invocation, so the dry-run HLO stays one-group sized.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import _dtype, remat_policy
from repro.parallel.tp import ParallelCtx, col_linear, constrain_acts, row_linear


def _group_count(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.shared_attn_every == 0
    return cfg.n_layers // cfg.shared_attn_every


def init(cfg: ModelConfig, key) -> dict:
    g = _group_count(cfg)
    keys = jax.random.split(key, cfg.n_layers + 4)
    mamba_layers = []
    for i in range(cfg.n_layers):
        mamba_layers.append({
            "ln": jnp.ones((cfg.d_model,)),
            "mamba": S.init_mamba2(keys[i], cfg),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *mamba_layers)
    # regroup leading dim [L] -> [G, per]
    per = cfg.shared_attn_every
    stacked = jax.tree.map(
        lambda a: a.reshape(g, per, *a.shape[1:]), stacked)

    hd = cfg.d_model * 2 // cfg.shared_attn_heads   # shared block head dim
    k1, k2 = jax.random.split(keys[-4])
    shared = {
        "attn": L.init_attn(k1, 2 * cfg.d_model, cfg.shared_attn_heads,
                            cfg.shared_attn_heads, hd),
        "wo_down": L.dense_init(k2, (2 * cfg.d_model, cfg.d_model)),
        "mlp": L.init_mlp(keys[-3], 2 * cfg.d_model, cfg.shared_attn_d_ff),
        "mlp_down": L.dense_init(keys[-2], (2 * cfg.d_model, cfg.d_model)),
    }
    return {
        "embed": L.dense_init(keys[-1], (cfg.vocab, cfg.d_model)),
        "groups": stacked,
        "inv_norms": jnp.ones((g, 2 * cfg.d_model)),   # per-invocation norm
        "shared": shared,
        "ln_f": jnp.ones((cfg.d_model,)),
    }


def shared_block(sp: dict, x: jax.Array, x0: jax.Array, inv_norm, cfg,
                 cos, sin, pctx, cache=None, pos=None):
    """x, x0: [B,S,D].  Returns (delta [B,S,D], new kv cache or None)."""
    h2 = jnp.concatenate([x, x0], axis=-1)
    h2 = L.rms_norm(h2, inv_norm, cfg.norm_eps)
    heads = cfg.shared_attn_heads
    hd = 2 * cfg.d_model // heads
    b, s, _ = h2.shape
    if cache is None:
        q, k, v = L.attn_qkv(sp["attn"], h2, heads, heads, hd, cos, sin,
                             cfg.norm_eps, pctx)
        o = L.attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                        unroll=cfg.scan_unroll)
        new_cache = None
    else:
        q, k, v = L.attn_qkv(sp["attn"], h2, heads, heads, hd, cos, sin,
                             cfg.norm_eps, pctx)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, pos, 0, 0))
        o = L.attn_full(q, ck.astype(q.dtype), cv.astype(q.dtype),
                        causal=True, q_offset=pos)
        new_cache = {"k": ck, "v": cv}
    o = row_linear(o.reshape(b, s, heads * hd), sp["attn"]["wo"], pctx)
    attn_out = o @ sp["wo_down"].astype(o.dtype)          # 2D -> D
    mlp_out = L.mlp_block(sp["mlp"], h2, pctx) @ sp["mlp_down"].astype(x.dtype)
    return attn_out + mlp_out, new_cache


def group_fwd(gp, inv_norm, x, x0, shared, cfg, cos, sin, pctx):
    """One group: shared-attn invocation + ``per`` Mamba2 layers."""
    delta, _ = shared_block(shared, x, x0, inv_norm, cfg, cos, sin, pctx)
    x = x + delta

    def body(carry, lp):
        h = L.rms_norm(carry, lp["ln"], cfg.norm_eps)
        y, _, _ = S.mamba2_block(lp["mamba"], h, cfg, pctx)
        return constrain_acts(carry + y, pctx), None

    x, _ = jax.lax.scan(body, x, gp, unroll=True if cfg.scan_unroll else 1)
    return x


def hidden_states(params, cfg: ModelConfig, tokens, pctx=None):
    x = L.embed(params["embed"], tokens, _dtype(cfg))
    x0 = x
    s = tokens.shape[1]
    hd = 2 * cfg.d_model // cfg.shared_attn_heads
    cos, sin = L.rope_cos_sin(jnp.arange(s), hd, cfg.rope_theta)

    def body(carry, g):
        gp, inv_norm = g
        return group_fwd(gp, inv_norm, carry, x0, params["shared"], cfg,
                         cos, sin, pctx), None

    x = constrain_acts(x, pctx)
    x, _ = jax.lax.scan(jax.checkpoint(body, policy=remat_policy(cfg)),
                        x, (params["groups"], params["inv_norms"]),
                        unroll=True if cfg.scan_unroll else 1)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def forward(params, cfg, batch, pctx=None):
    x = hidden_states(params, cfg, batch["tokens"], pctx)
    return L.logits_head(x, params["embed"].T, pctx)   # tied embeddings


def loss(params, cfg, batch, pctx=None):
    return L.xent_loss(forward(params, cfg, batch, pctx), batch["labels"])


# --------------------------------------------------------------------------- #
# decode: Mamba2 states + conv tails per layer; KV cache per shared invocation
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    g = _group_count(cfg)
    per = cfg.shared_attn_every
    d_inner, h, n, hd, ck = S.mamba2_dims(cfg)
    heads = cfg.shared_attn_heads
    shd = 2 * cfg.d_model // heads
    conv_dim = d_inner + 2 * n
    dt = _dtype(cfg)
    return {
        "ssm": jnp.zeros((g, per, batch, h, hd, n), jnp.float32),
        "conv": jnp.zeros((g, per, batch, ck - 1, conv_dim), dt),
        "k": jnp.zeros((g, batch, max_seq, heads, shd), dt),
        "v": jnp.zeros((g, batch, max_seq, heads, shd), dt),
    }


def decode_step(params, cfg: ModelConfig, batch, cache, pctx=None):
    tokens, pos = batch["tokens"], batch["pos"]
    x = L.embed(params["embed"], tokens, _dtype(cfg))
    x0 = x
    hd = 2 * cfg.d_model // cfg.shared_attn_heads
    cos, sin = L.rope_cos_sin(pos[None], hd, cfg.rope_theta)

    def gbody(x, g):
        gp, inv_norm, ssm, conv, k, v = g
        delta, kv = shared_block(params["shared"], x, x0, inv_norm, cfg,
                                 cos, sin, pctx, cache={"k": k, "v": v},
                                 pos=pos)
        x = x + delta

        def lbody(carry, lp_state):
            lp, st, cv = lp_state
            h = L.rms_norm(carry, lp["ln"], cfg.norm_eps)
            y, st, cv = S.mamba2_block(lp["mamba"], h, cfg, pctx,
                                       state=st, conv_prev=cv,
                                       single_step=True)
            return carry + y, (st, cv)

        x, (ssm, conv) = jax.lax.scan(lbody, x, (gp, ssm, conv),
                                      unroll=True if cfg.scan_unroll else 1)
        return x, (ssm, conv, kv["k"], kv["v"])

    x, (ssm, conv, k, v) = jax.lax.scan(
        gbody, x, (params["groups"], params["inv_norms"], cache["ssm"],
                   cache["conv"], cache["k"], cache["v"]),
        unroll=True if cfg.scan_unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.logits_head(x, params["embed"].T, pctx)
    return logits, {"ssm": ssm, "conv": conv, "k": k, "v": v}
