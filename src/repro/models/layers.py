"""Shared layer library: norms, RoPE, attention (full/chunked/decode), MLP.

Pure JAX, pytree (nested-dict) parameters.  Tensor-parallel matmuls route
through :mod:`repro.parallel.tp` so the paper's INA toggle applies uniformly.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.tp import ParallelCtx, col_linear, row_linear

# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #
def dense_init(key, shape, in_dim: Optional[int] = None, dtype=jnp.float32):
    in_dim = in_dim if in_dim is not None else shape[0]
    scale = 1.0 / math.sqrt(max(in_dim, 1))
    return jax.random.normal(key, shape, dtype) * scale


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions [S] -> (cos, sin) each [S, head_dim/2], float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, H, D]; cos/sin: [S, D/2] (llama-style rotate-half pairs)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[None, :, None, :].astype(jnp.float32)
    s = sin[None, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x1f * s + x2f * c],
                           axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------- #
# attention cores
# --------------------------------------------------------------------------- #
def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """GQA: repeat KV heads to match query heads. k: [B, S, K, D]."""
    kv_heads = k.shape[2]
    if kv_heads == n_heads:
        return k
    reps = n_heads // kv_heads
    return jnp.repeat(k, reps, axis=2)


def attn_full(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
              q_offset: int | jax.Array = 0) -> jax.Array:
    """Exact attention. q: [B,Sq,H,D], k/v: [B,Sk,K,D] -> [B,Sq,H,D].

    GQA via grouped einsum — KV heads are never materialized at H width, so
    a seq- or head-sharded KV cache is consumed in place (repeating KV used
    to force GSPMD to re-gather the whole cache per layer at decode).
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    dv = v.shape[-1]
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    if causal:
        qp = jnp.arange(sq) + q_offset
        kp = jnp.arange(k.shape[1])
        mask = qp[:, None] >= kp[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, sq, h, dv)


def attn_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *, chunk: int,
                 causal: bool, q_offset: int | jax.Array = 0,
                 unroll: bool = False) -> jax.Array:
    """Memory-efficient attention: online softmax over KV chunks.

    Never materializes the [Sq, Sk] score matrix; peak extra memory is
    [B, H, Sq, chunk].  This is the pure-JAX twin of the Pallas flash kernel
    (kernels/flash_attention.py) and is what the dry-run lowers (the CPU
    backend cannot compile TPU Pallas).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    if sk % chunk != 0:
        return attn_full(q, k, v, causal=causal, q_offset=q_offset)
    k, v = _expand_kv(k, h), _expand_kv(v, h)
    dv = v.shape[-1]                      # MLA: v head dim != qk head dim
    nchunks = sk // chunk
    kc = k.reshape(b, nchunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, h, dv).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(d)
    qp = jnp.arange(sq) + q_offset

    def step(carry, inputs):
        m, l, acc = carry
        idx, kb, vb = inputs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        if causal:
            kp = idx * chunk + jnp.arange(chunk)
            mask = qp[:, None] >= kp[None, :]
            s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(nchunks), kc, vc),
        unroll=True if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(q, k, v, *, causal: bool, chunk: int = 0,
              q_offset: int | jax.Array = 0,
              unroll: bool = False) -> jax.Array:
    if chunk and k.shape[1] > chunk and q.shape[1] > 1:
        return attn_chunked(q, k, v, chunk=chunk, causal=causal,
                            q_offset=q_offset, unroll=unroll)
    return attn_full(q, k, v, causal=causal, q_offset=q_offset)


# --------------------------------------------------------------------------- #
# GQA attention block (params + forward), used by dense/moe/hybrid/encdec/vlm
# --------------------------------------------------------------------------- #
def init_attn(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool = False, qkv_bias: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim)),
        "wk": dense_init(ks[1], (d_model, n_kv * head_dim)),
        "wv": dense_init(ks[2], (d_model, n_kv * head_dim)),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model)),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,))
        p["bk"] = jnp.zeros((n_kv * head_dim,))
        p["bv"] = jnp.zeros((n_kv * head_dim,))
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,))
        p["k_norm"] = jnp.ones((head_dim,))
    return p


def attn_qkv(p: dict, x: jax.Array, n_heads: int, n_kv: int, head_dim: int,
             cos, sin, eps: float, pctx: Optional[ParallelCtx] = None):
    """Project to q/k/v heads (+qk-norm, +rope). Returns q,k,v [B,S,H,D]."""
    b, s, _ = x.shape
    q = col_linear(x, p["wq"], pctx, p.get("bq")).reshape(b, s, n_heads, head_dim)
    k = col_linear(x, p["wk"], pctx, p.get("bk")).reshape(b, s, n_kv, head_dim)
    v = col_linear(x, p["wv"], pctx, p.get("bv")).reshape(b, s, n_kv, head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attn_block(p: dict, x: jax.Array, *, n_heads: int, n_kv: int,
               head_dim: int, cos, sin, causal: bool = True, chunk: int = 0,
               eps: float = 1e-5, pctx: Optional[ParallelCtx] = None,
               unroll: bool = False) -> jax.Array:
    b, s, _ = x.shape
    q, k, v = attn_qkv(p, x, n_heads, n_kv, head_dim, cos, sin, eps, pctx)
    o = attention(q, k, v, causal=causal, chunk=chunk, unroll=unroll)
    return row_linear(o.reshape(b, s, n_heads * head_dim), p["wo"], pctx)


def attn_block_decode(p: dict, x: jax.Array, cache_k, cache_v, pos, *,
                      n_heads: int, n_kv: int, head_dim: int, cos, sin,
                      eps: float = 1e-5, pctx: Optional[ParallelCtx] = None):
    """Single-token decode with a KV cache [B, S, K, D]; returns (y, k, v).

    Attention is masked to cache positions ``<= pos`` (``causal=True`` with
    the query offset at ``pos``): the zero-initialized tail of the cache
    must not dilute the softmax, and masking it makes a per-token decode
    loop agree with a batched causal prefill over the same tokens.
    """
    b = x.shape[0]
    q, k, v = attn_qkv(p, x, n_heads, n_kv, head_dim, cos, sin, eps, pctx)
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                      (0, pos, 0, 0))
    o = attn_full(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=True,
                  q_offset=pos)
    y = row_linear(o.reshape(b, 1, n_heads * head_dim), p["wo"], pctx)
    return y, ck, cv


# --------------------------------------------------------------------------- #
# SwiGLU / GeLU MLP
# --------------------------------------------------------------------------- #
def init_mlp(key, d_model: int, d_ff: int, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff)),
         "w_down": dense_init(ks[1], (d_ff, d_model))}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff))
    return p


def mlp_block(p: dict, x: jax.Array,
              pctx: Optional[ParallelCtx] = None) -> jax.Array:
    up = col_linear(x, p["w_up"], pctx)
    if "w_gate" in p:
        h = jax.nn.silu(col_linear(x, p["w_gate"], pctx)) * up
    else:
        h = jax.nn.gelu(up)
    return row_linear(h, p["w_down"], pctx)


# --------------------------------------------------------------------------- #
# embedding / logits / loss
# --------------------------------------------------------------------------- #
def embed(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    return table.astype(dtype)[tokens]


def logits_head(x: jax.Array, w: jax.Array,
                pctx: Optional[ParallelCtx] = None) -> jax.Array:
    return col_linear(x, w, pctx)   # vocab-sharded logits


def xent_loss(logits: jax.Array, labels: jax.Array,
              z_coef: float = 0.0) -> jax.Array:
    """Mean next-token cross-entropy; logits [B,S,V], labels [B,S]."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - gold)
    if z_coef:
        loss = loss + z_coef * jnp.mean(jnp.square(lse))
    return loss
