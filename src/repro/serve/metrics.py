"""Serving metrics: TTFT / TPOT / latency percentiles, Little's law.

Summaries are plain-float dicts, rounded to a fixed precision and written
with sorted keys — byte-identical across runs of the same seed (no
wall-clock, no dict-order dependence; see tests/test_serve_cluster.py).
"""
from __future__ import annotations

_ROUND = 9


def percentile(xs: list[float], p: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    import math
    rank = max(1, math.ceil(p / 100.0 * len(xs)))
    return xs[rank - 1]


def _dist(xs: list[float]) -> dict:
    if not xs:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0, "max": 0.0}
    return {
        "mean": round(sum(xs) / len(xs), _ROUND),
        "p50": round(percentile(xs, 50), _ROUND),
        "p95": round(percentile(xs, 95), _ROUND),
        "p99": round(percentile(xs, 99), _ROUND),
        "max": round(max(xs), _ROUND),
    }


def time_in_system(records: list[dict]) -> float:
    """Time-averaged number of requests in the system (arrival..finish),
    over the span from first arrival to last finish."""
    if not records:
        return 0.0
    t0 = min(r["arrival"] for r in records)
    t1 = max(r["finish"] for r in records)
    if t1 <= t0:
        return 0.0
    area = sum(r["finish"] - r["arrival"] for r in records)
    return area / (t1 - t0)


def summarize(records: list[dict]) -> dict:
    """Aggregate per-request records into the serving metrics dict.

    Each record: ``arrival``, ``admit``, ``first_token``, ``finish``
    (seconds), ``prompt_len``, ``max_new``.
    """
    if not records:
        return {"requests": 0, "tokens_out": 0, "makespan_s": 0.0,
                "throughput_rps": 0.0, "throughput_tok_s": 0.0,
                "queueing_s": _dist([]), "ttft_s": _dist([]),
                "tpot_s": _dist([]), "e2e_s": _dist([]),
                "littles_law_ratio": 1.0}
    t0 = min(r["arrival"] for r in records)
    t1 = max(r["finish"] for r in records)
    makespan = t1 - t0
    tokens = sum(r["max_new"] for r in records)
    n = len(records)
    queueing = [r["admit"] - r["arrival"] for r in records]
    ttft = [r["first_token"] - r["arrival"] for r in records]
    e2e = [r["finish"] - r["arrival"] for r in records]
    tpot = [(r["finish"] - r["first_token"]) / (r["max_new"] - 1)
            for r in records if r["max_new"] > 1]

    # Little's law: L = lambda * W.  lambda is estimated from the observed
    # arrival span (not the makespan — that would make the identity hold
    # by construction), W is the mean time in system, and L is the
    # time-averaged occupancy integrated over the run; the ratio is a
    # consistency check on the event loop, ~1.0 up to finite-horizon edge
    # effects.  Degenerates to 1.0 for batch arrivals (zero span).
    arr_span = max(r["arrival"] for r in records) - t0
    lam = n / makespan if makespan > 0 else 0.0
    w = sum(e2e) / n
    l_direct = time_in_system(records)
    if arr_span > 0 and l_direct > 0:
        ratio = ((n - 1) / arr_span) * w / l_direct
    else:
        ratio = 1.0

    return {
        "requests": n,
        "tokens_out": tokens,
        "makespan_s": round(makespan, _ROUND),
        "throughput_rps": round(lam, _ROUND),
        "throughput_tok_s": round(tokens / makespan, _ROUND)
        if makespan > 0 else 0.0,
        "queueing_s": _dist(queueing),
        "ttft_s": _dist(ttft),
        "tpot_s": _dist(tpot),
        "e2e_s": _dist(e2e),
        "littles_law_ratio": round(ratio, _ROUND),
    }
