"""NoC-costed iteration latencies for the cluster simulator.

:class:`PlanCostModel` turns per-phase :class:`~repro.plan.ExecutionPlan`s
into wall-clock step latencies: one serving iteration's cycles are the
per-decoder-block GEMM cycles of the phase plan's mapper verdicts (scaled
by how many M-tile passes the in-flight token count needs and by the
model's depth) plus the plan's psum collective cycles.  Because PR-5 plans
record the cost of **every** auto candidate per psum site
(``PsumDecision.costs``) and both the INA-searched and eject/inject
baseline mapper verdicts per GEMM, a single plan prices both semantics —
``semantics="ina"`` vs ``"eject_inject"`` needs no replanning, which is
what lets ``experiments --section serve`` sweep the INA advantage into a
fleet-size delta.

Cycles → seconds via ``clock_ghz`` plus a ``calibration`` scale, the hook
for anchoring against a measured engine (fit one scalar from a real
iteration time; the default 1.0 keeps results in model-relative units).
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ModelConfig, depth_units

SEMANTICS = ("ina", "eject_inject")


def _gemm_cycles(plan, semantics: str) -> float:
    """One decoder block's GEMM cycles at the plan's M tile."""
    if semantics == "ina":
        return sum(g.latency_cycles for g in plan.gemms)
    return sum(g.baseline_latency_cycles for g in plan.gemms)


def _psum_cycles(plan, semantics: str) -> float:
    """All psum sites' cycles under one collective semantics."""
    total = 0.0
    for d in plan.psum:
        costs = d.cost_of
        lat = costs.get(semantics)
        if lat is None:                     # plan predates per-mode costs
            lat = costs.get(d.mode, (0.0, 0.0))
        total += lat[0] * d.count
    return total


@dataclasses.dataclass(frozen=True)
class PlanCostModel:
    """Step latencies derived from (prefill plan, decode plan).

    ``chips`` is read off the plans (DESIGN.md S14): a ``chips``-chip
    replica shards the token tile across its chips (the mapper's output-row
    split), so one pass covers ``tokens * chips`` tokens — the psum cycles
    already carry the plans' hierarchical collective pricing.
    """

    arch: str
    semantics: str
    clock_ghz: float
    calibration: float
    depth: int
    prefill_chunk: int
    pf_gemm_cycles: float          # per block, at pf_tokens M tile
    pf_tokens: int
    pf_psum_cycles: float
    dec_gemm_cycles: float
    dec_tokens: int
    dec_psum_cycles: float
    chips: int = 1                 # chips per replica (from the plans)

    @classmethod
    def from_plans(cls, cfg: ModelConfig, prefill_plan, decode_plan,
                   prefill_chunk: int, semantics: str = "ina",
                   clock_ghz: float = 1.0, calibration: float = 1.0,
                   ) -> "PlanCostModel":
        if semantics not in SEMANTICS:
            raise ValueError(f"semantics {semantics!r} not in {SEMANTICS}")
        if not prefill_plan.gemms or not decode_plan.gemms:
            raise ValueError("cost model needs plans built with gemm_search")
        if prefill_plan.chips != decode_plan.chips:
            raise ValueError(
                f"phase plans disagree on chip count "
                f"({prefill_plan.chips} vs {decode_plan.chips})")
        return cls(
            arch=cfg.name, semantics=semantics, clock_ghz=clock_ghz,
            calibration=calibration, depth=depth_units(cfg),
            prefill_chunk=prefill_chunk,
            pf_gemm_cycles=_gemm_cycles(prefill_plan, semantics),
            pf_tokens=prefill_plan.tokens,
            pf_psum_cycles=_psum_cycles(prefill_plan, semantics),
            dec_gemm_cycles=_gemm_cycles(decode_plan, semantics),
            dec_tokens=decode_plan.tokens,
            dec_psum_cycles=_psum_cycles(decode_plan, semantics),
            chips=prefill_plan.chips)

    def _seconds(self, cycles: float) -> float:
        return cycles / (self.clock_ghz * 1e9) * self.calibration

    def prefill_chunk_seconds(self) -> float:
        """One B=1 chunk of chunked prefill."""
        tiles = max(1, math.ceil(self.prefill_chunk
                                 / (self.pf_tokens * self.chips)))
        return self._seconds(
            self.depth * self.pf_gemm_cycles * tiles + self.pf_psum_cycles)

    def decode_iter_seconds(self, n_active: int) -> float:
        """One continuous-batching decode step over ``n_active`` slots."""
        tiles = max(1, math.ceil(max(1, n_active)
                                 / (self.dec_tokens * self.chips)))
        return self._seconds(
            self.depth * self.dec_gemm_cycles * tiles + self.dec_psum_cycles)


@dataclasses.dataclass(frozen=True)
class DegradedCostModel:
    """A cost model slowed by on-die faults (DESIGN.md S15).

    Wraps any step-cost model and scales every latency by ``slowdown`` —
    the faulted/clean collective latency ratio from the same simulated
    mesh (see :func:`fault_slowdown`), so the cluster simulator prices a
    degraded replica without replanning."""

    base: object
    slowdown: float = 1.0

    def prefill_chunk_seconds(self) -> float:
        return self.base.prefill_chunk_seconds() * self.slowdown

    def decode_iter_seconds(self, n_active: int) -> float:
        return self.base.decode_iter_seconds(n_active) * self.slowdown


def fault_slowdown(faults, cfg=None, *, payload_bits: float = 4096.0,
                   semantics: str = "ina") -> float:
    """Faulted/clean allreduce latency ratio on ``cfg``'s mesh — the
    single scalar :class:`DegradedCostModel` scales a replica's step
    costs by.  An empty model returns exactly 1.0; the ratio is clamped
    at 1.0 from below (the repair BFS can emit a *shallower* tree than
    the clean XY embedding, but a degraded replica never speeds up)."""
    from repro.core.noc.collective.cost import collective_cost
    from repro.core.noc.router import NocConfig
    cfg = NocConfig() if cfg is None else cfg
    if faults is None or faults.empty:
        return 1.0
    clean = collective_cost("allreduce", payload_bits, cfg,
                            semantics=semantics)
    faulted = collective_cost("allreduce", payload_bits, cfg,
                              semantics=semantics, faults=faults)
    return max(1.0, faulted.latency_cycles / max(1, clean.latency_cycles))


@dataclasses.dataclass(frozen=True)
class SyntheticCostModel:
    """Fixed latencies for unit tests (no plans, no NoC)."""

    prefill_chunk_s: float = 0.002
    decode_base_s: float = 0.004
    decode_per_slot_s: float = 0.0005

    def prefill_chunk_seconds(self) -> float:
        return self.prefill_chunk_s

    def decode_iter_seconds(self, n_active: int) -> float:
        return self.decode_base_s + self.decode_per_slot_s * n_active


def serve_plans(cfg: ModelConfig, mesh_shape, plan_dir=None,
                verbose: bool = True, chips: int = 1,
                package: str = "mesh") -> dict:
    """Per-phase plans for serving: ``{"prefill": (plan, info), "decode":
    (plan, info)}`` through :func:`~repro.plan.plan_for_launch` on the
    canonical phase shapes — a store warmed by ``experiments --section
    plan`` (or a previous serve run) answers with **zero collective
    simulations**, the acceptance evidence ``repro.serve`` reports.
    ``chips`` > 1 plans a multi-chip replica (hierarchical psum pricing,
    stored under the plan's ``__cN`` key)."""
    from repro.configs.base import SHAPES
    from repro.plan import plan_for_launch

    out = {}
    for phase, shape_name in (("prefill", "prefill_32k"),
                              ("decode", "decode_32k")):
        plan, info = plan_for_launch(cfg, mesh_shape, SHAPES[shape_name],
                                     "auto", plan_dir=plan_dir,
                                     verbose=verbose, chips=chips,
                                     package=package)
        out[phase] = (plan, info)
    return out
