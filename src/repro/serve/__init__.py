"""Serving subsystem: continuous batching + paged KV execution engine and
the request-level cluster capacity simulator (DESIGN.md S12).

* :mod:`repro.serve.engine` — real jax serving: continuous batching over a
  vmapped per-slot decode step, chunked batched prefill, paged KV cache.
* :mod:`repro.serve.cluster` — fleets of simulated instances with
  NoC-plan-derived iteration latencies; TTFT/TPOT/p99 + fleet sizing.
* ``python -m repro.serve`` — the capacity-planning CLI gluing both.
"""
from repro.serve.batching import Request, RequestQueue, RequestState, Scheduler
from repro.serve.cluster import ClusterSimulator, search_fleet
from repro.serve.costs import PlanCostModel, SyntheticCostModel, serve_plans
from repro.serve.engine import ServingEngine
from repro.serve.kvcache import BlockAllocator, PagedKVCache
from repro.serve.metrics import percentile, summarize
from repro.serve.traffic import load_trace, make_workload, poisson_arrivals

__all__ = [
    "BlockAllocator", "ClusterSimulator", "PagedKVCache", "PlanCostModel",
    "Request", "RequestQueue", "RequestState", "Scheduler", "ServingEngine",
    "SyntheticCostModel", "load_trace", "make_workload", "percentile",
    "poisson_arrivals", "search_fleet", "serve_plans", "summarize",
]
