"""Paged KV cache: fixed-size blocks, a free-list allocator, block tables.

The serving engine keeps two views of decode state:

* a **monolithic working cache** on device (``model.init_cache(slots,
  max_seq)``) that the jitted decode/prefill steps read and write — jax
  wants dense rectangular arrays;
* this **paged pool** on host, the authoritative per-request store.  Leaves
  with a sequence axis (K/V, MLA latents) are chopped into fixed-size
  position blocks owned by a free-list :class:`BlockAllocator`; leaves
  without one (SSM states, conv tails) are stored whole per request.

A request's cache row round-trips bit-identically: columns extracted from
the working cache go into blocks verbatim, and :meth:`PagedKVCache.
gather_row` reassembles exactly the row the monolithic cache held (zeros
past the request's length, which decode attention masks out).  That makes
"paged == monolithic" a checkable invariant rather than a hope — see
``ServingEngine(check=True)`` and tests/test_serve.py.

Block accounting is the admission-control currency shared with the
request-level cluster simulator (:mod:`repro.serve.cluster`): an instance
admits a request only when enough free blocks exist for its worst-case
length (prompt + max_new, reserved up front — simple and safe; growing
on demand is a possible refinement noted in DESIGN.md S12).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` fixed-size blocks.

    Invariants (checked by :meth:`check`): every block is either free or
    owned by exactly one request (no aliasing), and ``free + live ==
    total`` (no leaks).  Allocation order is deterministic (lowest block
    id first) so simulations replay identically.
    """

    def __init__(self, num_blocks: int) -> None:
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))   # pop() -> lowest id
        self.tables: dict[object, list[int]] = {}

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return sum(len(t) for t in self.tables.values())

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, rid, n: int) -> list[int]:
        """Reserve ``n`` blocks for ``rid`` (must not already own any)."""
        if rid in self.tables:
            raise KeyError(f"request {rid!r} already has a block table")
        if n < 0 or not self.can_alloc(n):
            raise MemoryError(
                f"need {n} blocks, {len(self._free)} free "
                f"(of {self.num_blocks})")
        blocks = [self._free.pop() for _ in range(n)]
        self.tables[rid] = blocks
        return blocks

    def extend(self, rid, n: int) -> list[int]:
        """Append ``n`` more blocks to an existing table."""
        if rid not in self.tables:      # check before popping: a failed
            raise KeyError(             # extend must not leak free blocks
                f"request {rid!r} has no block table to extend")
        if n < 0 or not self.can_alloc(n):
            raise MemoryError(
                f"need {n} more blocks, {len(self._free)} free")
        new = [self._free.pop() for _ in range(n)]
        self.tables[rid].extend(new)
        return new

    def free(self, rid) -> int:
        """Release every block ``rid`` owns; returns how many."""
        blocks = self.tables.pop(rid)
        self._free.extend(reversed(blocks))
        self._free.sort(reverse=True)    # keep pop() order deterministic
        return len(blocks)

    def check(self) -> None:
        """Raise on any no-alias / no-leak violation.

        Delegates to the static verifier (``repro.analysis``) so the CLI
        and this runtime guard agree on one invariant set; raises
        ``AssertionError`` (explicitly — not a bare ``assert``, so the
        check survives ``python -O``)."""
        from repro.analysis.verify import verify_allocator
        findings = verify_allocator(self)
        if findings:
            raise AssertionError("; ".join(str(f) for f in findings))


@dataclasses.dataclass(frozen=True)
class _LeafMeta:
    """Layout of one cache leaf, batch axis removed (a 'row')."""

    name: str              # '/'-joined tree path, for debugging
    batch_axis: int        # axis index in the *batched* leaf
    paged: bool            # has a max_seq axis right after the batch axis
    row_shape: tuple       # shape with the batch axis removed
    dtype: object


def _flatten_with_names(tree):
    import jax
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", k)) for k in path)
             for path, _ in leaves]
    return names, [leaf for _, leaf in leaves], treedef


class PagedKVCache:
    """Host-side paged store for one engine's (or one simulated
    instance's) decode state.

    ``row`` trees below always mean a single request's cache with the
    batch axis removed (what ``jnp.take(leaf, slot, axis=batch_axis)``
    yields); paged leaves keep their native axis order, with the sequence
    axis sitting where the batch axis used to be.
    """

    def __init__(self, cfg, max_seq: int, block_size: int,
                 num_blocks: int) -> None:
        import jax

        from repro.models.api import cache_batch_axes, get_model
        if max_seq % block_size:
            raise ValueError(f"block_size {block_size} must divide "
                             f"max_seq {max_seq}")
        self.cfg = cfg
        self.max_seq = max_seq
        self.block_size = block_size
        self.allocator = BlockAllocator(num_blocks)

        model = get_model(cfg)
        shapes = jax.eval_shape(lambda: model.init_cache(1, max_seq))
        baxes = cache_batch_axes(cfg)
        names, leaves, self._treedef = _flatten_with_names(shapes)
        _, axes, _ = _flatten_with_names(baxes)
        self.leaves: list[_LeafMeta] = []
        self._pools: list = []           # aligned; None for unpaged leaves
        for name, leaf, a in zip(names, leaves, axes):
            row = leaf.shape[:a] + leaf.shape[a + 1:]
            # After removing the batch axis the sequence axis (if any) is
            # at index ``a``; identified by its extent == max_seq.  Small
            # leaf dims never collide with a serving-scale max_seq.
            paged = a < len(row) and row[a] == max_seq
            self.leaves.append(_LeafMeta(name, a, paged, row,
                                         np.dtype(leaf.dtype)))
            if paged:
                per_pos = row[:a] + row[a + 1:]
                self._pools.append(np.zeros(
                    (num_blocks, block_size) + per_pos,
                    dtype=np.dtype(leaf.dtype)))
            else:
                self._pools.append(None)
        # per-request store for unpaged leaves (whole rows, latest value)
        self._state: dict[object, list] = {}
        self._length: dict[object, int] = {}

    # ------------------------------------------------------------------ #
    def blocks_for(self, positions: int) -> int:
        return math.ceil(positions / self.block_size)

    def can_admit(self, positions: int) -> bool:
        return self.allocator.can_alloc(self.blocks_for(positions))

    def admit(self, rid, positions: int) -> None:
        """Reserve blocks for ``positions`` cache slots (prompt + max
        new tokens — worst case up front)."""
        self.allocator.alloc(rid, self.blocks_for(positions))
        self._state[rid] = [None] * len(self.leaves)
        self._length[rid] = 0

    def release(self, rid) -> int:
        self._state.pop(rid)
        self._length.pop(rid)
        return self.allocator.free(rid)

    def length(self, rid) -> int:
        return self._length[rid]

    # ------------------------------------------------------------------ #
    def _seq_front(self, meta: _LeafMeta, row):
        """Move a row leaf's sequence axis to the front."""
        return np.moveaxis(row, meta.batch_axis, 0)

    def write_range(self, rid, pos0: int, row_tree, length: int) -> None:
        """Store positions ``[pos0, pos0+length)`` of ``row_tree`` (a full
        or partial row whose paged leaves carry >= pos0+length positions)
        and refresh the unpaged per-request state."""
        table = self.allocator.tables[rid]
        rows = self._treedef.flatten_up_to(row_tree)
        for i, (meta, row) in enumerate(zip(self.leaves, rows)):
            row = np.asarray(row)
            if not meta.paged:
                self._state[rid][i] = row.copy()
                continue
            sf = self._seq_front(meta, row)
            for pos in range(pos0, pos0 + length):
                blk, off = divmod(pos, self.block_size)
                self._pools[i][table[blk], off] = sf[pos]
        self._length[rid] = max(self._length[rid], pos0 + length)

    def gather_row(self, rid, length: int | None = None):
        """Reassemble ``rid``'s row (native layout): block contents for
        positions < length, zeros beyond (exactly the monolithic slot)."""
        table = self.allocator.tables[rid]
        length = self._length[rid] if length is None else length
        out = []
        for i, meta in enumerate(self.leaves):
            if not meta.paged:
                st = self._state[rid][i]
                out.append(np.zeros(meta.row_shape, meta.dtype)
                           if st is None else st.copy())
                continue
            per_pos = meta.row_shape[:meta.batch_axis] + \
                meta.row_shape[meta.batch_axis + 1:]
            sf = np.zeros((self.max_seq,) + per_pos, meta.dtype)
            for pos in range(length):
                blk, off = divmod(pos, self.block_size)
                sf[pos] = self._pools[i][table[blk], off]
            out.append(np.moveaxis(sf, 0, meta.batch_axis))
        return self._treedef.unflatten(out)

    def assert_matches(self, rid, row_tree, length: int) -> None:
        """Bitwise: pooled content == ``row_tree`` on positions < length
        (the paged==monolithic invariant)."""
        rows = self._treedef.flatten_up_to(row_tree)
        mine = self._treedef.flatten_up_to(self.gather_row(rid, length))
        for meta, theirs, ours in zip(self.leaves, rows, mine):
            theirs = np.asarray(theirs)
            if meta.paged:
                sl = [slice(None)] * theirs.ndim
                sl[meta.batch_axis] = slice(0, length)
                theirs, ours = theirs[tuple(sl)], ours[tuple(sl)]
            if not np.array_equal(theirs, ours):
                raise AssertionError(
                    f"paged/monolithic mismatch on leaf {meta.name} "
                    f"for request {rid!r}")

    def check(self) -> None:
        """Allocator invariants plus the paged bookkeeping (state/length
        keys match block tables, lengths covered); see kvcache check()."""
        from repro.analysis.verify import verify_kvcache
        findings = verify_kvcache(self)
        if findings:
            raise AssertionError("; ".join(str(f) for f in findings))
