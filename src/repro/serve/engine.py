"""ServingEngine: continuous batching + paged KV over real jax decode.

The engine is the *execution* half of the serving subsystem (the
request-level :mod:`~repro.serve.cluster` simulator is the capacity half;
they share :mod:`~repro.serve.batching` and the block-accounting rules of
:mod:`~repro.serve.kvcache`).  Per iteration it

1. admits queued requests into free cache slots (token boundary only),
2. prefills each admitted prompt — batched chunked prefill
   (:func:`~repro.parallel.steps.build_prefill_step`) when the family
   implements ``prefill``, a per-token decode loop otherwise — writing the
   prompt's K/V into the paged pool and emitting the first token,
3. runs one vmapped per-slot-position decode step
   (:func:`~repro.parallel.steps.build_paged_serve_step`) over the whole
   slot batch, appends one token per active request, and pages out the
   newly written cache column,
4. retires finished requests, releasing their blocks and slot.

Each slot computes exactly what the request would compute running alone
(the decode step is a vmap of the B=1 decode; decode attention masks
positions ``> pos``), so joining or leaving the batch can never change a
request's tokens — the property tests/test_serve.py pins against the
legacy one-batch loop.

Prefill/decode are disaggregated: each phase carries its own
``ParallelCtx`` (and, under ``--psum-mode auto``, its own
:class:`~repro.plan.ExecutionPlan` via ``plan_for_launch`` — see
``launch/serve.py``).

Media-conditioned families (encdec/vlm) need a per-request media tensor
threaded through admission; the engine rejects them — the legacy batch
loop in ``launch/serve.py`` still serves those.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.serve.batching import Request, RequestState, Scheduler
from repro.serve.kvcache import PagedKVCache

_NO_ENGINE_FAMILIES = ("encdec", "vlm")


@dataclasses.dataclass
class EngineReport:
    """What one :meth:`ServingEngine.run` did (deterministic content)."""

    requests: list                 # per-request dicts, finish order
    iterations: int
    prefill_chunks: int
    decode_steps: int
    checks: int                    # paged==monolithic verifications passed

    def tokens(self) -> dict:
        return {r["rid"]: r["tokens"] for r in self.requests}


class ServingEngine:
    def __init__(self, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: Optional[int] = None, block_size: int = 16,
                 num_blocks: Optional[int] = None, prefill_chunk: int = 8,
                 psum_mode: str = "ina", prefill_plan=None, decode_plan=None,
                 batched_prefill: bool = True, policy: str = "fcfs",
                 model_parallel: int = 1, check: bool = False,
                 param_seed: int = 0) -> None:
        import jax

        from repro.launch.mesh import make_host_mesh
        from repro.models.api import get_model
        from repro.parallel.steps import (build_paged_serve_step,
                                          build_prefill_step)
        from repro.parallel.tp import ParallelCtx

        if cfg.family in _NO_ENGINE_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} needs per-request media plumbing; "
                "use launch/serve.py --legacy-loop")
        self.cfg = cfg
        self.model = get_model(cfg)
        self.slots = slots
        self.max_seq = max_seq or cfg.max_seq
        self.prefill_chunk = prefill_chunk
        self.check = check
        if num_blocks is None:
            # enough for every slot to hold a full-length request
            num_blocks = slots * math.ceil(self.max_seq / block_size)
        self.kv = PagedKVCache(cfg, self.max_seq, block_size, num_blocks)
        self.sched = Scheduler(slots, self.kv, policy)

        self.mesh = make_host_mesh(model_parallel)
        pctx_d = ParallelCtx(mesh=self.mesh, psum_mode=psum_mode,
                             plan=decode_plan)
        pctx_p = ParallelCtx(mesh=self.mesh, psum_mode=psum_mode,
                             plan=prefill_plan)
        dshape = ShapeConfig("serve", self.max_seq, slots, "decode")
        self.step = build_paged_serve_step(self.model, self.mesh, dshape,
                                           pctx_d, donate_cache=True)
        self.baxis = self.step.cache_batch_axes

        self.prefill_step = None
        if batched_prefill and self.model.has_prefill:
            pshape = ShapeConfig("serve", self.max_seq, 1, "prefill")
            self.prefill_step = build_prefill_step(
                self.model, self.mesh, pshape, prefill_chunk, pctx_p,
                donate_cache=True)
            self._pcache = self.model.init_cache(1, self.max_seq)
        else:
            # per-token fallback: B=1 decode loop doubles as prefill
            self._loop_step = jax.jit(
                lambda p, t, pos, c: self.model.decode_step(
                    p, {"tokens": t, "pos": pos}, c, pctx_p))

        self.params = jax.device_put(
            self.model.init(jax.random.PRNGKey(param_seed)),
            self.step.param_sharding)
        self.working = self.model.init_cache(slots, self.max_seq)
        self._jnp = jax.numpy
        self._jax = jax

    # ------------------------------------------------------------------ #
    def _extract_row(self, cache, slot: int):
        """One slot's cache row (host numpy, batch axis removed)."""
        jnp = self._jnp
        return self._jax.tree.map(
            lambda leaf, a: np.asarray(jnp.take(leaf, slot, axis=a)),
            cache, self.baxis)

    def _seat(self, st: RequestState) -> None:
        """Materialize the request's pooled row into its working-cache
        slot (zeros past its length — masked by decode attention)."""
        jnp = self._jnp
        row = self.kv.gather_row(st.req.rid, st.req.prompt_len)

        def put(leaf, r, a, slot=st.slot):
            idx = (slice(None),) * a + (slot,)
            return leaf.at[idx].set(jnp.asarray(r, dtype=leaf.dtype))

        self.working = self._jax.tree.map(put, self.working, row, self.baxis)

    def _prefill(self, st: RequestState) -> tuple[int, int]:
        """Run the prompt, write its K/V into the pool, return (first
        generated token, chunk/step count)."""
        jnp = self._jnp
        req = st.req
        prompt = np.asarray(req.prompt, np.int32)
        plen = req.prompt_len
        if self.prefill_step is not None:
            chunk = self.prefill_chunk
            steps = 0
            logits = None
            for c0 in range(0, plen, chunk):
                part = prompt[c0:c0 + chunk]
                toks = np.zeros((1, chunk), np.int32)
                toks[0, :len(part)] = part     # pad tail: causally masked
                logits, self._pcache = self.prefill_step.fn(
                    self.params,
                    {"tokens": jnp.asarray(toks),
                     "pos0": jnp.asarray(c0, jnp.int32)},
                    self._pcache)
                steps += 1
            first = int(jnp.argmax(logits[0, (plen - 1) % chunk]))
            row = self._extract_row(self._pcache, 0)
        else:
            cache = self.model.init_cache(1, self.max_seq)
            steps = 0
            for pos in range(plen):
                lg, cache = self._loop_step(
                    self.params, jnp.asarray(prompt[None, pos:pos + 1]),
                    jnp.asarray(pos, jnp.int32), cache)
                steps += 1
            first = int(jnp.argmax(lg[0, -1]))
            row = self._extract_row(cache, 0)
        self.kv.write_range(req.rid, 0, row, plen)
        return first, steps

    # ------------------------------------------------------------------ #
    def run(self, requests: list[Request], max_iters: int = 100_000,
            ) -> EngineReport:
        jnp = self._jnp
        for req in requests:
            if req.prompt is None:
                raise ValueError(f"{req.rid}: engine requests need tokens")
            if req.total_positions > self.max_seq:
                raise ValueError(f"{req.rid}: prompt+max_new "
                                 f"{req.total_positions} > max_seq "
                                 f"{self.max_seq}")
            self.sched.submit(req)

        finished, it, pf_chunks, dsteps, checks = [], 0, 0, 0, 0
        while self.sched.has_work:
            if it >= max_iters:
                raise RuntimeError(f"engine exceeded {max_iters} iterations")
            admitted = self.sched.admit(now=it)
            for st in admitted:
                first, steps = self._prefill(st)
                pf_chunks += steps
                self._seat(st)
                st.generated.append(first)
                st.first_token_time = it
            if not self.sched.active:
                if len(self.sched.queue):
                    head = self.sched.queue.peek()
                    raise RuntimeError(
                        f"request {head.rid!r} can never be admitted "
                        f"(needs {self.kv.blocks_for(head.total_positions)} "
                        f"blocks of {self.kv.allocator.num_blocks})")
                break
            checks += self._retire(it, finished)
            if not self.sched.active:
                it += 1
                continue

            toks = np.zeros((self.slots, 1), np.int32)
            pos = np.zeros((self.slots,), np.int32)
            for slot, st in self.sched.active.items():
                toks[slot, 0] = st.generated[-1]
                pos[slot] = st.pos - 1           # feed token at its position
            nxt, self.working = self.step.fn(
                self.params,
                {"tokens": jnp.asarray(toks), "pos": jnp.asarray(pos)},
                self.working)
            nxt = np.asarray(nxt)
            dsteps += 1
            for slot, st in list(self.sched.active.items()):
                written = st.pos - 1
                row = self._extract_row(self.working, slot)
                self.kv.write_range(st.req.rid, written, row, 1)
                st.generated.append(int(nxt[slot]))
            it += 1
            checks += self._retire(it, finished)
        self.kv.check()
        return EngineReport(requests=finished, iterations=it,
                            prefill_chunks=pf_chunks, decode_steps=dsteps,
                            checks=checks)

    def _retire(self, it: int, finished: list) -> int:
        checks = 0
        for slot in sorted(self.sched.active):
            st = self.sched.active[slot]
            if not st.done:
                continue
            if self.check:
                # every position actually fed is pooled bit-identically
                covered = st.req.prompt_len + len(st.generated) - 1
                self.kv.assert_matches(
                    st.req.rid, self._extract_row(self.working, slot),
                    min(covered, self.max_seq))
                self.kv.check()
                checks += 1
            self.sched.finish(slot, now=it)
            finished.append({
                "rid": st.req.rid, "slot": slot,
                "prompt_len": st.req.prompt_len,
                "tokens": list(st.generated),
                "admit_iter": int(st.admit_time),
                "first_token_iter": int(st.first_token_time),
                "finish_iter": it,
            })
        return checks
