"""Request queue + continuous-batching scheduler.

One :class:`Scheduler` drives both execution surfaces: the real
:class:`~repro.serve.engine.ServingEngine` (jax decode steps) and the
request-level :class:`~repro.serve.cluster.ClusterSimulator` (cost-model
iterations).  Sharing the admission logic is the point — the simulator's
capacity answer ("how many meshes at this SLO") is only credible if it
admits and evicts exactly like the engine it models.

Continuous batching: requests join and leave the running batch at token
boundaries only.  Admission happens at the top of an iteration when (a) a
cache slot is free and (b) the paged-KV block allocator can reserve the
request's worst-case footprint (prompt + max_new, see
:mod:`repro.serve.kvcache`).  Policies: ``fcfs`` (arrival order) or
``priority`` (lower value first, arrival-stable).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

POLICIES = ("fcfs", "priority")


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.

    ``prompt`` carries real token ids when the request targets the
    execution engine; the cluster simulator only needs ``prompt_len``.
    """

    rid: str
    prompt_len: int
    max_new: int
    arrival: float = 0.0
    priority: int = 0
    prompt: Optional[tuple[int, ...]] = None

    def __post_init__(self):
        if self.prompt_len <= 0 or self.max_new <= 0:
            raise ValueError(f"{self.rid}: prompt_len and max_new must be "
                             "positive")
        if self.prompt is not None and len(self.prompt) != self.prompt_len:
            raise ValueError(f"{self.rid}: prompt/prompt_len mismatch")

    @property
    def total_positions(self) -> int:
        """Worst-case cache footprint (block reservation unit)."""
        return self.prompt_len + self.max_new


@dataclasses.dataclass
class RequestState:
    """Mutable per-request serving state (engine and simulator)."""

    req: Request
    slot: int
    admit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    generated: list = dataclasses.field(default_factory=list)

    @property
    def pos(self) -> int:
        """Next cache position to write = prompt + tokens generated."""
        return self.req.prompt_len + len(self.generated)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.req.max_new


class RequestQueue:
    """Deterministic admission queue (fcfs | priority)."""

    def __init__(self, policy: str = "fcfs") -> None:
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.policy = policy
        self._heap: list = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, req: Request) -> None:
        key = (req.priority, req.arrival, self._seq) \
            if self.policy == "priority" else (req.arrival, self._seq)
        heapq.heappush(self._heap, (key, self._seq, req))
        self._seq += 1

    def peek(self) -> Optional[Request]:
        return self._heap[0][2] if self._heap else None

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]


class Scheduler:
    """Slot + block admission control for continuous batching.

    Owns the free-slot set and consults the cache's
    :class:`~repro.serve.kvcache.BlockAllocator` before seating a request.
    Head-of-line semantics: admission stops at the first request that does
    not fit, preserving the policy order (no starvation by smaller
    latecomers).
    """

    def __init__(self, slots: int, kv, policy: str = "fcfs") -> None:
        self.slots = slots
        self.kv = kv                       # PagedKVCache (or stand-in)
        self.queue = RequestQueue(policy)
        self.active: dict[int, RequestState] = {}
        self._free_slots = list(range(slots - 1, -1, -1))   # pop -> lowest

    # ------------------------------------------------------------------ #
    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def has_work(self) -> bool:
        return bool(self.active) or len(self.queue) > 0

    def submit(self, req: Request) -> None:
        self.queue.push(req)

    def admit(self, now: float = 0.0) -> list[RequestState]:
        """Seat queued requests (policy order) while a slot and blocks are
        available; returns the newly admitted states."""
        admitted = []
        while self._free_slots:
            req = self.queue.peek()
            if req is None or req.arrival > now:
                break
            if not self.kv.can_admit(req.total_positions):
                break                      # head-of-line blocks the rest
            self.queue.pop()
            slot = self._free_slots.pop()
            self.kv.admit(req.rid, req.total_positions)
            st = RequestState(req=req, slot=slot, admit_time=now)
            self.active[slot] = st
            admitted.append(st)
        return admitted

    def finish(self, slot: int, now: float = 0.0) -> RequestState:
        """Evict a completed request: release its blocks, free the slot."""
        st = self.active.pop(slot)
        st.finish_time = now
        self.kv.release(st.req.rid)
        self._free_slots.append(slot)
        self._free_slots.sort(reverse=True)
        return st

    def next_arrival(self) -> Optional[float]:
        req = self.queue.peek()
        return None if req is None else req.arrival
