"""Request-level cluster simulator: fleets of serving instances on a
shared NoC cost model.

Answers the capacity question ("how many 8x8 meshes serve this traffic at
p99 X ms?") by replaying a seeded workload through N simulated instances.
Each instance reuses the engine's *actual* admission machinery — a
:class:`~repro.serve.batching.Scheduler` over a block-accounting stand-in
with the same free-list arithmetic as the paged KV cache — and advances in
continuous-batching iterations whose latencies come from a
:class:`~repro.serve.costs.PlanCostModel` (per-phase ExecutionPlans, NoC
psum cycles) or a synthetic model in tests.

Iteration semantics mirror :class:`~repro.serve.engine.ServingEngine`
exactly: an iteration admits, chunk-prefills the admissions (first token),
then runs one decode step over every slot still needing tokens.  The event
loop is a plain heap with an insertion-order tiebreak, all arithmetic is
python floats, and no wall-clock enters any record — same seed, same
bytes.

Degradation (DESIGN.md S15): a seeded replica-failure trace
(:func:`replica_failure_trace`, or explicit ``(t, instance, kind)``
events) takes instances down and up mid-run.  Going down evicts the
instance's in-flight requests — their progress is lost, and each re-enters
the cluster after a capped exponential backoff, keeping its *original*
arrival so e2e/TTFT absorb every retry — and re-dispatches its queued
(never-started) requests immediately.  A request evicted more than
``max_retries`` times fails; completed/submitted is the run's goodput.
In-flight iteration completions from before the failure are dropped by an
epoch counter.  An empty trace leaves every code path and record
byte-identical to the fault-free simulator.
"""
from __future__ import annotations

import heapq
import math
import random

from repro.serve.batching import Request, Scheduler
from repro.serve.kvcache import BlockAllocator
from repro.serve.metrics import summarize


class SimKV:
    """Block accounting only — the scheduler-facing surface of
    :class:`~repro.serve.kvcache.PagedKVCache` without the pools."""

    def __init__(self, block_size: int, num_blocks: int) -> None:
        self.block_size = block_size
        self.allocator = BlockAllocator(num_blocks)

    def blocks_for(self, positions: int) -> int:
        return math.ceil(positions / self.block_size)

    def can_admit(self, positions: int) -> bool:
        return self.allocator.can_alloc(self.blocks_for(positions))

    def admit(self, rid, positions: int) -> None:
        self.allocator.alloc(rid, self.blocks_for(positions))

    def release(self, rid) -> int:
        return self.allocator.free(rid)


class _Instance:
    def __init__(self, idx: int, slots: int, block_size: int,
                 num_blocks: int, policy: str) -> None:
        self.idx = idx
        self.kv = SimKV(block_size, num_blocks)
        self.sched = Scheduler(slots, self.kv, policy)
        self.busy = False
        self.down = False          # replica failed (dispatch skips it)
        self.epoch = 0             # bumped per failure; stale iters drop
        self.work = 0              # outstanding work units (dispatch key)
        self.iterations = 0
        self._grants: list = []    # (slot, tokens, is_first) for this iter


def replica_failure_trace(fleet: int, horizon_s: float, *,
                          mtbf_s: float, mttr_s: float,
                          seed: int = 0) -> list[tuple]:
    """Seeded alternating down/up events, ``(t, instance, kind)`` sorted.

    Per instance, time-to-failure and time-to-repair are exponential draws
    (``mtbf_s`` / ``mttr_s`` means) from one ``random.Random(seed)``
    stream in fixed instance order — the trace is a pure function of its
    arguments.  Events past ``horizon_s`` are dropped; an instance down at
    the horizon simply stays down."""
    rng = random.Random(seed)
    events: list[tuple] = []
    for idx in range(fleet):
        t = rng.expovariate(1.0 / mtbf_s)
        while t < horizon_s:
            events.append((round(t, 9), idx, "down"))
            t += rng.expovariate(1.0 / mttr_s)
            if t >= horizon_s:
                break
            events.append((round(t, 9), idx, "up"))
            t += rng.expovariate(1.0 / mtbf_s)
    events.sort()
    return events


class ClusterSimulator:
    def __init__(self, fleet: int, *, slots: int = 8, block_size: int = 16,
                 num_blocks: int | None = None, max_seq: int = 1024,
                 prefill_chunk: int = 64, cost=None, policy: str = "fcfs",
                 failures: "list[tuple] | None" = None,
                 max_retries: int = 3, retry_backoff_s: float = 0.5,
                 retry_backoff_cap_s: float = 8.0) -> None:
        if fleet <= 0:
            raise ValueError("fleet must be positive")
        if cost is None:
            raise ValueError("ClusterSimulator needs a cost model "
                             "(PlanCostModel or SyntheticCostModel)")
        if num_blocks is None:
            num_blocks = slots * math.ceil(max_seq / block_size)
        self.cost = cost
        self.prefill_chunk = prefill_chunk
        self.instances = [_Instance(i, slots, block_size, num_blocks, policy)
                          for i in range(fleet)]
        self.failures = list(failures or ())
        for t, idx, kind in self.failures:
            if kind not in ("down", "up") or not 0 <= idx < fleet:
                raise ValueError(f"bad failure event {(t, idx, kind)!r}")
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_cap_s = retry_backoff_cap_s
        self.records: list[dict] = []
        self.events = 0
        self.retries = 0
        self.downtime_events = 0
        self.failed_requests: list = []
        self._attempts: dict = {}       # rid -> eviction count
        self._pending: list = []        # arrivals while every replica down

    # ------------------------------------------------------------------ #
    def _work_units(self, req: Request) -> int:
        return req.max_new + math.ceil(req.prompt_len / self.prefill_chunk)

    def _dispatch(self, req: Request) -> "_Instance | None":
        """Least-outstanding-work *up* instance, lowest index on ties;
        ``None`` when the whole fleet is down (caller parks the request
        until the next ``up`` event)."""
        up = [inst for inst in self.instances if not inst.down]
        if not up:
            return None
        return min(up, key=lambda inst: (inst.work, inst.idx))

    def _start_iteration(self, inst: _Instance, t: float, push) -> None:
        admitted = inst.sched.admit(now=t)
        active = inst.sched.active
        if not active:
            if len(inst.sched.queue):
                head = inst.sched.queue.peek()
                raise RuntimeError(
                    f"request {head.rid!r} can never be admitted on "
                    f"instance {inst.idx} (prompt+max_new "
                    f"{head.total_positions} exceeds capacity)")
            inst.busy = False
            return
        admitted_slots = {st.slot for st in admitted}
        dt = sum(math.ceil(st.req.prompt_len / self.prefill_chunk)
                 * self.cost.prefill_chunk_seconds() for st in admitted)
        grants = []
        participants = 0
        for slot, st in active.items():
            gained = 0
            if slot in admitted_slots:
                gained += 1                       # prefill emits token #1
            if len(st.generated) + gained < st.req.max_new \
                    or slot not in admitted_slots:
                gained += 1                       # decode step token
                participants += 1
            grants.append((slot, gained, slot in admitted_slots))
        if participants:
            dt += self.cost.decode_iter_seconds(participants)
        inst._grants = grants
        inst.busy = True
        inst.iterations += 1
        push(t + dt, "iter", (inst, inst.epoch))

    def _end_iteration(self, inst: _Instance, t: float, push) -> None:
        for slot, gained, is_first in inst._grants:
            st = inst.sched.active[slot]
            if is_first:
                st.first_token_time = t
            st.generated.extend([0] * min(
                gained, st.req.max_new - len(st.generated)))
        for slot in sorted(inst.sched.active):
            st = inst.sched.active[slot]
            if not st.done:
                continue
            inst.sched.finish(slot, now=t)
            inst.work -= self._work_units(st.req)
            self.records.append({
                "rid": st.req.rid, "instance": inst.idx,
                "arrival": st.req.arrival, "admit": st.admit_time,
                "first_token": st.first_token_time, "finish": t,
                "prompt_len": st.req.prompt_len,
                "max_new": st.req.max_new,
            })
        self._start_iteration(inst, t, push)

    def _fail_instance(self, inst: _Instance, t: float, push) -> None:
        """Take a replica down: in-flight requests lose their progress and
        retry with capped exponential backoff (or fail past the retry
        budget); queued-but-unstarted requests re-dispatch at once."""
        if inst.down:
            return
        inst.down = True
        inst.epoch += 1          # any in-flight iter completion is stale
        inst.busy = False
        inst._grants = []
        self.downtime_events += 1
        for slot in sorted(inst.sched.active):
            st = inst.sched.finish(slot, now=t)
            req = st.req
            k = self._attempts[req.rid] = self._attempts.get(req.rid, 0) + 1
            if k > self.max_retries:
                self.failed_requests.append(req.rid)
                continue
            self.retries += 1
            backoff = min(self.retry_backoff_cap_s,
                          self.retry_backoff_s * 2 ** (k - 1))
            push(t + backoff, "arrival", req)
        while len(inst.sched.queue):
            push(t, "arrival", inst.sched.queue.pop())
        inst.work = 0

    # ------------------------------------------------------------------ #
    def run(self, requests: list[Request],
            max_events: int = 5_000_000) -> dict:
        heap: list = []
        seq = 0

        def push(t: float, kind: str, payload) -> None:
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, payload))
            seq += 1

        for ev in sorted(self.failures):
            push(ev[0], ev[2], ev[1])
        for req in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            push(req.arrival, "arrival", req)

        while heap:
            if self.events >= max_events:
                raise RuntimeError(f"simulation exceeded {max_events} events")
            t, _, kind, payload = heapq.heappop(heap)
            self.events += 1
            if kind == "arrival":
                inst = self._dispatch(payload)
                if inst is None:
                    self._pending.append(payload)
                    continue
                inst.work += self._work_units(payload)
                inst.sched.submit(payload)
                if not inst.busy:
                    self._start_iteration(inst, t, push)
            elif kind == "iter":
                inst, epoch = payload
                if epoch != inst.epoch:
                    continue         # completed on a replica that failed
                self._end_iteration(inst, t, push)
            elif kind == "down":
                self._fail_instance(self.instances[payload], t, push)
            else:                    # "up"
                self.instances[payload].down = False
                parked, self._pending = self._pending, []
                for req in parked:
                    push(t, "arrival", req)

        metrics = summarize(self.records)
        metrics["fleet"] = len(self.instances)
        metrics["iterations"] = sum(i.iterations for i in self.instances)
        metrics["events"] = self.events
        metrics["per_instance_requests"] = [
            sum(1 for r in self.records if r["instance"] == i.idx)
            for i in self.instances]
        metrics["goodput"] = len(self.records) / max(1, len(requests))
        metrics["retries"] = self.retries
        metrics["failed_requests"] = len(self.failed_requests)
        metrics["downtime_events"] = self.downtime_events
        return metrics


def search_fleet(requests: list[Request], slo_s: float,
                 metric: str = "e2e_s", max_fleet: int = 16,
                 cost_by_chips: "dict[int, object] | None" = None,
                 **sim_kwargs) -> dict:
    """Smallest fleet whose p99 ``metric`` meets ``slo_s``.

    Returns ``{"fleet": n | None, "slo_s", "metric", "searched": [...]}``
    where ``searched`` records every fleet size tried with its p99 —
    capacity is monotone in fleet size for this workload model, so the
    first size that meets the SLO is the answer.

    ``cost_by_chips`` (DESIGN.md S14) maps chips-per-replica to a cost
    model (e.g. multi-chip :class:`~repro.serve.costs.PlanCostModel`s) and
    turns the search two-dimensional: every chip option runs its own fleet
    sweep, ``searched`` rows gain ``chips_per_replica``/``total_chips``,
    and the answer minimizes **total chips** (replicas x chips each; fewer
    chips per replica breaks ties — bigger replicas must earn their
    silicon).  The flat call (``cost_by_chips=None``) is byte-identical to
    the pre-hierarchy behaviour.
    """
    if cost_by_chips is not None:
        searched: list[dict] = []
        best = None                       # (total_chips, chips, answer)
        for chips in sorted(cost_by_chips):
            kwargs = dict(sim_kwargs, cost=cost_by_chips[chips])
            ans = search_fleet(requests, slo_s, metric=metric,
                               max_fleet=max_fleet, **kwargs)
            for row in ans["searched"]:
                row["chips_per_replica"] = chips
                row["total_chips"] = chips * row["fleet"]
            searched.extend(ans["searched"])
            if ans["fleet"] is not None:
                key = (chips * ans["fleet"], chips)
                if best is None or key < best[0]:
                    best = (key, chips, ans)
        if best is None:
            return {"fleet": None, "chips_per_replica": None,
                    "total_chips": None, "slo_s": slo_s, "metric": metric,
                    "searched": searched, "metrics": None}
        _, chips, ans = best
        return {"fleet": ans["fleet"], "chips_per_replica": chips,
                "total_chips": chips * ans["fleet"], "slo_s": slo_s,
                "metric": metric, "searched": searched,
                "metrics": ans["metrics"]}

    searched = []
    chosen = None
    chosen_metrics = None
    for n in range(1, max_fleet + 1):
        sim = ClusterSimulator(n, **sim_kwargs)
        metrics = sim.run(requests)
        p99 = metrics[metric]["p99"]
        searched.append({"fleet": n, "p99_s": p99,
                         "throughput_rps": metrics["throughput_rps"]})
        if p99 <= slo_s:
            chosen, chosen_metrics = n, metrics
            break
    return {"fleet": chosen, "slo_s": slo_s, "metric": metric,
            "searched": searched, "metrics": chosen_metrics}
