"""Request-level cluster simulator: fleets of serving instances on a
shared NoC cost model.

Answers the capacity question ("how many 8x8 meshes serve this traffic at
p99 X ms?") by replaying a seeded workload through N simulated instances.
Each instance reuses the engine's *actual* admission machinery — a
:class:`~repro.serve.batching.Scheduler` over a block-accounting stand-in
with the same free-list arithmetic as the paged KV cache — and advances in
continuous-batching iterations whose latencies come from a
:class:`~repro.serve.costs.PlanCostModel` (per-phase ExecutionPlans, NoC
psum cycles) or a synthetic model in tests.

Iteration semantics mirror :class:`~repro.serve.engine.ServingEngine`
exactly: an iteration admits, chunk-prefills the admissions (first token),
then runs one decode step over every slot still needing tokens.  The event
loop is a plain heap with an insertion-order tiebreak, all arithmetic is
python floats, and no wall-clock enters any record — same seed, same
bytes.
"""
from __future__ import annotations

import heapq
import math

from repro.serve.batching import Request, Scheduler
from repro.serve.kvcache import BlockAllocator
from repro.serve.metrics import summarize


class SimKV:
    """Block accounting only — the scheduler-facing surface of
    :class:`~repro.serve.kvcache.PagedKVCache` without the pools."""

    def __init__(self, block_size: int, num_blocks: int) -> None:
        self.block_size = block_size
        self.allocator = BlockAllocator(num_blocks)

    def blocks_for(self, positions: int) -> int:
        return math.ceil(positions / self.block_size)

    def can_admit(self, positions: int) -> bool:
        return self.allocator.can_alloc(self.blocks_for(positions))

    def admit(self, rid, positions: int) -> None:
        self.allocator.alloc(rid, self.blocks_for(positions))

    def release(self, rid) -> int:
        return self.allocator.free(rid)


class _Instance:
    def __init__(self, idx: int, slots: int, block_size: int,
                 num_blocks: int, policy: str) -> None:
        self.idx = idx
        self.kv = SimKV(block_size, num_blocks)
        self.sched = Scheduler(slots, self.kv, policy)
        self.busy = False
        self.work = 0              # outstanding work units (dispatch key)
        self.iterations = 0
        self._grants: list = []    # (slot, tokens, is_first) for this iter


class ClusterSimulator:
    def __init__(self, fleet: int, *, slots: int = 8, block_size: int = 16,
                 num_blocks: int | None = None, max_seq: int = 1024,
                 prefill_chunk: int = 64, cost=None, policy: str = "fcfs",
                 ) -> None:
        if fleet <= 0:
            raise ValueError("fleet must be positive")
        if cost is None:
            raise ValueError("ClusterSimulator needs a cost model "
                             "(PlanCostModel or SyntheticCostModel)")
        if num_blocks is None:
            num_blocks = slots * math.ceil(max_seq / block_size)
        self.cost = cost
        self.prefill_chunk = prefill_chunk
        self.instances = [_Instance(i, slots, block_size, num_blocks, policy)
                          for i in range(fleet)]
        self.records: list[dict] = []
        self.events = 0

    # ------------------------------------------------------------------ #
    def _work_units(self, req: Request) -> int:
        return req.max_new + math.ceil(req.prompt_len / self.prefill_chunk)

    def _dispatch(self, req: Request) -> _Instance:
        """Least-outstanding-work instance, lowest index on ties."""
        return min(self.instances, key=lambda inst: (inst.work, inst.idx))

    def _start_iteration(self, inst: _Instance, t: float, push) -> None:
        admitted = inst.sched.admit(now=t)
        active = inst.sched.active
        if not active:
            if len(inst.sched.queue):
                head = inst.sched.queue.peek()
                raise RuntimeError(
                    f"request {head.rid!r} can never be admitted on "
                    f"instance {inst.idx} (prompt+max_new "
                    f"{head.total_positions} exceeds capacity)")
            inst.busy = False
            return
        admitted_slots = {st.slot for st in admitted}
        dt = sum(math.ceil(st.req.prompt_len / self.prefill_chunk)
                 * self.cost.prefill_chunk_seconds() for st in admitted)
        grants = []
        participants = 0
        for slot, st in active.items():
            gained = 0
            if slot in admitted_slots:
                gained += 1                       # prefill emits token #1
            if len(st.generated) + gained < st.req.max_new \
                    or slot not in admitted_slots:
                gained += 1                       # decode step token
                participants += 1
            grants.append((slot, gained, slot in admitted_slots))
        if participants:
            dt += self.cost.decode_iter_seconds(participants)
        inst._grants = grants
        inst.busy = True
        inst.iterations += 1
        push(t + dt, "iter", inst)

    def _end_iteration(self, inst: _Instance, t: float, push) -> None:
        for slot, gained, is_first in inst._grants:
            st = inst.sched.active[slot]
            if is_first:
                st.first_token_time = t
            st.generated.extend([0] * min(
                gained, st.req.max_new - len(st.generated)))
        for slot in sorted(inst.sched.active):
            st = inst.sched.active[slot]
            if not st.done:
                continue
            inst.sched.finish(slot, now=t)
            inst.work -= self._work_units(st.req)
            self.records.append({
                "rid": st.req.rid, "instance": inst.idx,
                "arrival": st.req.arrival, "admit": st.admit_time,
                "first_token": st.first_token_time, "finish": t,
                "prompt_len": st.req.prompt_len,
                "max_new": st.req.max_new,
            })
        self._start_iteration(inst, t, push)

    # ------------------------------------------------------------------ #
    def run(self, requests: list[Request],
            max_events: int = 5_000_000) -> dict:
        heap: list = []
        seq = 0

        def push(t: float, kind: str, payload) -> None:
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, payload))
            seq += 1

        for req in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            push(req.arrival, "arrival", req)

        while heap:
            if self.events >= max_events:
                raise RuntimeError(f"simulation exceeded {max_events} events")
            t, _, kind, payload = heapq.heappop(heap)
            self.events += 1
            if kind == "arrival":
                inst = self._dispatch(payload)
                inst.work += self._work_units(payload)
                inst.sched.submit(payload)
                if not inst.busy:
                    self._start_iteration(inst, t, push)
            else:
                self._end_iteration(payload, t, push)

        metrics = summarize(self.records)
        metrics["fleet"] = len(self.instances)
        metrics["iterations"] = sum(i.iterations for i in self.instances)
        metrics["events"] = self.events
        metrics["per_instance_requests"] = [
            sum(1 for r in self.records if r["instance"] == i.idx)
            for i in self.instances]
        return metrics


def search_fleet(requests: list[Request], slo_s: float,
                 metric: str = "e2e_s", max_fleet: int = 16,
                 cost_by_chips: "dict[int, object] | None" = None,
                 **sim_kwargs) -> dict:
    """Smallest fleet whose p99 ``metric`` meets ``slo_s``.

    Returns ``{"fleet": n | None, "slo_s", "metric", "searched": [...]}``
    where ``searched`` records every fleet size tried with its p99 —
    capacity is monotone in fleet size for this workload model, so the
    first size that meets the SLO is the answer.

    ``cost_by_chips`` (DESIGN.md S14) maps chips-per-replica to a cost
    model (e.g. multi-chip :class:`~repro.serve.costs.PlanCostModel`s) and
    turns the search two-dimensional: every chip option runs its own fleet
    sweep, ``searched`` rows gain ``chips_per_replica``/``total_chips``,
    and the answer minimizes **total chips** (replicas x chips each; fewer
    chips per replica breaks ties — bigger replicas must earn their
    silicon).  The flat call (``cost_by_chips=None``) is byte-identical to
    the pre-hierarchy behaviour.
    """
    if cost_by_chips is not None:
        searched: list[dict] = []
        best = None                       # (total_chips, chips, answer)
        for chips in sorted(cost_by_chips):
            kwargs = dict(sim_kwargs, cost=cost_by_chips[chips])
            ans = search_fleet(requests, slo_s, metric=metric,
                               max_fleet=max_fleet, **kwargs)
            for row in ans["searched"]:
                row["chips_per_replica"] = chips
                row["total_chips"] = chips * row["fleet"]
            searched.extend(ans["searched"])
            if ans["fleet"] is not None:
                key = (chips * ans["fleet"], chips)
                if best is None or key < best[0]:
                    best = (key, chips, ans)
        if best is None:
            return {"fleet": None, "chips_per_replica": None,
                    "total_chips": None, "slo_s": slo_s, "metric": metric,
                    "searched": searched, "metrics": None}
        _, chips, ans = best
        return {"fleet": ans["fleet"], "chips_per_replica": chips,
                "total_chips": chips * ans["fleet"], "slo_s": slo_s,
                "metric": metric, "searched": searched,
                "metrics": ans["metrics"]}

    searched = []
    chosen = None
    chosen_metrics = None
    for n in range(1, max_fleet + 1):
        sim = ClusterSimulator(n, **sim_kwargs)
        metrics = sim.run(requests)
        p99 = metrics[metric]["p99"]
        searched.append({"fleet": n, "p99_s": p99,
                         "throughput_rps": metrics["throughput_rps"]})
        if p99 <= slo_s:
            chosen, chosen_metrics = n, metrics
            break
    return {"fleet": chosen, "slo_s": slo_s, "metric": metric,
            "searched": searched, "metrics": chosen_metrics}
