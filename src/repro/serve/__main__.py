"""Capacity-planning CLI: serve a seeded workload, answer fleet sizing.

  PYTHONPATH=src python -m repro.serve --arch qwen2-1.5b --qps 50 \\
      --requests 200 --slo-p99-ms 200 --search-fleet

Three stages, one deterministic JSON artifact:

1. **Plans** — per-phase ExecutionPlans (prefill + decode) through the
   persistent PlanStore; a warm store answers with 0 collective engine
   runs (recorded in the JSON as the warm-plan evidence).
2. **Engine demo** — a reduced-config :class:`~repro.serve.ServingEngine`
   executes a few requests end-to-end (continuous batching, paged KV,
   paged==monolithic checks); its token ids land in the JSON, its wall
   time only on stdout.
3. **Cluster sim** — the full workload through N simulated instances with
   plan-derived iteration latencies; TTFT/TPOT/p50/p95/p99, throughput,
   queueing, Little's-law check, and (with ``--search-fleet``) the
   smallest fleet meeting the SLO.

The JSON contains no wall-clock and is written with sorted keys: identical
seed and flags give byte-identical output (CI diffs two runs).
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

from repro.configs import ARCHS
from repro.exec.timing import Stopwatch

_ENGINE_EXCLUDED = ("encdec", "vlm")


def parse_mesh(spec: str):
    d, m = spec.lower().split("x")
    return (("data", int(d)), ("model", int(m)))


def build_parser() -> argparse.ArgumentParser:
    from repro.plan import add_plan_cli_args
    from repro.serve.batching import POLICIES
    from repro.serve.costs import SEMANTICS

    ap = argparse.ArgumentParser(
        prog="repro.serve",
        description="serving capacity planner (engine + cluster simulator)")
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--seed", type=int, default=0)
    # workload
    ap.add_argument("--qps", type=float, default=50.0,
                    help="Poisson arrival rate (<=0: all at t=0)")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--prompt-dist", default="lognormal:128:0.5:512")
    ap.add_argument("--gen-dist", default="uniform:32:128")
    ap.add_argument("--trace", default=None, metavar="JSON",
                    help="replay a recorded trace instead of sampling")
    # instance geometry
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=1024)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None)
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--policy", default="fcfs", choices=POLICIES)
    # cost model
    ap.add_argument("--mesh", default="8x8",
                    help="per-instance mesh DxM for the phase plans")
    ap.add_argument("--semantics", default="ina", choices=SEMANTICS,
                    help="collective semantics priced by the cost model")
    ap.add_argument("--chips", type=int, default=1,
                    help="chips per replica; with --search-fleet every "
                         "power of two up to this joins the trade-off "
                         "(replica count vs chips each, DESIGN.md S14)")
    ap.add_argument("--package", default="mesh",
                    choices=("mesh", "express"),
                    help="cross-chip package fabric for --chips > 1")
    ap.add_argument("--clock-ghz", type=float, default=1.0)
    ap.add_argument("--calibration", type=float, default=1.0,
                    help="measured-seconds-per-modeled-second scale")
    add_plan_cli_args(ap)
    # fleet question
    ap.add_argument("--fleet", type=int, default=1)
    ap.add_argument("--search-fleet", action="store_true")
    ap.add_argument("--max-fleet", type=int, default=16)
    ap.add_argument("--slo-p99-ms", type=float, default=200.0)
    ap.add_argument("--slo-metric", default="e2e_s",
                    choices=("e2e_s", "ttft_s", "queueing_s"))
    # engine demo
    ap.add_argument("--no-execute", action="store_true",
                    help="skip the reduced-config engine execution")
    ap.add_argument("--execute-requests", type=int, default=6)
    ap.add_argument("--out", default=None, metavar="JSON")
    return ap


def run_engine_demo(cfg, seed: int, n: int) -> dict:
    """Execute ``n`` small requests on the reduced config: functional
    evidence (deterministic token ids + paged==monolithic checks)."""
    from repro.serve.engine import ServingEngine
    from repro.serve.traffic import make_workload

    rc = cfg.reduced()
    reqs = make_workload(n, qps=0.0, prompt_dist="uniform:4:12",
                         gen_dist="uniform:2:6", seed=seed,
                         vocab=rc.vocab, prefix="e")
    eng = ServingEngine(rc, slots=2, max_seq=rc.max_seq, block_size=8,
                        prefill_chunk=4, check=True)
    watch = Stopwatch()
    report = eng.run(reqs)
    wall = watch.seconds
    print(f"[serve] engine demo: {len(reqs)} requests, "
          f"{report.iterations} iterations, {report.decode_steps} decode "
          f"steps, {report.prefill_chunks} prefill chunks, "
          f"{report.checks} paged==monolithic checks in {wall:.1f}s")
    return {
        "arch_reduced": rc.name, "requests": len(reqs),
        "slots": 2, "block_size": 8, "prefill_chunk": 4,
        "iterations": report.iterations,
        "decode_steps": report.decode_steps,
        "prefill_chunks": report.prefill_chunks,
        "paged_monolithic_checks": report.checks,
        "tokens": report.tokens(),
    }


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    cfg = ARCHS[args.arch]
    mesh_shape = parse_mesh(args.mesh)

    # -- per-phase plans + cost model ---------------------------------- #
    # chip options: powers of two up to --chips (1 always included); the
    # single-run path prices exactly --chips, --search-fleet trades them.
    chip_options = [1]
    while chip_options[-1] * 2 <= max(1, args.chips):
        chip_options.append(chip_options[-1] * 2)
    if args.chips not in chip_options:
        chip_options.append(args.chips)
    doc_plan = None
    cost_by_chips = None
    if args.no_plan:
        from repro.serve.costs import SyntheticCostModel
        cost = SyntheticCostModel()
        print("[serve] --no-plan: synthetic cost model")
    else:
        from repro.serve.costs import PlanCostModel, serve_plans
        doc_plan = {}
        cost_by_chips = {}
        want = chip_options if args.search_fleet else [args.chips]
        for chips in want:
            plans = serve_plans(cfg, mesh_shape, plan_dir=args.plan_dir,
                                chips=chips, package=args.package)
            cost_by_chips[chips] = PlanCostModel.from_plans(
                cfg, plans["prefill"][0], plans["decode"][0],
                prefill_chunk=args.prefill_chunk, semantics=args.semantics,
                clock_ghz=args.clock_ghz, calibration=args.calibration)
            for phase, (_, info) in plans.items():
                doc_plan[f"{phase}__c{chips}" if chips > 1 else phase] = {
                    "key": info["key"], "from_store": info["from_store"],
                    "collective_sims": info["collective_sims"],
                    "modes": info["psum"]["modes"]}
        cost = cost_by_chips[args.chips if not args.search_fleet
                             else chip_options[0]]
        total_sims = sum(p["collective_sims"] for p in doc_plan.values())
        print(f"[serve] per-phase plans ready "
              f"(collective sims this launch: {total_sims})")

    # -- workload ------------------------------------------------------ #
    from repro.serve.traffic import load_trace, make_workload
    if args.trace:
        requests = load_trace(args.trace)
    else:
        requests = make_workload(args.requests, args.qps, args.prompt_dist,
                                 args.gen_dist, args.seed)
    too_big = [r for r in requests if r.total_positions > args.max_seq]
    if too_big:
        raise SystemExit(f"{len(too_big)} requests exceed --max-seq "
                         f"{args.max_seq} (first: {too_big[0].rid})")

    # -- engine demo --------------------------------------------------- #
    doc_engine = None
    if not args.no_execute:
        if cfg.family in _ENGINE_EXCLUDED:
            print(f"[serve] engine demo skipped: family {cfg.family!r} "
                  "needs media plumbing")
        else:
            doc_engine = run_engine_demo(cfg, args.seed,
                                         args.execute_requests)

    # -- cluster simulation / fleet search ----------------------------- #
    sim_kwargs = dict(slots=args.slots, block_size=args.block_size,
                      num_blocks=args.num_blocks, max_seq=args.max_seq,
                      prefill_chunk=args.prefill_chunk, cost=cost,
                      policy=args.policy)
    slo_s = args.slo_p99_ms / 1e3
    watch = Stopwatch()
    if args.search_fleet:
        from repro.serve.cluster import search_fleet
        multi = cost_by_chips if cost_by_chips and len(cost_by_chips) > 1 \
            else None
        if multi is not None:
            sim_kwargs.pop("cost")
        answer = search_fleet(requests, slo_s, metric=args.slo_metric,
                              max_fleet=args.max_fleet,
                              cost_by_chips=multi, **sim_kwargs)
        metrics = answer["metrics"] or {}
        doc_fleet = answer
        fleet_str = answer["fleet"] if answer["fleet"] is not None \
            else f">{args.max_fleet}"
        if multi is not None and answer["fleet"] is not None:
            fleet_str = (f"{answer['fleet']} x "
                         f"{answer['chips_per_replica']}-chip "
                         f"({answer['total_chips']} chips total)")
        print(f"[serve] fleet answer: {fleet_str} instance(s) for p99 "
              f"{args.slo_metric} <= {args.slo_p99_ms} ms "
              f"({len(answer['searched'])} sizes simulated, "
              f"{watch.seconds:.1f}s)")
    else:
        from repro.serve.cluster import ClusterSimulator
        metrics = ClusterSimulator(args.fleet, **sim_kwargs).run(requests)
        met = metrics[args.slo_metric]["p99"]
        doc_fleet = {"fleet": args.fleet, "slo_s": slo_s,
                     "metric": args.slo_metric, "searched": [],
                     "metrics": metrics, "slo_met": bool(met <= slo_s)}
        print(f"[serve] fleet {args.fleet}: p99 {args.slo_metric} "
              f"{met*1e3:.2f} ms (SLO {args.slo_p99_ms} ms) "
              f"in {watch.seconds:.1f}s")
    if metrics:
        print(f"[serve] throughput {metrics['throughput_rps']:.2f} req/s "
              f"{metrics['throughput_tok_s']:.1f} tok/s | "
              f"ttft p99 {metrics['ttft_s']['p99']*1e3:.2f} ms | "
              f"tpot p99 {metrics['tpot_s']['p99']*1e3:.2f} ms | "
              f"little's-law ratio {metrics['littles_law_ratio']:.4f}")

    # -- deterministic artifact ---------------------------------------- #
    doc = {
        "arch": args.arch, "seed": args.seed, "qps": args.qps,
        "requests": len(requests), "mesh": [list(p) for p in mesh_shape],
        "semantics": args.semantics, "clock_ghz": args.clock_ghz,
        "calibration": args.calibration,
        "chips": args.chips, "package": args.package,
        "instance": {"slots": args.slots, "max_seq": args.max_seq,
                     "block_size": args.block_size,
                     "num_blocks": args.num_blocks,
                     "prefill_chunk": args.prefill_chunk,
                     "policy": args.policy},
        "plan": doc_plan,
        "engine": doc_engine,
        "fleet_answer": doc_fleet,
    }
    out = args.out or os.path.join(
        "results", "serve", f"serve_{args.arch}_seed{args.seed}.json")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    from repro.core.noc.simcache import atomic_write_text
    atomic_write_text(Path(out),
                      json.dumps(doc, indent=1, sort_keys=True) + "\n")
    print(f"[serve] wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
