"""Seeded request workloads: Poisson arrivals, length laws, trace replay.

Everything routes through one ``random.Random(seed)`` stream so a workload
is a pure function of its parameters — the foundation of the simulator's
byte-identical-metrics guarantee (same seed, same JSON).

Length specs are small strings so they can ride CLI flags and sweep
configs: ``fixed:64``, ``uniform:16:128``, ``lognormal:64:0.5:512``
(median, sigma, max).
"""
from __future__ import annotations

import json
import random

from repro.serve.batching import Request


def parse_length_dist(spec: str):
    """A ``rng -> int`` sampler from a distribution spec string."""
    parts = spec.split(":")
    kind = parts[0]
    if kind == "fixed":
        n = int(parts[1])
        return lambda rng: n
    if kind == "uniform":
        lo, hi = int(parts[1]), int(parts[2])
        if lo > hi:
            raise ValueError(f"uniform bounds reversed in {spec!r}")
        return lambda rng: rng.randint(lo, hi)
    if kind == "lognormal":
        import math
        median, sigma, cap = float(parts[1]), float(parts[2]), int(parts[3])
        mu = math.log(median)
        return lambda rng: max(1, min(cap,
                                      round(rng.lognormvariate(mu, sigma))))
    raise ValueError(f"unknown length distribution {spec!r} "
                     "(fixed:N | uniform:LO:HI | lognormal:MED:SIGMA:MAX)")


def poisson_arrivals(qps: float, n: int, rng: random.Random) -> list[float]:
    """``n`` cumulative arrival times at rate ``qps`` (exponential gaps);
    ``qps <= 0`` means everything arrives at t=0 (offline batch)."""
    if qps <= 0:
        return [0.0] * n
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(qps)
        out.append(t)
    return out


def make_workload(n: int, qps: float, prompt_dist: str, gen_dist: str,
                  seed: int, vocab: int | None = None,
                  prefix: str = "r") -> list[Request]:
    """``n`` seeded requests; with ``vocab``, prompts carry real token ids
    (engine-executable), otherwise lengths only (simulator)."""
    rng = random.Random(seed)
    prompts = parse_length_dist(prompt_dist)
    gens = parse_length_dist(gen_dist)
    arrivals = poisson_arrivals(qps, n, rng)
    out = []
    for i, t in enumerate(arrivals):
        plen = prompts(rng)
        gen = gens(rng)
        tokens = None
        if vocab is not None:
            tokens = tuple(rng.randrange(3, vocab) for _ in range(plen))
        out.append(Request(rid=f"{prefix}{i:04d}", prompt_len=plen,
                           max_new=gen, arrival=t, prompt=tokens))
    return out


def load_trace(path: str) -> list[Request]:
    """Replay a recorded trace: a JSON list of ``{"t": float,
    "prompt_len": int, "max_new": int}`` objects (optional ``"priority"``,
    ``"rid"``)."""
    with open(path) as fh:
        rows = json.load(fh)
    out = []
    for i, row in enumerate(rows):
        out.append(Request(
            rid=str(row.get("rid", f"t{i:04d}")),
            prompt_len=int(row["prompt_len"]), max_new=int(row["max_new"]),
            arrival=float(row["t"]), priority=int(row.get("priority", 0))))
    return sorted(out, key=lambda r: (r.arrival, r.rid))
