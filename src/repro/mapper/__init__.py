"""Mapper: mapping-search subsystem (layer -> mesh schedules).

The paper evaluates exactly one mapping per layer — the fixed WS placement
of Eqs. (1)-(4) on a square N x N mesh.  This subsystem treats the mapping
as a *search problem*: it enumerates candidate placements per layer
(:mod:`.space` — rectangular meshes, chain grouping, PEs/router, precision,
WS/OS dataflow, INA vs eject/inject semantics), prunes with the analytical
model, scores survivors exactly on the event-driven simulator through the
plan-keyed sim cache (:mod:`.search`), and emits a whole-network
:class:`~.schedule.NetworkSchedule` replayable on the collective program
engine (:mod:`.schedule`).

With the GEMM front-end (:mod:`repro.core.ops`) the search covers the
paper's CNNs *and* FC/transformer layers; ``python -m repro.experiments
--section mapper`` writes the paper-vs-auto Pareto report.  Design notes:
DESIGN.md S9; CLI and artifact schema: EXPERIMENTS.md.
"""
from .schedule import LayerAssignment, NetworkSchedule
from .search import SearchOutcome, evaluate_mapping, search_network
from .space import (DATAFLOWS, Mapping, MapperConfig, PAPER_MAPPING,
                    QUICK_MAPPER, SEMANTICS, analytic_latency,
                    hardware_candidates, hardware_mapping_fields,
                    layer_candidates, shard_layer)

__all__ = [
    "Mapping", "MapperConfig", "PAPER_MAPPING", "QUICK_MAPPER",
    "DATAFLOWS", "SEMANTICS",
    "LayerAssignment", "NetworkSchedule",
    "SearchOutcome", "search_network", "evaluate_mapping",
    "analytic_latency", "hardware_candidates", "hardware_mapping_fields",
    "layer_candidates", "shard_layer",
]
