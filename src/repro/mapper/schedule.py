"""Whole-network schedules: the mapper's output artifact.

A :class:`NetworkSchedule` fixes one hardware point and one per-layer
:class:`~.space.Mapping` each, with the exact simulated cost attached.  It
serializes to the JSON the experiments section writes (``mapper.json``) and
re-emits, on demand, the per-layer packet programs
(:func:`~repro.core.noc.collective.schedule.ws_round_program`) so any
schedule can be replayed on the collective program engine — the same path
``tests/test_mapper.py`` exercises.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

from repro.core.noc import NocConfig
from repro.core.noc.collective.schedule import PacketOp, ws_round_program
from repro.core.noc.traffic import LayerResult, layer_plan
from repro.core.ops import LayerShape

from .space import Mapping, shard_layer


def mapping_utilization(layer: LayerShape, mapping: Mapping,
                        base_cfg: NocConfig = NocConfig()) -> float:
    """Placement efficiency: live PE round-slots / provided PE round-slots.

    Each accumulation round offers ``W*H*E`` PE-slots; the mapping keeps
    ``W*G*P#*E`` of them on live work (idle column tails when ``H % P# !=
    0``) and rounds it runs beyond ``F * outputs * passes / (chains * E)``
    are pure ceil waste.  MAC issue time is not simulated (compute overlaps
    the NoC, paper [12]), so this measures how much of the mesh the mapping
    *can* keep busy, not a cycle-level activity factor.  Multi-chip
    mappings measure their per-chip shard (every chip runs the same
    placement on its own output rows, so the ratio is chip-invariant).
    """
    m = mapping
    cfg = m.cfg(base_cfg)
    layer = shard_layer(layer, m.chips)
    plan = layer_plan(layer, cfg, m.e_pes, m.mode, m.q_bits, m.groups)
    provided = plan.rounds * cfg.width * cfg.height * m.e_pes
    live = layer.F * layer.outputs * plan.p * plan.passes
    return min(1.0, live / max(provided, 1))


@dataclass(frozen=True)
class LayerAssignment:
    """One layer's chosen mapping plus its simulated cost."""

    layer: str
    mapping: Mapping
    rounds: int
    fills: int
    latency_cycles: float
    noc_energy_pj: float
    stream_energy_pj: float
    macs: int
    utilization: float

    @property
    def total_energy_pj(self) -> float:
        return self.noc_energy_pj + self.stream_energy_pj

    @classmethod
    def from_result(cls, layer: LayerShape, mapping: Mapping,
                    result: LayerResult,
                    base_cfg: NocConfig = NocConfig()) -> "LayerAssignment":
        return cls(layer=layer.name, mapping=mapping, rounds=result.rounds,
                   fills=result.fills, latency_cycles=result.latency_cycles,
                   noc_energy_pj=result.noc_energy_pj,
                   stream_energy_pj=result.stream_energy_pj,
                   macs=layer.macs,
                   utilization=mapping_utilization(layer, mapping, base_cfg))


@dataclass(frozen=True)
class NetworkSchedule:
    """Per-layer mappings for a whole network on one hardware point."""

    workload: str
    hardware: tuple[int, ...]      # (width, height, e_pes[, chips])
    assignments: tuple[LayerAssignment, ...]

    @property
    def latency_cycles(self) -> float:
        """Layers execute back-to-back (as in the paper's evaluation)."""
        return sum(a.latency_cycles for a in self.assignments)

    @property
    def total_energy_pj(self) -> float:
        return sum(a.total_energy_pj for a in self.assignments)

    @property
    def noc_energy_pj(self) -> float:
        return sum(a.noc_energy_pj for a in self.assignments)

    @property
    def num_pes(self) -> int:
        w, h, e = self.hardware[:3]
        chips = self.hardware[3] if len(self.hardware) > 3 else 1
        return w * h * e * chips

    @property
    def pe_utilization(self) -> float:
        """Time-weighted placement efficiency (see mapping_utilization)."""
        total = self.latency_cycles
        if total <= 0:
            return 0.0
        return sum(a.utilization * a.latency_cycles
                   for a in self.assignments) / total

    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        return {
            "workload": self.workload,
            "hardware": list(self.hardware),
            "latency_cycles": self.latency_cycles,
            "total_energy_pj": self.total_energy_pj,
            "noc_energy_pj": self.noc_energy_pj,
            "pe_utilization": self.pe_utilization,
            "layers": [{
                "layer": a.layer,
                "mapping": dataclasses.asdict(a.mapping),
                "rounds": a.rounds,
                "fills": a.fills,
                "latency_cycles": a.latency_cycles,
                "noc_energy_pj": a.noc_energy_pj,
                "stream_energy_pj": a.stream_energy_pj,
                "macs": a.macs,
                "utilization": a.utilization,
            } for a in self.assignments],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "NetworkSchedule":
        return cls(
            workload=d["workload"], hardware=tuple(d["hardware"]),
            assignments=tuple(
                LayerAssignment(
                    layer=row["layer"], mapping=Mapping(**row["mapping"]),
                    rounds=row["rounds"], fills=row["fills"],
                    latency_cycles=row["latency_cycles"],
                    noc_energy_pj=row["noc_energy_pj"],
                    stream_energy_pj=row["stream_energy_pj"],
                    macs=row["macs"], utilization=row["utilization"])
                for row in d["layers"]))

    # ------------------------------------------------------------------ #
    def programs(self, layers: Sequence[LayerShape],
                 base_cfg: NocConfig = NocConfig(),
                 window: Optional[int] = None,
                 ) -> Iterator[tuple[str, NocConfig, list[PacketOp]]]:
        """Re-emit each layer's accumulation-round packet program.

        Yields ``(layer_name, cfg, program)`` replayable via
        :func:`~repro.core.noc.collective.engine.run_program`.  ``window``
        caps the rounds emitted per layer (None = one round, the homogeneous
        unit the simulator extrapolates from).
        """
        by_name = {l.name: l for l in layers}
        for a in self.assignments:
            layer = by_name[a.layer]
            m = a.mapping
            cfg = m.cfg(base_cfg)
            # Multi-chip assignments re-emit one chip's shard program: all
            # chips run the same rounds, so one lane is the replay unit.
            layer = shard_layer(layer, m.chips)
            plan = layer_plan(layer, cfg, m.e_pes, m.mode, m.q_bits, m.groups)
            rounds = max(1, min(plan.rounds, window or 1))
            prog = ws_round_program(cfg, m.mode, rounds, g=plan.g, p=plan.p,
                                    gather_flits=plan.gather_flits,
                                    unicast_flits=plan.unicast_flits,
                                    e_pes=m.e_pes)
            yield a.layer, cfg, prog
