"""Mapping search: analytic pruning + exact scoring through the sim cache.

Per hardware point, each layer's candidate mappings are ranked by the
analytical model and only the best few reach the event-driven simulator —
whose results are memoized per plan shape in
:data:`repro.core.noc.simcache.SIM_CACHE`, so a whole-network search costs a
handful of distinct window programs rather than |layers| x |candidates| sim
runs (the PR-2 cache is what makes this subsystem affordable; see
EXPERIMENTS.md).

Selection is *baseline-dominating* constrained optimization: the reference
is the paper's fixed mapping (:data:`~.space.PAPER_MAPPING`) simulated per
layer; per layer the mapper minimizes latency subject to the layer's
baseline energy, and across hardware points it picks the lowest-latency
schedule whose network totals weakly dominate the baseline's (the baseline
hardware always qualifies when it is inside the budget, so the searched
schedule is never worse than the paper's on either axis — equality when the
paper mapping is already optimal).  Everything is deterministic: no RNG,
total sort keys, cache hits bit-identical to ground truth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from itertools import islice
from typing import Optional, Sequence

from repro.core.noc import SIM_CACHE, NocConfig
from repro.core.noc.compiled import compiled_enabled
from repro.core.noc.traffic import LayerResult, simulate_layer
from repro.core.noc.vectorized import prefetch_windows, vectorized_enabled
from repro.core.ops import LayerShape
from repro.exec import parallel_map

from .schedule import LayerAssignment, NetworkSchedule
from .space import (Mapping, MapperConfig, PAPER_MAPPING, analytic_latency,
                    hardware_candidates, hardware_mapping_fields,
                    layer_candidates, shard_layer)


@dataclass
class SearchOutcome:
    """Everything one network search produced."""

    workload: str
    baseline: NetworkSchedule            # the paper's fixed mapping, simulated
    best: NetworkSchedule                # lowest-latency baseline-dominating
    pareto: tuple[NetworkSchedule, ...]  # latency/energy front over hardware
    stats: dict = field(default_factory=dict)

    @property
    def latency_x(self) -> float:
        return self.baseline.latency_cycles / max(self.best.latency_cycles, 1.0)

    @property
    def energy_x(self) -> float:
        return self.baseline.total_energy_pj \
            / max(self.best.total_energy_pj, 1.0)


# --------------------------------------------------------------------------- #
# Layer-result memo: a LayerResult is a pure function of the layer's Eq.(1)-(4)
# shape (R, C, F, outputs) and the mapping, never of the layer identity —
# ResNet-50 repeats the same bottleneck shapes dozens of times, and every
# hardware point re-scores the baseline anchor.  Keyed off
# ``SIM_CACHE.generation`` so ``SIM_CACHE.clear()`` invalidates it too, and
# bypassed entirely when the window cache is disabled (ground-truth mode).
# --------------------------------------------------------------------------- #
_EVAL_MEMO: dict = {"gen": -1, "store": {}}

#: Ranked keep-list memo, same lifecycle: the candidate enumeration +
#: analytic ranking of one (layer shape, hardware, space) cell is pure and
#: repeats across identically-shaped layers (ResNet bottlenecks) and warm
#: re-searches, and with the vectorized window kernels it — not the
#: simulator — is the scoring loop's dominant cost.
_RANK_MEMO: dict = {"gen": -1, "store": {}}


def _memo_store(memo: dict) -> dict:
    if memo["gen"] != SIM_CACHE.generation:
        memo["gen"] = SIM_CACHE.generation
        memo["store"] = {}
    return memo["store"]


def _eval_store() -> dict:
    return _memo_store(_EVAL_MEMO)


def _rank_store() -> dict:
    return _memo_store(_RANK_MEMO)


def memo_sizes() -> tuple[int, int]:
    """(eval, rank) memo lengths — pair with :func:`memo_export`."""
    return len(_eval_store()), len(_rank_store())


def memo_export(sizes: tuple[int, int]) -> tuple[dict, dict]:
    """Entries appended since ``sizes`` (insertion-ordered tails).

    Lets a pool worker ship the layer/ranking memo growth of a whole
    search back to the parent (:func:`repro.experiments.sweeps.run_mapper`
    fans out at workload grain), mirroring what ``_score_hardware``'s
    delta does per hardware point.
    """
    ev, rk = _eval_store(), _rank_store()
    return ({k: ev[k] for k in islice(iter(ev), sizes[0], None)},
            {k: rk[k] for k in islice(iter(rk), sizes[1], None)})


def memo_merge(deltas: tuple[dict, dict]) -> None:
    """Merge :func:`memo_export` deltas (pure values; order-free)."""
    ev, rk = deltas
    _eval_store().update(ev)
    _rank_store().update(rk)


def _eval_key(layer: LayerShape, mapping: Mapping, base_cfg: NocConfig,
              sim_rounds: int) -> tuple:
    return ((layer.R, layer.C, layer.F, layer.outputs), mapping, base_cfg,
            sim_rounds)


def _evaluate_multichip(layer: LayerShape, mapping: Mapping,
                        base_cfg: NocConfig, sim_rounds: int,
                        package: str) -> LayerResult:
    """Multi-chip cost: per-chip shard sim + package broadcast surcharge.

    Every chip runs the identical shard concurrently (latency is one
    chip's; NoC/stream energy multiplies by the chip count), and each
    weight fill first broadcasts the mesh's fill payload over the package
    network (:func:`~repro.core.noc.hierarchy.chip_round_cost`, riding the
    same sim cache).  DESIGN.md S14.
    """
    from repro.core.noc.hierarchy import chip_round_cost
    from repro.core.noc.traffic import layer_plan
    flat = dataclasses.replace(mapping, chips=1)
    shard = shard_layer(layer, mapping.chips)
    r = evaluate_mapping(shard, flat, base_cfg, sim_rounds)
    cfg = mapping.cfg(base_cfg)
    plan = layer_plan(shard, cfg, mapping.e_pes, mapping.mode,
                      mapping.q_bits, mapping.groups)
    fill_bits = plan.weight_bits_per_router * cfg.width * cfg.height
    pkg_lat, pkg_en = chip_round_cost(fill_bits, mapping.chips, cfg,
                                      package=package,
                                      semantics=mapping.semantics)
    c = mapping.chips
    return dataclasses.replace(
        r, name=layer.name,
        latency_cycles=r.latency_cycles + pkg_lat * r.fills,
        noc_energy_pj=r.noc_energy_pj * c + pkg_en * r.fills,
        stream_energy_pj=r.stream_energy_pj * c)


def _evaluate_cached(layer: LayerShape, mapping: Mapping,
                     base_cfg: NocConfig, sim_rounds: int,
                     package: str) -> LayerResult:
    """Memo-backed cost, possibly named after an identically-shaped twin.

    Internal fast path: callers that never read ``result.name``
    (``_score_hardware``'s choose/assign loop) skip the per-call re-stamp
    copy.  The returned object is shared with the memo — do not mutate.
    """
    if mapping.chips > 1:
        return _evaluate_multichip(layer, mapping, base_cfg, sim_rounds,
                                   package)
    if not SIM_CACHE.enabled or not compiled_enabled():
        return simulate_layer(layer, mapping.mode, mapping.cfg(base_cfg),
                              mapping.e_pes, sim_rounds,
                              q_bits=mapping.q_bits, groups=mapping.groups)
    store = _eval_store()
    key = _eval_key(layer, mapping, base_cfg, sim_rounds)
    hit = store.get(key)
    if hit is None:
        hit = simulate_layer(layer, mapping.mode, mapping.cfg(base_cfg),
                             mapping.e_pes, sim_rounds,
                             q_bits=mapping.q_bits, groups=mapping.groups)
        store[key] = hit
    return hit


def evaluate_mapping(layer: LayerShape, mapping: Mapping,
                     base_cfg: NocConfig = NocConfig(),
                     sim_rounds: int = 16,
                     package: str = "mesh") -> LayerResult:
    """Exact (event-driven, cache-backed) cost of one mapping."""
    hit = _evaluate_cached(layer, mapping, base_cfg, sim_rounds, package)
    if hit.name == layer.name:
        return hit
    # Hand out a copy re-stamped with the caller's layer identity: the memo
    # collapses identically-shaped layers, but results name their layer.
    return dataclasses.replace(hit, name=layer.name)


def _choose(results: list[tuple[Mapping, LayerResult]],
            energy_budget: float) -> tuple[Mapping, LayerResult]:
    """Min latency subject to the baseline energy budget; energy breaks ties.

    Falls back to the unconstrained (latency, energy) minimum when nothing
    on this hardware meets the budget (a rectangular mesh can be faster but
    hotter — it then competes only through the Pareto front).
    """
    within = [(m, r) for m, r in results
              if r.total_energy_pj <= energy_budget]
    pool = within or results
    return min(pool, key=lambda mr: (mr[1].latency_cycles,
                                     mr[1].total_energy_pj,
                                     mr[0].sort_key))


def _pareto(schedules: list[NetworkSchedule]) -> list[NetworkSchedule]:
    """Non-dominated schedules over (latency, total energy), sorted."""
    ordered = sorted(schedules, key=lambda s: (s.latency_cycles,
                                               s.total_energy_pj, s.hardware))
    front: list[NetworkSchedule] = []
    best_energy = float("inf")
    for s in ordered:
        if s.total_energy_pj < best_energy:
            front.append(s)
            best_energy = s.total_energy_pj
    return front


def _window_keys(layer: LayerShape, mapping: Mapping, base_cfg: NocConfig,
                 sim_rounds: int) -> tuple:
    """SIM_CACHE window keys that scoring ``mapping`` will ask for.

    Mirrors :func:`evaluate_mapping` → ``simulate_layer`` → ``_accum_phase``
    window selection exactly (big window + optional half window; multichip
    mappings score their per-chip shard with ``chips=1``), so a batched
    prefetch over these keys leaves the scalar scoring path on warm,
    bit-identical cache hits.
    """
    from repro.core.noc.traffic import layer_plan
    if mapping.chips > 1:
        layer = shard_layer(layer, mapping.chips)
        mapping = dataclasses.replace(mapping, chips=1)
    cfg = mapping.cfg(base_cfg)
    plan = layer_plan(layer, cfg, mapping.e_pes, mapping.mode,
                      mapping.q_bits, mapping.groups)
    if plan.rounds <= 0:
        return ()
    w_big = min(plan.rounds, max(1, sim_rounds))
    keys = [(cfg, mapping.mode, w_big, plan.g, plan.p, plan.gather_flits,
             plan.unicast_flits, mapping.e_pes)]
    if plan.rounds > w_big:
        w_small = max(1, w_big // 2)
        if w_small != w_big:
            keys.append((cfg, mapping.mode, w_small, plan.g, plan.p,
                         plan.gather_flits, plan.unicast_flits,
                         mapping.e_pes))
    return tuple(keys)


def _prefetch_hardware(per_layer, base_cfg: NocConfig,
                       sim_rounds: int) -> None:
    """Batch-prefetch the window sims a hardware point is about to score.

    Collects the union of window-cache keys across every surviving
    candidate of every layer and hands them to
    :func:`repro.core.noc.vectorized.prefetch_windows` as one stacked
    array pass — amortizing the candidate-mapping axis the scalar scoring
    loop walks one key at a time (DESIGN.md S16).  Purely a cache warmer:
    scoring results are bit-identical with it disabled.
    """
    if not (vectorized_enabled() and compiled_enabled()
            and SIM_CACHE.enabled):
        return
    store = _eval_store()
    keys: list = []
    seen_shapes: set = set()
    for layer, _base_r, keep in per_layer:
        # Identically-shaped layers share keys (and eval-memo entries).
        shape = (layer.R, layer.C, layer.F, layer.outputs)
        if shape in seen_shapes:
            continue
        seen_shapes.add(shape)
        for m in keep:
            if _eval_key(layer, m, base_cfg, sim_rounds) not in store:
                keys.extend(_window_keys(layer, m, base_cfg, sim_rounds))
    if keys:
        prefetch_windows(keys)


def _score_hardware(payload) -> tuple[NetworkSchedule, int, int, dict]:
    """Score every layer on one hardware point (a pool-fanout unit).

    Returns ``(schedule, candidates, simulated, layer-memo delta)``; the
    delta ships memoized LayerResults back to the parent process so a
    warm parent keeps getting warmer across ``--jobs`` fan-outs.
    """
    workload, layers, base_results, hw, mcfg, base_cfg = payload
    memo_before = len(_eval_store())
    w, h, e, chips = hardware_mapping_fields(hw)
    # The hardware's own paper-style mapping is always scored exactly,
    # whatever the analytic ranking says — it anchors the energy-budget
    # pool (and *is* the baseline mapping on the baseline hardware).
    anchor = Mapping(w, h, e, "ws", "ina", mcfg.q_list[0], None, chips)
    n_cands = n_sim = 0
    rank_before = len(_rank_store())
    per_layer = []
    for layer, base_r in zip(layers, base_results):
        # Candidates and their analytic ranking are pure functions of the
        # layer's Eq.(1)-(4) shape (same determinants as the sim memo
        # above), so identically-shaped layers share one ranked keep list.
        rkey = ((layer.R, layer.C, layer.F, layer.outputs), hw, mcfg,
                base_cfg)
        hit = _rank_store().get(rkey)
        if hit is None:
            cands = layer_candidates(layer, hw, mcfg)
            ranked = sorted(cands, key=lambda m: (
                analytic_latency(layer, m, base_cfg), m.sort_key))
            keep = ranked[:mcfg.prune_keep]
            if anchor in cands and anchor not in keep:
                keep.append(anchor)
            hit = (tuple(keep), len(cands))
            _rank_store()[rkey] = hit
        n_cands += hit[1]
        per_layer.append((layer, base_r, hit[0]))
    _prefetch_hardware(per_layer, base_cfg, mcfg.sim_rounds)
    assignments = []
    for layer, base_r, keep in per_layer:
        results = [(m, _evaluate_cached(layer, m, base_cfg,
                                        mcfg.sim_rounds, mcfg.package))
                   for m in keep]
        n_sim += len(results)
        m, r = _choose(results, base_r.total_energy_pj)
        assignments.append(
            LayerAssignment.from_result(layer, m, r, base_cfg))
    schedule = NetworkSchedule(workload=workload, hardware=hw,
                               assignments=tuple(assignments))
    # New memo entries = everything appended past the starting length
    # (insertion-ordered dicts, never deleted from within a generation).
    store = _eval_store()
    delta = {k: store[k]
             for k in islice(iter(store), memo_before, None)}
    rstore = _rank_store()
    rank_delta = {k: rstore[k]
                  for k in islice(iter(rstore), rank_before, None)}
    return schedule, n_cands, n_sim, delta, rank_delta


def search_network(workload: str, layers: Sequence[LayerShape],
                   mcfg: MapperConfig = MapperConfig(),
                   base_cfg: NocConfig = NocConfig(),
                   baseline_mapping: Mapping = PAPER_MAPPING,
                   jobs: int = 1, debug: bool = False) -> SearchOutcome:
    """Search the mapping space for a whole network; emit the best schedule.

    Deterministic: same (layers, mcfg, base_cfg) -> identical outcome,
    whatever ``jobs`` is — hardware points are scored across a process
    pool (:mod:`repro.exec.pool`) and merged back in candidate order, and
    every scored cost is a pure function of the plan shape.

    ``debug=True`` statically verifies the winning schedule's re-emitted
    packet programs (``repro.analysis.verify_schedule``: routes, DAG, CDG
    deadlock freedom) and raises ``VerificationError`` on any finding
    before the outcome escapes.
    """
    cache_before = SIM_CACHE.stats()
    stats = {"candidates": 0, "simulated": 0, "hardware_evaluated": 0}

    base_results = [evaluate_mapping(l, baseline_mapping, base_cfg,
                                     mcfg.sim_rounds, mcfg.package)
                    for l in layers]
    stats["simulated"] += len(base_results)
    baseline = NetworkSchedule(
        workload=workload, hardware=baseline_mapping.hardware,
        assignments=tuple(
            LayerAssignment.from_result(l, baseline_mapping, r, base_cfg)
            for l, r in zip(layers, base_results)))

    hws = hardware_candidates(mcfg)
    layers = tuple(layers)
    scored = parallel_map(
        _score_hardware,
        [(workload, layers, base_results, hw, mcfg, base_cfg) for hw in hws],
        jobs=jobs)
    schedules: list[NetworkSchedule] = []
    for schedule, n_cands, n_sim, delta, rank_delta in scored:
        stats["hardware_evaluated"] += 1
        stats["candidates"] += n_cands
        stats["simulated"] += n_sim
        _eval_store().update(delta)
        _rank_store().update(rank_delta)
        schedules.append(schedule)

    dominating = [s for s in schedules
                  if s.latency_cycles <= baseline.latency_cycles
                  and s.total_energy_pj <= baseline.total_energy_pj]
    # The baseline hardware always yields a dominating schedule when it is
    # inside the budget (its energy pool contains the baseline mapping);
    # outside the budget the baseline itself is the conservative answer.
    best = min(dominating, key=lambda s: (s.latency_cycles,
                                          s.total_energy_pj, s.hardware)) \
        if dominating else baseline

    cache_after = SIM_CACHE.stats()
    stats["sim_misses"] = cache_after["misses"] - cache_before["misses"]
    stats["sim_hits"] = cache_after["hits"] - cache_before["hits"]
    if debug:
        from repro.analysis.findings import VerificationError
        from repro.analysis.verify import verify_schedule
        findings = verify_schedule(best, layers, base_cfg)
        if findings:
            raise VerificationError(findings)
    return SearchOutcome(workload=workload, baseline=baseline, best=best,
                         pareto=tuple(_pareto(schedules + [baseline])),
                         stats=stats)
