"""Mapping search: analytic pruning + exact scoring through the sim cache.

Per hardware point, each layer's candidate mappings are ranked by the
analytical model and only the best few reach the event-driven simulator —
whose results are memoized per plan shape in
:data:`repro.core.noc.simcache.SIM_CACHE`, so a whole-network search costs a
handful of distinct window programs rather than |layers| x |candidates| sim
runs (the PR-2 cache is what makes this subsystem affordable; see
EXPERIMENTS.md).

Selection is *baseline-dominating* constrained optimization: the reference
is the paper's fixed mapping (:data:`~.space.PAPER_MAPPING`) simulated per
layer; per layer the mapper minimizes latency subject to the layer's
baseline energy, and across hardware points it picks the lowest-latency
schedule whose network totals weakly dominate the baseline's (the baseline
hardware always qualifies when it is inside the budget, so the searched
schedule is never worse than the paper's on either axis — equality when the
paper mapping is already optimal).  Everything is deterministic: no RNG,
total sort keys, cache hits bit-identical to ground truth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from itertools import islice
from typing import Optional, Sequence

from repro.core.noc import SIM_CACHE, NocConfig
from repro.core.noc.compiled import compiled_enabled
from repro.core.noc.traffic import LayerResult, simulate_layer
from repro.core.ops import LayerShape
from repro.exec import parallel_map

from .schedule import LayerAssignment, NetworkSchedule
from .space import (Mapping, MapperConfig, PAPER_MAPPING, analytic_latency,
                    hardware_candidates, hardware_mapping_fields,
                    layer_candidates, shard_layer)


@dataclass
class SearchOutcome:
    """Everything one network search produced."""

    workload: str
    baseline: NetworkSchedule            # the paper's fixed mapping, simulated
    best: NetworkSchedule                # lowest-latency baseline-dominating
    pareto: tuple[NetworkSchedule, ...]  # latency/energy front over hardware
    stats: dict = field(default_factory=dict)

    @property
    def latency_x(self) -> float:
        return self.baseline.latency_cycles / max(self.best.latency_cycles, 1.0)

    @property
    def energy_x(self) -> float:
        return self.baseline.total_energy_pj \
            / max(self.best.total_energy_pj, 1.0)


# --------------------------------------------------------------------------- #
# Layer-result memo: a LayerResult is a pure function of the layer's Eq.(1)-(4)
# shape (R, C, F, outputs) and the mapping, never of the layer identity —
# ResNet-50 repeats the same bottleneck shapes dozens of times, and every
# hardware point re-scores the baseline anchor.  Keyed off
# ``SIM_CACHE.generation`` so ``SIM_CACHE.clear()`` invalidates it too, and
# bypassed entirely when the window cache is disabled (ground-truth mode).
# --------------------------------------------------------------------------- #
_EVAL_MEMO: dict = {"gen": -1, "store": {}}


def _eval_store() -> dict:
    if _EVAL_MEMO["gen"] != SIM_CACHE.generation:
        _EVAL_MEMO["gen"] = SIM_CACHE.generation
        _EVAL_MEMO["store"] = {}
    return _EVAL_MEMO["store"]


def _eval_key(layer: LayerShape, mapping: Mapping, base_cfg: NocConfig,
              sim_rounds: int) -> tuple:
    return ((layer.R, layer.C, layer.F, layer.outputs), mapping, base_cfg,
            sim_rounds)


def _evaluate_multichip(layer: LayerShape, mapping: Mapping,
                        base_cfg: NocConfig, sim_rounds: int,
                        package: str) -> LayerResult:
    """Multi-chip cost: per-chip shard sim + package broadcast surcharge.

    Every chip runs the identical shard concurrently (latency is one
    chip's; NoC/stream energy multiplies by the chip count), and each
    weight fill first broadcasts the mesh's fill payload over the package
    network (:func:`~repro.core.noc.hierarchy.chip_round_cost`, riding the
    same sim cache).  DESIGN.md S14.
    """
    from repro.core.noc.hierarchy import chip_round_cost
    from repro.core.noc.traffic import layer_plan
    flat = dataclasses.replace(mapping, chips=1)
    shard = shard_layer(layer, mapping.chips)
    r = evaluate_mapping(shard, flat, base_cfg, sim_rounds)
    cfg = mapping.cfg(base_cfg)
    plan = layer_plan(shard, cfg, mapping.e_pes, mapping.mode,
                      mapping.q_bits, mapping.groups)
    fill_bits = plan.weight_bits_per_router * cfg.width * cfg.height
    pkg_lat, pkg_en = chip_round_cost(fill_bits, mapping.chips, cfg,
                                      package=package,
                                      semantics=mapping.semantics)
    c = mapping.chips
    return dataclasses.replace(
        r, name=layer.name,
        latency_cycles=r.latency_cycles + pkg_lat * r.fills,
        noc_energy_pj=r.noc_energy_pj * c + pkg_en * r.fills,
        stream_energy_pj=r.stream_energy_pj * c)


def evaluate_mapping(layer: LayerShape, mapping: Mapping,
                     base_cfg: NocConfig = NocConfig(),
                     sim_rounds: int = 16,
                     package: str = "mesh") -> LayerResult:
    """Exact (event-driven, cache-backed) cost of one mapping."""
    if mapping.chips > 1:
        return _evaluate_multichip(layer, mapping, base_cfg, sim_rounds,
                                   package)
    if not SIM_CACHE.enabled or not compiled_enabled():
        return simulate_layer(layer, mapping.mode, mapping.cfg(base_cfg),
                              mapping.e_pes, sim_rounds,
                              q_bits=mapping.q_bits, groups=mapping.groups)
    store = _eval_store()
    key = _eval_key(layer, mapping, base_cfg, sim_rounds)
    hit = store.get(key)
    if hit is None:
        hit = simulate_layer(layer, mapping.mode, mapping.cfg(base_cfg),
                             mapping.e_pes, sim_rounds,
                             q_bits=mapping.q_bits, groups=mapping.groups)
        store[key] = hit
    # Hand out a copy re-stamped with the caller's layer identity: the memo
    # collapses identically-shaped layers, but results name their layer.
    return dataclasses.replace(hit, name=layer.name)


def _choose(results: list[tuple[Mapping, LayerResult]],
            energy_budget: float) -> tuple[Mapping, LayerResult]:
    """Min latency subject to the baseline energy budget; energy breaks ties.

    Falls back to the unconstrained (latency, energy) minimum when nothing
    on this hardware meets the budget (a rectangular mesh can be faster but
    hotter — it then competes only through the Pareto front).
    """
    within = [(m, r) for m, r in results
              if r.total_energy_pj <= energy_budget]
    pool = within or results
    return min(pool, key=lambda mr: (mr[1].latency_cycles,
                                     mr[1].total_energy_pj,
                                     mr[0].sort_key))


def _pareto(schedules: list[NetworkSchedule]) -> list[NetworkSchedule]:
    """Non-dominated schedules over (latency, total energy), sorted."""
    ordered = sorted(schedules, key=lambda s: (s.latency_cycles,
                                               s.total_energy_pj, s.hardware))
    front: list[NetworkSchedule] = []
    best_energy = float("inf")
    for s in ordered:
        if s.total_energy_pj < best_energy:
            front.append(s)
            best_energy = s.total_energy_pj
    return front


def _score_hardware(payload) -> tuple[NetworkSchedule, int, int, dict]:
    """Score every layer on one hardware point (a pool-fanout unit).

    Returns ``(schedule, candidates, simulated, layer-memo delta)``; the
    delta ships memoized LayerResults back to the parent process so a
    warm parent keeps getting warmer across ``--jobs`` fan-outs.
    """
    workload, layers, base_results, hw, mcfg, base_cfg = payload
    memo_before = len(_eval_store())
    w, h, e, chips = hardware_mapping_fields(hw)
    # The hardware's own paper-style mapping is always scored exactly,
    # whatever the analytic ranking says — it anchors the energy-budget
    # pool (and *is* the baseline mapping on the baseline hardware).
    anchor = Mapping(w, h, e, "ws", "ina", mcfg.q_list[0], None, chips)
    n_cands = n_sim = 0
    assignments = []
    for layer, base_r in zip(layers, base_results):
        cands = layer_candidates(layer, hw, mcfg)
        n_cands += len(cands)
        ranked = sorted(cands, key=lambda m: (
            analytic_latency(layer, m, base_cfg), m.sort_key))
        keep = ranked[:mcfg.prune_keep]
        if anchor in cands and anchor not in keep:
            keep.append(anchor)
        results = [(m, evaluate_mapping(layer, m, base_cfg,
                                        mcfg.sim_rounds, mcfg.package))
                   for m in keep]
        n_sim += len(results)
        m, r = _choose(results, base_r.total_energy_pj)
        assignments.append(
            LayerAssignment.from_result(layer, m, r, base_cfg))
    schedule = NetworkSchedule(workload=workload, hardware=hw,
                               assignments=tuple(assignments))
    # New memo entries = everything appended past the starting length
    # (insertion-ordered dict, never deleted from within a generation).
    store = _eval_store()
    delta = {k: store[k]
             for k in islice(iter(store), memo_before, None)}
    return schedule, n_cands, n_sim, delta


def search_network(workload: str, layers: Sequence[LayerShape],
                   mcfg: MapperConfig = MapperConfig(),
                   base_cfg: NocConfig = NocConfig(),
                   baseline_mapping: Mapping = PAPER_MAPPING,
                   jobs: int = 1, debug: bool = False) -> SearchOutcome:
    """Search the mapping space for a whole network; emit the best schedule.

    Deterministic: same (layers, mcfg, base_cfg) -> identical outcome,
    whatever ``jobs`` is — hardware points are scored across a process
    pool (:mod:`repro.exec.pool`) and merged back in candidate order, and
    every scored cost is a pure function of the plan shape.

    ``debug=True`` statically verifies the winning schedule's re-emitted
    packet programs (``repro.analysis.verify_schedule``: routes, DAG, CDG
    deadlock freedom) and raises ``VerificationError`` on any finding
    before the outcome escapes.
    """
    cache_before = SIM_CACHE.stats()
    stats = {"candidates": 0, "simulated": 0, "hardware_evaluated": 0}

    base_results = [evaluate_mapping(l, baseline_mapping, base_cfg,
                                     mcfg.sim_rounds, mcfg.package)
                    for l in layers]
    stats["simulated"] += len(base_results)
    baseline = NetworkSchedule(
        workload=workload, hardware=baseline_mapping.hardware,
        assignments=tuple(
            LayerAssignment.from_result(l, baseline_mapping, r, base_cfg)
            for l, r in zip(layers, base_results)))

    hws = hardware_candidates(mcfg)
    layers = tuple(layers)
    scored = parallel_map(
        _score_hardware,
        [(workload, layers, base_results, hw, mcfg, base_cfg) for hw in hws],
        jobs=jobs)
    schedules: list[NetworkSchedule] = []
    for schedule, n_cands, n_sim, delta in scored:
        stats["hardware_evaluated"] += 1
        stats["candidates"] += n_cands
        stats["simulated"] += n_sim
        _eval_store().update(delta)
        schedules.append(schedule)

    dominating = [s for s in schedules
                  if s.latency_cycles <= baseline.latency_cycles
                  and s.total_energy_pj <= baseline.total_energy_pj]
    # The baseline hardware always yields a dominating schedule when it is
    # inside the budget (its energy pool contains the baseline mapping);
    # outside the budget the baseline itself is the conservative answer.
    best = min(dominating, key=lambda s: (s.latency_cycles,
                                          s.total_energy_pj, s.hardware)) \
        if dominating else baseline

    cache_after = SIM_CACHE.stats()
    stats["sim_misses"] = cache_after["misses"] - cache_before["misses"]
    stats["sim_hits"] = cache_after["hits"] - cache_before["hits"]
    if debug:
        from repro.analysis.findings import VerificationError
        from repro.analysis.verify import verify_schedule
        findings = verify_schedule(best, layers, base_cfg)
        if findings:
            raise VerificationError(findings)
    return SearchOutcome(workload=workload, baseline=baseline, best=best,
                         pareto=tuple(_pareto(schedules + [baseline])),
                         stats=stats)
