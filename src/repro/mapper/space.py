"""The mapping search space: what the mapper enumerates, and how it prunes.

A :class:`Mapping` is one way to lay a layer onto the accelerator: a mesh
shape (rectangular ``width x height`` included), PEs per router, dataflow
(WS/OS), router collective semantics (INA vs eject->add->inject), weight
precision, and the chains-per-column count G (the paper always uses the
maximum ``floor(H/P#)``; smaller G trades bigger gather payloads against
round count, which is exactly the latency/energy tension the Pareto report
surfaces).

Hardware axes (``width``/``height``/``e_pes``) are fixed for a whole network
— a chip does not reconfigure between layers — while the per-layer axes
(``dataflow``/``semantics``/``groups``/``q_bits``) may vary layer to layer.
:class:`MapperConfig` bounds the space under a PE budget so searched
mappings compare fairly against the paper's fixed 8x8x1 placement.

Pruning rules (DESIGN.md S9):
1. *Feasibility* — WS needs ``g * P# <= height`` per Eq. (2); chains taller
   than a column fall back to the sequential multi-pass model and only the
   maximal-G mapping is kept for them.
2. *Budget* — ``width * height * e_pes`` must land in
   ``[pe_budget * min_pe_fill, pe_budget]``; aspect ratios beyond
   ``max_aspect`` are dropped (row streaming degenerates).
3. *Analytic ranking* — survivors are ranked by the Eq. (1)-(4) round count
   composed with per-round serialization bounds (:func:`analytic_latency`),
   and only the ``prune_keep`` best per (layer, hardware) reach the
   event-driven simulator.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

from repro.core.ina_model import DEFAULT_Q_BITS, p_num
from repro.core.noc import NocConfig
from repro.core.noc.router import cached_field_hash
from repro.core.noc.traffic import layer_plan
from repro.core.ops import LayerShape

DATAFLOWS = ("ws", "os")
SEMANTICS = ("ina", "eject_inject")


@dataclass(frozen=True)
class Mapping:
    """One candidate placement of a layer onto the mesh.

    ``chips`` > 1 replicates the mesh across a package of chips
    (DESIGN.md S14): output rows shard evenly per chip, weights are
    broadcast over the package network once per fill, and the per-chip
    shard runs the unchanged flat simulator.
    """

    width: int = 8
    height: int = 8
    e_pes: int = 1
    dataflow: str = "ws"            # "ws" | "os"
    semantics: str = "ina"          # "ina" | "eject_inject"
    q_bits: int = DEFAULT_Q_BITS
    groups: Optional[int] = None    # chains per column (None = max feasible)
    chips: int = 1                  # package replication (1 = flat mesh)

    @property
    def mode(self) -> str:
        """The traffic-generator mode this mapping lowers to."""
        if self.dataflow == "os":
            return "os_gather"
        return "ws_ina" if self.semantics == "ina" else "ws_noina"

    @property
    def num_pes(self) -> int:
        return self.width * self.height * self.e_pes * self.chips

    @property
    def hardware(self) -> tuple[int, ...]:
        """(w, h, e) for flat mappings — the pre-hierarchy tuple — and
        (w, h, e, chips) once a package axis exists."""
        if self.chips == 1:
            return (self.width, self.height, self.e_pes)
        return (self.width, self.height, self.e_pes, self.chips)

    @property
    def sort_key(self) -> tuple:
        """Total deterministic order (``groups=None`` sorts first)."""
        return (self.width, self.height, self.e_pes, self.dataflow,
                self.semantics, self.q_bits,
                -1 if self.groups is None else self.groups, self.chips)

    def cfg(self, base: NocConfig = NocConfig()) -> NocConfig:
        """The NocConfig one chip of this mapping simulates under."""
        rows = None if self.height == self.width else self.height
        return _mesh_cfg(base, self.width, rows)

    def label(self) -> str:
        g = "max" if self.groups is None else str(self.groups)
        lab = (f"{self.width}x{self.height}xE{self.e_pes}:{self.dataflow}/"
               f"{self.semantics}/q{self.q_bits}/g{g}")
        if self.chips > 1:
            lab += f"/c{self.chips}"
        return lab


#: Mappings are dict keys in the layer-result memo and members of sort
#: keys; cache their field hash like NocConfig's (see router.py).
Mapping.__hash__ = cached_field_hash


@lru_cache(maxsize=None)
def _mesh_cfg(base: NocConfig, n: int, rows: Optional[int]) -> NocConfig:
    """Memoized mesh reshape (``dataclasses.replace`` is surprisingly hot:
    the search derives the same few configs tens of thousands of times)."""
    return dataclasses.replace(base, n=n, rows=rows)


#: The paper's fixed placement: 8x8 square, 1 PE/router, WS + INA, q=32,
#: maximal chains per column (Eqs. 1-4 / Fig. 3).
PAPER_MAPPING = Mapping()


@dataclass(frozen=True)
class MapperConfig:
    """Bounds of the search space (defaults sized to the paper's 64 PEs).

    ``pe_budget`` bounds one *chip*; ``chips_list`` adds a package axis on
    top of it (every listed count pairs with every in-budget chip shape),
    so multi-chip candidates compare per-chip-fair against the paper's
    fully-populated single mesh.
    """

    pe_budget: int = 64             # width * height * e_pes ceiling per chip
    min_pe_fill: float = 0.5        # floor, as a fraction of the budget
    max_aspect: int = 4             # max width/height (and height/width)
    min_dim: int = 2                # smallest mesh side considered
    e_list: tuple[int, ...] = (1, 2, 4)
    q_list: tuple[int, ...] = (DEFAULT_Q_BITS,)
    dataflows: tuple[str, ...] = DATAFLOWS
    semantics: tuple[str, ...] = SEMANTICS
    group_options: int = 3          # distinct G values tried per (layer, hw)
    prune_keep: int = 6             # survivors simulated per (layer, hw)
    sim_rounds: int = 16            # simulated window length (PR-2 default)
    chips_list: tuple[int, ...] = (1,)   # package axis (DESIGN.md S14)
    package: str = "mesh"           # cross-chip fabric ("mesh" | "express")


#: CI smoke shape: square + one rectangle, two E points, short windows.
QUICK_MAPPER = MapperConfig(e_list=(1, 2), min_dim=4, group_options=2,
                            prune_keep=4, sim_rounds=4)


def hardware_candidates(mcfg: MapperConfig) -> list[tuple[int, ...]]:
    """All hardware points inside the per-chip budget (deterministic).

    Dimensions run over powers of two (meshes and Eq. (3) divisions stay
    integral); the budget floor keeps the comparison against the paper's
    fully-populated mesh fair.  Single-chip points stay the historical
    ``(w, h, e)`` triples; every ``chips_list`` entry > 1 adds
    ``(w, h, e, chips)`` package points on the same chip shapes.
    """
    dims = []
    d = mcfg.min_dim
    while d * mcfg.min_dim <= mcfg.pe_budget:
        dims.append(d)
        d *= 2
    out: list[tuple[int, ...]] = []
    lo = mcfg.pe_budget * mcfg.min_pe_fill
    for w in dims:
        for h in dims:
            if max(w, h) > mcfg.max_aspect * min(w, h):
                continue
            for e in mcfg.e_list:
                if not lo <= w * h * e <= mcfg.pe_budget:
                    continue
                for chips in sorted(set(mcfg.chips_list)):
                    out.append((w, h, e) if chips == 1
                               else (w, h, e, chips))
    return sorted(out)


def hardware_mapping_fields(hw: tuple[int, ...]) -> tuple[int, int, int, int]:
    """(w, h, e, chips) from a 3- or 4-tuple hardware point."""
    w, h, e = hw[:3]
    chips = hw[3] if len(hw) > 3 else 1
    return w, h, e, chips


def group_choices(p_req: int, height: int, k: int) -> list[Optional[int]]:
    """Up to ``k`` chains-per-column values: max feasible, then halvings.

    ``None`` (= the paper's maximal G) always leads; ``G=1`` closes the list
    when it fits.  Chains taller than the column (``p_req > height``) leave
    only the sequential multi-pass mapping (pruning rule 1).
    """
    g_max = height // min(p_req, height)
    if p_req > height or g_max <= 1:
        return [None]
    out: list[Optional[int]] = [None]
    g = g_max // 2
    while g > 1 and len(out) < k - 1:
        out.append(g)
        g //= 2
    if len(out) < k:
        out.append(1)
    return out


def layer_candidates(layer: LayerShape, hardware: tuple[int, ...],
                     mcfg: MapperConfig) -> list[Mapping]:
    """Enumerate the per-layer mappings for one hardware point (sorted)."""
    w, h, e, chips = hardware_mapping_fields(hardware)
    out = []
    for q in mcfg.q_list:
        if "os" in mcfg.dataflows and "ina" in mcfg.semantics:
            # OS keeps psums local; the gather collective is the only NoC
            # flow and it needs gather-capable routers — OS under plain
            # eject/inject routers is not modeled (paper SIV.B compares
            # OS-with-gather only), so OS contributes one candidate per q
            # and none at all when the space excludes capable routers.
            out.append(Mapping(w, h, e, "os", "ina", q, None, chips))
        if "ws" not in mcfg.dataflows:
            continue
        p_req = p_num(layer, q_bits=q)
        for sem in mcfg.semantics:
            for g in group_choices(p_req, h, mcfg.group_options):
                out.append(Mapping(w, h, e, "ws", sem, q, g, chips))
    return sorted(set(out), key=lambda m: m.sort_key)


def shard_layer(layer: LayerShape, chips: int) -> LayerShape:
    """The per-chip slice of a layer under package replication.

    Output rows (M) shard evenly across chips — weights replicate, so the
    only cross-chip traffic is the per-fill package broadcast the search
    prices via :func:`~repro.core.noc.hierarchy.chip_round_cost`.  CONV
    layers shard through their exact im2col GEMM (same MACs, P#, rounds).
    """
    if chips <= 1:
        return layer
    from repro.core.ops import GemmLayer, im2col
    g = layer if isinstance(layer, GemmLayer) else im2col(layer)
    return dataclasses.replace(g, name=f"{g.name}+c{chips}",
                               M=-(-g.M // chips))


def analytic_latency(layer: LayerShape, mapping: Mapping,
                     base_cfg: NocConfig = NocConfig()) -> float:
    """Cheap cycle estimate used for pruning (no event-driven simulation).

    Composes the Eq. (1)-(4) round count (via :func:`layer_plan`, the same
    arithmetic) with per-round serialization bounds: the column gather
    occupies its ejection port for ``gather_flits`` cycles per round, a
    Fig. 4(a) relay chain adds its eject->add->inject pipeline, and weight
    fills bar execution.  Not exact — contention is what the simulator is
    for — but monotone enough to rank candidates (DESIGN.md S9).  Chips > 1
    rank on their per-chip shard plus a hop-count package-broadcast bound
    (the exact surcharge is simulated only for pruning survivors).
    """
    cfg = mapping.cfg(base_cfg)
    layer = shard_layer(layer, mapping.chips)
    plan = layer_plan(layer, cfg, mapping.e_pes, mapping.mode,
                      mapping.q_bits, mapping.groups)
    hop = cfg.router_cycles + cfg.link_cycles
    per_round = float(plan.gather_flits)
    if mapping.mode == "ws_noina" and plan.p > 1:
        per_round += (plan.p - 1) * (hop + 2 * cfg.ni_cycles
                                     + plan.unicast_flits
                                     + cfg.pe_add_cycles)
    depth = (cfg.height - 1) * hop + 2 * cfg.ni_cycles
    fill = plan.fills * (cfg.width // cfg.stream_buses_per_row) \
        * cfg.payload_flits(plan.weight_bits_per_router)
    stream = plan.weight_bits / (plan.p * cfg.ws_input_reuse * cfg.flit_bits
                                 * cfg.stream_buses_per_row)
    if mapping.dataflow == "os":
        # OS re-streams weights continuously (no stationarity): its
        # per-round pacing is the weight re-stream plus input streaming,
        # mirroring _os_weight_stream_round in the exact simulator.
        stream += plan.weight_bits / (cfg.flit_bits * cfg.os_weight_reuse
                                      * cfg.os_stream_bw)
    total = fill + depth + plan.rounds * max(per_round, stream)
    if mapping.chips > 1:
        # Analytic package surcharge: per fill, the weight payload crosses
        # the package diameter and serializes onto one root link.
        pkg_bits = plan.weight_bits_per_router * cfg.width * cfg.height
        total += plan.fills * ((mapping.chips - 1) * (cfg.router_cycles + 4)
                               + pkg_bits / cfg.flit_bits)
    return total
