"""The mapping search space: what the mapper enumerates, and how it prunes.

A :class:`Mapping` is one way to lay a layer onto the accelerator: a mesh
shape (rectangular ``width x height`` included), PEs per router, dataflow
(WS/OS), router collective semantics (INA vs eject->add->inject), weight
precision, and the chains-per-column count G (the paper always uses the
maximum ``floor(H/P#)``; smaller G trades bigger gather payloads against
round count, which is exactly the latency/energy tension the Pareto report
surfaces).

Hardware axes (``width``/``height``/``e_pes``) are fixed for a whole network
— a chip does not reconfigure between layers — while the per-layer axes
(``dataflow``/``semantics``/``groups``/``q_bits``) may vary layer to layer.
:class:`MapperConfig` bounds the space under a PE budget so searched
mappings compare fairly against the paper's fixed 8x8x1 placement.

Pruning rules (DESIGN.md S9):
1. *Feasibility* — WS needs ``g * P# <= height`` per Eq. (2); chains taller
   than a column fall back to the sequential multi-pass model and only the
   maximal-G mapping is kept for them.
2. *Budget* — ``width * height * e_pes`` must land in
   ``[pe_budget * min_pe_fill, pe_budget]``; aspect ratios beyond
   ``max_aspect`` are dropped (row streaming degenerates).
3. *Analytic ranking* — survivors are ranked by the Eq. (1)-(4) round count
   composed with per-round serialization bounds (:func:`analytic_latency`),
   and only the ``prune_keep`` best per (layer, hardware) reach the
   event-driven simulator.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

from repro.core.ina_model import DEFAULT_Q_BITS, p_num
from repro.core.noc import NocConfig
from repro.core.noc.router import cached_field_hash
from repro.core.noc.traffic import layer_plan
from repro.core.ops import LayerShape

DATAFLOWS = ("ws", "os")
SEMANTICS = ("ina", "eject_inject")


@dataclass(frozen=True)
class Mapping:
    """One candidate placement of a layer onto the mesh."""

    width: int = 8
    height: int = 8
    e_pes: int = 1
    dataflow: str = "ws"            # "ws" | "os"
    semantics: str = "ina"          # "ina" | "eject_inject"
    q_bits: int = DEFAULT_Q_BITS
    groups: Optional[int] = None    # chains per column (None = max feasible)

    @property
    def mode(self) -> str:
        """The traffic-generator mode this mapping lowers to."""
        if self.dataflow == "os":
            return "os_gather"
        return "ws_ina" if self.semantics == "ina" else "ws_noina"

    @property
    def num_pes(self) -> int:
        return self.width * self.height * self.e_pes

    @property
    def hardware(self) -> tuple[int, int, int]:
        return (self.width, self.height, self.e_pes)

    @property
    def sort_key(self) -> tuple:
        """Total deterministic order (``groups=None`` sorts first)."""
        return (self.width, self.height, self.e_pes, self.dataflow,
                self.semantics, self.q_bits,
                -1 if self.groups is None else self.groups)

    def cfg(self, base: NocConfig = NocConfig()) -> NocConfig:
        """The NocConfig this mapping simulates under (keyed by the cache)."""
        rows = None if self.height == self.width else self.height
        return _mesh_cfg(base, self.width, rows)

    def label(self) -> str:
        g = "max" if self.groups is None else str(self.groups)
        return (f"{self.width}x{self.height}xE{self.e_pes}:{self.dataflow}/"
                f"{self.semantics}/q{self.q_bits}/g{g}")


#: Mappings are dict keys in the layer-result memo and members of sort
#: keys; cache their field hash like NocConfig's (see router.py).
Mapping.__hash__ = cached_field_hash


@lru_cache(maxsize=None)
def _mesh_cfg(base: NocConfig, n: int, rows: Optional[int]) -> NocConfig:
    """Memoized mesh reshape (``dataclasses.replace`` is surprisingly hot:
    the search derives the same few configs tens of thousands of times)."""
    return dataclasses.replace(base, n=n, rows=rows)


#: The paper's fixed placement: 8x8 square, 1 PE/router, WS + INA, q=32,
#: maximal chains per column (Eqs. 1-4 / Fig. 3).
PAPER_MAPPING = Mapping()


@dataclass(frozen=True)
class MapperConfig:
    """Bounds of the search space (defaults sized to the paper's 64 PEs)."""

    pe_budget: int = 64             # width * height * e_pes ceiling
    min_pe_fill: float = 0.5        # floor, as a fraction of the budget
    max_aspect: int = 4             # max width/height (and height/width)
    min_dim: int = 2                # smallest mesh side considered
    e_list: tuple[int, ...] = (1, 2, 4)
    q_list: tuple[int, ...] = (DEFAULT_Q_BITS,)
    dataflows: tuple[str, ...] = DATAFLOWS
    semantics: tuple[str, ...] = SEMANTICS
    group_options: int = 3          # distinct G values tried per (layer, hw)
    prune_keep: int = 6             # survivors simulated per (layer, hw)
    sim_rounds: int = 16            # simulated window length (PR-2 default)


#: CI smoke shape: square + one rectangle, two E points, short windows.
QUICK_MAPPER = MapperConfig(e_list=(1, 2), min_dim=4, group_options=2,
                            prune_keep=4, sim_rounds=4)


def hardware_candidates(mcfg: MapperConfig) -> list[tuple[int, int, int]]:
    """All (width, height, e_pes) triples inside the budget (deterministic).

    Dimensions run over powers of two (meshes and Eq. (3) divisions stay
    integral); the budget floor keeps the comparison against the paper's
    fully-populated mesh fair.
    """
    dims = []
    d = mcfg.min_dim
    while d * mcfg.min_dim <= mcfg.pe_budget:
        dims.append(d)
        d *= 2
    out = []
    lo = mcfg.pe_budget * mcfg.min_pe_fill
    for w in dims:
        for h in dims:
            if max(w, h) > mcfg.max_aspect * min(w, h):
                continue
            for e in mcfg.e_list:
                if lo <= w * h * e <= mcfg.pe_budget:
                    out.append((w, h, e))
    return sorted(out)


def group_choices(p_req: int, height: int, k: int) -> list[Optional[int]]:
    """Up to ``k`` chains-per-column values: max feasible, then halvings.

    ``None`` (= the paper's maximal G) always leads; ``G=1`` closes the list
    when it fits.  Chains taller than the column (``p_req > height``) leave
    only the sequential multi-pass mapping (pruning rule 1).
    """
    g_max = height // min(p_req, height)
    if p_req > height or g_max <= 1:
        return [None]
    out: list[Optional[int]] = [None]
    g = g_max // 2
    while g > 1 and len(out) < k - 1:
        out.append(g)
        g //= 2
    if len(out) < k:
        out.append(1)
    return out


def layer_candidates(layer: LayerShape, hardware: tuple[int, int, int],
                     mcfg: MapperConfig) -> list[Mapping]:
    """Enumerate the per-layer mappings for one hardware point (sorted)."""
    w, h, e = hardware
    out = []
    for q in mcfg.q_list:
        if "os" in mcfg.dataflows and "ina" in mcfg.semantics:
            # OS keeps psums local; the gather collective is the only NoC
            # flow and it needs gather-capable routers — OS under plain
            # eject/inject routers is not modeled (paper SIV.B compares
            # OS-with-gather only), so OS contributes one candidate per q
            # and none at all when the space excludes capable routers.
            out.append(Mapping(w, h, e, "os", "ina", q, None))
        if "ws" not in mcfg.dataflows:
            continue
        p_req = p_num(layer, q_bits=q)
        for sem in mcfg.semantics:
            for g in group_choices(p_req, h, mcfg.group_options):
                out.append(Mapping(w, h, e, "ws", sem, q, g))
    return sorted(set(out), key=lambda m: m.sort_key)


def analytic_latency(layer: LayerShape, mapping: Mapping,
                     base_cfg: NocConfig = NocConfig()) -> float:
    """Cheap cycle estimate used for pruning (no event-driven simulation).

    Composes the Eq. (1)-(4) round count (via :func:`layer_plan`, the same
    arithmetic) with per-round serialization bounds: the column gather
    occupies its ejection port for ``gather_flits`` cycles per round, a
    Fig. 4(a) relay chain adds its eject->add->inject pipeline, and weight
    fills bar execution.  Not exact — contention is what the simulator is
    for — but monotone enough to rank candidates (DESIGN.md S9).
    """
    cfg = mapping.cfg(base_cfg)
    plan = layer_plan(layer, cfg, mapping.e_pes, mapping.mode,
                      mapping.q_bits, mapping.groups)
    hop = cfg.router_cycles + cfg.link_cycles
    per_round = float(plan.gather_flits)
    if mapping.mode == "ws_noina" and plan.p > 1:
        per_round += (plan.p - 1) * (hop + 2 * cfg.ni_cycles
                                     + plan.unicast_flits
                                     + cfg.pe_add_cycles)
    depth = (cfg.height - 1) * hop + 2 * cfg.ni_cycles
    fill = plan.fills * (cfg.width // cfg.stream_buses_per_row) \
        * cfg.payload_flits(plan.weight_bits_per_router)
    stream = plan.weight_bits / (plan.p * cfg.ws_input_reuse * cfg.flit_bits
                                 * cfg.stream_buses_per_row)
    if mapping.dataflow == "os":
        # OS re-streams weights continuously (no stationarity): its
        # per-round pacing is the weight re-stream plus input streaming,
        # mirroring _os_weight_stream_round in the exact simulator.
        stream += plan.weight_bits / (cfg.flit_bits * cfg.os_weight_reuse
                                      * cfg.os_stream_bw)
    return fill + depth + plan.rounds * max(per_round, stream)
