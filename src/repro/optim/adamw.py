"""AdamW with global-norm clipping and a cosine schedule (pure JAX).

Optimizer state mirrors the parameter pytree (m, v per leaf) so the same
FSDP PartitionSpecs shard it without extra rules.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # int32 scalar
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base_lr * (0.1 + 0.9 * cos))
    return lr


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state: AdamWState, lr, *,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_norm: float = 1.0):
    """One AdamW step.  ``lr`` is a schedule fn (step -> lr) or a float."""
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.float32(lr)
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
            p.astype(jnp.float32) * (p.ndim >= 2)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), \
        {"grad_norm": gnorm, "lr": lr_t}
