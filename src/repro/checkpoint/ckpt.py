"""Fault-tolerant checkpointing: atomic writes, keep-k, mesh-independent.

Checkpoints are written as one ``.npz`` of flattened leaves + a JSON
manifest of the treedef and logical PartitionSpecs.  Restores are
*mesh-independent*: arrays are loaded as host numpy and ``device_put`` with
shardings fitted to whatever mesh the restarted job has (elastic re-mesh —
a job restarted on fewer/more chips reshards transparently).

Atomicity: write to ``step_XXXX.tmp/`` then ``os.replace`` — a crash never
leaves a half-written checkpoint visible; ``latest_step`` only ever sees
complete directories.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_pytree(tree, directory: str, step: int) -> str:
    """Atomic checkpoint write; returns the final directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _flatten_with_names(tree)
    arrays, dtypes = {}, []
    for i, l in enumerate(leaves):
        a = np.asarray(jax.device_get(l))
        dtypes.append(str(a.dtype))
        if str(a.dtype) == "bfloat16":        # npz cannot store ml_dtypes
            a = a.view(np.uint16)
        arrays[f"a{i}"] = a
    np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "names": names, "dtypes": dtypes,
                   "shapes": [list(a.shape) for a in arrays.values()]}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def restore_pytree(tree_like, directory: str, step: int | None = None,
                   shardings=None):
    """Restore into the structure of ``tree_like`` (shapes/treedef source).

    ``shardings``: optional pytree of Shardings (same structure) — enables
    restoring onto a *different* mesh than the checkpoint was written from.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "leaves.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _flatten_with_names(tree_like)
    import ml_dtypes
    loaded = []
    for i in range(len(leaves)):
        a = data[f"a{i}"]
        if manifest["dtypes"][i] == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
        loaded.append(a)
    for got, want, name in zip(loaded, leaves, names):
        if tuple(got.shape) != tuple(np.shape(want)):
            raise ValueError(
                f"checkpoint leaf {name} shape {got.shape} != {np.shape(want)}")
    restored = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, step


class CheckpointManager:
    """keep-k rotation + preemption-safe save/restore."""

    def __init__(self, directory: str, keep: int = 3, every: int = 100):
        self.directory = directory
        self.keep = keep
        self.every = every

    def maybe_save(self, tree, step: int, force: bool = False) -> bool:
        if not force and (step == 0 or step % self.every != 0):
            return False
        save_pytree(tree, self.directory, step)
        self._gc()
        return True

    def restore_or_none(self, tree_like, shardings=None):
        if latest_step(self.directory) is None:
            return None
        return restore_pytree(tree_like, self.directory, shardings=shardings)

    def _gc(self):
        steps = sorted(int(m.group(1)) for d in os.listdir(self.directory)
                       if (m := _STEP_RE.match(d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
