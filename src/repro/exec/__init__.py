"""Parallel execution layer: deterministic process-pool fan-out.

See :mod:`repro.exec.pool` (DESIGN.md S10).
"""
from .pool import default_jobs, parallel_map

__all__ = ["default_jobs", "parallel_map"]
