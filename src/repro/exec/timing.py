"""The sanctioned wall-clock path for progress reporting.

Modules under the determinism contract (``core/noc``, ``plan``, ``serve``,
``mapper`` — see ``repro.analysis.lint``) must not read the wall clock:
a timestamp that leaks into an artifact breaks byte-reproducibility, and
the lint's ``wall-clock`` rule flags the call sites.  Human-facing
*duration* reporting (stdout progress lines, ``info`` dicts the CLIs
print) is still wanted, so it routes through :class:`Stopwatch` here —
``exec/`` is outside the lint scope precisely because this module is the
one place clock access is concentrated and auditable.  Keep Stopwatch
readings out of persisted artifacts.
"""
from __future__ import annotations

import time


class Stopwatch:
    """Monotonic duration meter: ``Stopwatch().seconds`` since creation."""

    __slots__ = ("_t0",)

    def __init__(self) -> None:
        self._t0 = time.perf_counter()

    @property
    def seconds(self) -> float:
        return time.perf_counter() - self._t0

    def round(self, ndigits: int = 2) -> float:
        return round(self.seconds, ndigits)
