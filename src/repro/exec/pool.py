"""Deterministic process-pool fan-out for simulation workloads.

:func:`parallel_map` runs a picklable function over a list of items across
a ``fork`` process pool and returns results **in item order** — the same
list a serial ``[fn(x) for x in items]`` produces, which is what makes
``--jobs N`` observationally equivalent to ``--jobs 1`` (asserted by
``tests/test_perf_layer.py``): every window result is a pure function of
its plan key, so recomputing in a worker instead of hitting the parent's
warm cache yields bit-identical values.

Cache movement is two-way:

* **fork-time warmth** — workers are forked from the parent, so they start
  with the parent's in-memory :data:`~repro.core.noc.simcache.SIM_CACHE`
  (and every other memo) for free;
* **merge-on-return** — each task additionally ships the window-cache
  entries it created back to the parent, which merges them
  (:meth:`SimCache.merge`; duplicate keys carry identical values, so merge
  order cannot matter) so later sections and the persistent store see the
  union.

Fallbacks: ``jobs <= 1``, a single item, a platform without the ``fork``
start method (Windows), or a single schedulable CPU all run serially
in-process — the work is CPU-bound and deterministic, so forking on one
core can only add overhead, never overlap.  Forked pool workers exit via
``os._exit`` and therefore never trigger the persistent cache's atexit
merge — only the parent writes to disk.
"""
from __future__ import annotations

import multiprocessing
import os
from itertools import islice
from typing import Callable, Iterable, Optional, TypeVar

from repro.core.noc.simcache import SIM_CACHE

T = TypeVar("T")
R = TypeVar("R")

#: Set in pool workers; lets library code detect it runs inside a fan-out.
_IN_WORKER = False


def default_jobs(requested: Optional[int] = None) -> int:
    """Resolve a ``--jobs`` value: explicit N, else 0/None = all cores."""
    if requested is not None and requested > 0:
        return requested
    return max(1, os.cpu_count() or 1)


#: Start-method override.  ``fork`` is the default because it is what
#: makes fork-time cache warmth and test-local worker functions work; a
#: parent with heavy thread pools (e.g. JAX fully initialised) can set
#: ``REPRO_POOL_START=spawn``/``forkserver`` (workers then require
#: importable module-level callables and start cold) or ``serial`` to
#: disable fan-out entirely.
POOL_START_ENV = "REPRO_POOL_START"


def _effective_cpus() -> int:
    """CPUs this process may actually run on (cgroup/affinity aware)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:                          # pragma: no cover
        return os.cpu_count() or 1


def _fork_context():
    method = os.environ.get(POOL_START_ENV, "fork")
    if method == "serial":
        return None
    try:
        return multiprocessing.get_context(method)
    except ValueError:                              # pragma: no cover
        return None


def _run_task(payload):
    """Pool worker: run one task, return (result, new window-cache entries)."""
    global _IN_WORKER
    _IN_WORKER = True
    fn, item = payload
    before = len(SIM_CACHE._store)
    result = fn(item)
    # New entries are the insertion-ordered tail (the store never shrinks
    # inside a task); avoids hashing the whole store per task.
    delta = SIM_CACHE.export(
        list(islice(iter(SIM_CACHE._store), before, None)))
    return result, delta


def parallel_map(fn: Callable[[T], R], items: Iterable[T],
                 jobs: int = 1) -> list[R]:
    """``[fn(x) for x in items]`` across a fork pool, results in order.

    ``fn`` must be a module-level (picklable) callable and deterministic;
    window-cache entries created by workers are merged back into the
    parent cache.  Serial fallback keeps single-job runs allocation-free.
    """
    items = list(items)
    ctx = _fork_context()
    if jobs <= 1 or len(items) <= 1 or ctx is None or _IN_WORKER \
            or _effective_cpus() <= 1:
        return [fn(it) for it in items]
    with ctx.Pool(min(jobs, len(items))) as pool:
        out = pool.map(_run_task, [(fn, it) for it in items])
    results = []
    for result, delta in out:
        SIM_CACHE.merge(delta)
        results.append(result)
    return results
