"""Versioned on-disk plan store: repeated launches start warm.

Plans persist as one JSON file per plan key under ``results/.plans``
(sibling of the PR-4 ``results/.simcache`` window store; override with
``$REPRO_PLAN_DIR`` or an explicit directory).  The contract mirrors the
window store's:

* **schema-guarded** — every file carries :func:`~.plan.plan_schema_hash`;
  a mismatch (field drift, cost-model surface change, window-store schema
  bump) makes the file invisible (rebuild) instead of serving stale
  decisions;
* **atomic** — writes go through tempfile + ``os.replace``, so concurrent
  launches never observe a torn plan;
* **best-effort** — a missing/corrupt file is a cold start, never an
  error.

:meth:`PlanStore.get_or_build` is the one call consumers use: load when
warm (zero collective simulations — the acceptance criterion of this
layer), build + save when cold.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Optional

from repro.configs.base import ModelConfig
from repro.exec.timing import Stopwatch

from .plan import ExecutionPlan, plan_key, plan_schema_hash

#: Environment override for the store location (CLI flags take precedence).
PLAN_DIR_ENV = "REPRO_PLAN_DIR"

_DEFAULT_DIR = os.path.join("results", ".plans")


def default_plan_dir() -> str:
    """The store location honoring the environment override."""
    return os.environ.get(PLAN_DIR_ENV, _DEFAULT_DIR)


def add_plan_cli_args(ap) -> None:
    """The ``--psum-mode auto`` companion flags, shared by the launch CLIs
    (train/serve/dryrun) so the surface cannot drift between them."""
    ap.add_argument("--plan-dir", default=None, metavar="DIR",
                    help="ExecutionPlan store consulted by --psum-mode auto "
                         f"(default ${PLAN_DIR_ENV} or {_DEFAULT_DIR})")
    ap.add_argument("--no-plan", action="store_true",
                    help="auto mode without plans (per-site trace-time "
                         "resolution, the pre-plan behaviour)")


def launch_phase(shape) -> str:
    """Plan-phase label for a launch ShapeConfig.

    The canonical phase shapes (train_4k / prefill_32k / decode_32k) share
    the bare phase name, so dry-run cells and train/serve launches reuse
    each other's plans; any other shape keys by its full geometry — two
    CLI launches with different ``--batch``/``--seq`` must not collide on
    one plan file (the psum payloads differ).
    """
    from .builder import PHASE_SHAPES
    if PHASE_SHAPES.get(shape.kind) == shape.name:
        return shape.kind
    return (f"{shape.kind}-{shape.name}-"
            f"{shape.seq_len}x{shape.global_batch}")


def plan_for_launch(cfg: ModelConfig, mesh, shape, psum_mode: str,
                    plan_dir: Optional[str] = None, enabled: bool = True,
                    verbose: bool = True, **build_kwargs):
    """(plan, info) an ``--psum-mode auto`` launch should carry — or
    ``(None, None)`` when planning is off.

    Shared by the train/serve/dry-run drivers: persists the window cache
    (so cold plan builds warm the *next* launch), keys the plan via
    :func:`launch_phase`, and prints one status line.  ``info`` records
    the store behaviour (``from_store``, ``collective_sims``, timing) —
    the warm-store evidence the dry-run reports.
    """
    if psum_mode != "auto" or not enabled:
        return None, None
    from repro.core.noc.collective.cost import COST_STATS
    from repro.core.noc.simcache import SIM_CACHE
    if SIM_CACHE._persist_dir is None:
        # First launch-plan of the process wires persistence; re-calls
        # would re-parse the whole on-disk store per cell and retarget a
        # caller-configured cache dir.
        SIM_CACHE.persist(SIM_CACHE.persist_default_dir())
    store = PlanStore(plan_dir)
    runs0 = COST_STATS["engine_runs"]
    watch = Stopwatch()
    plan, built = store.get_or_build(cfg, mesh, launch_phase(shape),
                                     shape=shape, **build_kwargs)
    info = {"key": plan.key, "from_store": not built,
            "plan_s": watch.round(2),
            "collective_sims": COST_STATS["engine_runs"] - runs0,
            "psum": plan.psum_summary()}
    if verbose:
        src = "warm store" if info["from_store"] else "built"
        print(f"[plan] {plan.key}: {src} "
              f"({info['collective_sims']} collective sims) "
              f"modes={info['psum']['modes']}")
    return plan, info


class PlanStore:
    """Directory of schema-guarded ``ExecutionPlan`` JSON files."""

    def __init__(self, dir_path: Optional[str | Path] = None, *,
                 verify: bool = False) -> None:
        self.dir = Path(dir_path) if dir_path is not None \
            else Path(default_plan_dir())
        self.loads = 0
        self.builds = 0
        #: Opt-in hook: statically verify every loaded plan
        #: (``repro.analysis.verify_plan``) and raise on findings instead
        #: of serving a structurally invalid plan warm.
        self.verify = verify

    def path_for(self, key: str) -> Path:
        return self.dir / f"{key}.json"

    def load(self, key: str) -> Optional[ExecutionPlan]:
        """The stored plan for ``key``, or None (missing/corrupt/stale)."""
        try:
            doc = json.loads(self.path_for(key).read_text())
        except (OSError, ValueError):
            return None
        if doc.get("schema") != plan_schema_hash():
            return None
        try:
            plan = ExecutionPlan.from_dict(doc)
        except (KeyError, TypeError, ValueError):
            return None
        if self.verify:
            from repro.analysis.findings import VerificationError
            from repro.analysis.verify import verify_plan
            findings = verify_plan(plan)
            if findings:
                raise VerificationError(findings)
        self.loads += 1
        return plan

    def save(self, plan: ExecutionPlan) -> Path:
        """Atomically write ``plan``; returns the stored path."""
        from repro.core.noc.simcache import atomic_write_text
        self.dir.mkdir(parents=True, exist_ok=True)
        path = self.path_for(plan.key)
        atomic_write_text(path, plan.to_json())
        return path

    @staticmethod
    def _compatible(plan: ExecutionPlan, cfg: ModelConfig,
                    build_kwargs: dict) -> bool:
        """Was the stored plan built from this config, the way the caller
        is asking to build?

        The plan key deliberately covers only (model, mesh, phase, dtype);
        the config *content* (a registry edit keeps the name) and build
        parameters that change plan content — objective, mapper space,
        explicit token tile, gemm search on/off, a non-default NocConfig —
        are recorded in the plan and checked here, so a stale store can
        never silently answer a mismatched request: mismatch = cold =
        rebuild.
        """
        from repro.core.noc import NocConfig

        from .plan import config_digest
        if plan.config != config_digest(cfg):
            return False
        checks = {"objective": plan.objective, "tokens": plan.tokens}
        if build_kwargs.get("gemm_search", True):
            if not plan.gemms:
                return False
            checks["mapper_space"] = plan.mapper_space
        for key, have in checks.items():
            # None means "use the builder's derived default" (tokens=None
            # is documented API) — don't-care, matches whatever is stored.
            req = build_kwargs.get(key)
            if req is not None and req != have:
                return False
        # Chip topology is part of the plan key for chips > 1, but a
        # pre-hierarchy store could hold a 1-chip plan under the bare key
        # a multi-chip request would (wrongly) also resolve to if the key
        # scheme regressed — check content as well as filename.
        if plan.chips != build_kwargs.get("chips", 1):
            return False
        if plan.chips > 1 and \
                plan.package != build_kwargs.get("package", "mesh"):
            return False
        noc = repr(build_kwargs.get("noc_cfg") or NocConfig())
        return plan.noc == noc

    def get_or_build(self, cfg: ModelConfig, mesh_shape, phase: str,
                     **build_kwargs) -> tuple[ExecutionPlan, bool]:
        """(plan, built): load when warm, :func:`~.builder.build_plan` +
        save when cold.  ``build_kwargs`` forward to the builder; a stored
        plan built under different parameters (see :meth:`_compatible`)
        counts as cold and is rebuilt in place."""
        from .builder import build_plan, normalize_mesh
        key = plan_key(cfg.name, normalize_mesh(mesh_shape), phase,
                       str(cfg.dtype), build_kwargs.get("chips", 1),
                       build_kwargs.get("package", "mesh"))
        plan = self.load(key)
        if plan is not None and self._compatible(plan, cfg, build_kwargs):
            return plan, False
        plan = build_plan(cfg, mesh_shape, phase, **build_kwargs)
        self.save(plan)
        self.builds += 1
        return plan, True
