"""Pallas tile planning for the INA matmul kernel.

``kernels/ina_matmul.py`` historically hardcoded ``bm=bn=256, bk=512`` —
fine for the shapes its tests exercise, wrong (or outright failing the
divisibility assert) for the GEMM shapes real configs produce.  This module
turns the block choice into a planned decision with the TPU constraints
from the accelerator guide baked in:

* the MXU is a 128x128 systolic array and the lane dimension is always
  128, so blocks prefer multiples of 128 (falling back to the dtype's
  minimal sublane tile when a dimension is narrower or indivisible);
* x/w/acc blocks must fit VMEM (~16 MB/core) with headroom for the
  pipeline's double buffering, so ``bk`` shrinks first (the accumulator
  stays resident across the K grid — shrinking ``bm``/``bn`` would shrink
  the flushed tile instead).

Pure arithmetic — deterministic, no simulation — so tile planning adds
nothing to plan build time.
"""
from __future__ import annotations

import jax.numpy as jnp

#: Per-core VMEM budget for one grid step's working set.  Half of the
#: ~16 MB VMEM: the pipeline double-buffers the streamed x/w blocks.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

#: Minimal second-to-last-dim tile per dtype (sublane granularity).
_MIN_SUBLANE = {"float32": 8, "bfloat16": 16, "float16": 16,
                "int8": 32, "float8_e4m3fn": 32, "float8_e5m2": 32}

#: Upper bounds matching the kernel's historical defaults.
_TARGET_M = 256
_TARGET_N = 256
_TARGET_K = 512


def tile_policy_signature() -> tuple:
    """Everything a planned tile choice depends on besides the GEMM shape.

    Part of ``plan_schema_hash()``: changing any of these constants must
    invalidate persisted plans (stale tiles would otherwise be served
    warm)."""
    return (VMEM_BUDGET_BYTES, _TARGET_M, _TARGET_N, _TARGET_K,
            tuple(sorted(_MIN_SUBLANE.items())))


def _block(dim: int, target: int, align: int) -> int:
    """Largest divisor of ``dim`` that is <= ``target``.

    Prefers multiples of ``align`` (the MXU/lane granularity); when no
    aligned divisor exists (narrow or odd dims) the largest plain divisor
    wins — the kernel requires exact divisibility, alignment is advisory.
    """
    cap = min(target, dim)
    fallback = 1
    for b in range(cap, 0, -1):
        if dim % b:
            continue
        if b % align == 0:
            return b
        if fallback == 1:
            fallback = b
    return fallback


def tile_working_set(bm: int, bn: int, bk: int, dtype: str) -> int:
    """VMEM bytes one grid step holds for blocks ``(bm, bn, bk)``:
    double-buffered streamed x/w blocks plus the resident f32 accumulator
    and the output tile.  Shared by :func:`choose_tiles` and the static
    plan verifier (``repro.analysis.verify_plan``) so both sides price the
    same formula.
    """
    itemsize = jnp.dtype(dtype).itemsize
    stream = (bm * bk + bk * bn) * itemsize * 2         # double-buffered
    resident = bm * bn * 4 + bm * bn * itemsize         # acc + out tile
    return stream + resident


def choose_tiles(m: int, k: int, n: int, dtype: str = "bfloat16",
                 vmem_budget: int = VMEM_BUDGET_BYTES,
                 ) -> tuple[int, int, int]:
    """(bm, bn, bk) for ``[m, k] @ [k, n]`` under the kernel's constraints.

    Every returned block divides its dimension exactly (the kernel asserts
    this), targets the historical 256/256/512 ceilings, and fits the VMEM
    budget: ``bm*bk + bk*bn`` input bytes (double-buffered) plus the
    ``bm*bn`` f32 accumulator and output tile.
    """
    sublane = _MIN_SUBLANE.get(str(dtype), 8)
    bm = _block(m, _TARGET_M, 128 if m >= 128 else sublane)
    bn = _block(n, _TARGET_N, 128)
    bk = _block(k, _TARGET_K, 128)
    while tile_working_set(bm, bn, bk, dtype) > vmem_budget and bk > 1:
        bk = _block(k, bk // 2, 128 if bk > 128 else 1)
    return bm, bn, bk
