"""The planning pass: one abstract trace + one resolution sweep per plan.

:func:`build_plan` runs **once per (model config, mesh shape, phase,
dtype)** and produces an :class:`~.plan.ExecutionPlan` in three steps:

1. *Site collection* — the real model code is traced abstractly
   (``jax.eval_shape``; zero FLOPs, zero devices) with
   :func:`repro.core.collectives.record_psum_sites` active, so every
   ``mode="auto"`` psum site reports its (axis span, payload) instead of
   resolving itself.  Meshes of any shape trace on a single-CPU container
   via ``jax.sharding.AbstractMesh`` — the spans are what matter, not the
   devices.
2. *Resolution* — the deduplicated site shapes are costed once each
   through the NoC collective cost model (riding the persistent
   ``SIM_CACHE``, so a warm store resolves with zero engine runs) and the
   winning strategy recorded alongside the full candidate comparison.
3. *Mapper + tiles* — the config's decoder-block GEMMs get a PR-3 mapping
   search verdict (through the same sim cache) and a pallas tile choice
   (:mod:`.tiles`, pure arithmetic).

The builder imports jax lazily: the experiments CLI only pays for it when
the plan section actually runs.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.core.noc import NocConfig

from .plan import (ExecutionPlan, GemmVerdict, PsumDecision, TileChoice,
                   config_digest, plan_schema_hash)
from .tiles import choose_tiles

#: Phase -> the canonical ShapeConfig traced for it.
PHASES = ("train", "prefill", "decode")
PHASE_SHAPES = {"train": "train_4k", "prefill": "prefill_32k",
                "decode": "decode_32k"}


def normalize_mesh(mesh_shape) -> tuple[tuple[str, int], ...]:
    """((axis, span), ...) from a Mesh/AbstractMesh, dict, or pair list."""
    shape = getattr(mesh_shape, "shape", mesh_shape)
    if hasattr(shape, "items"):
        return tuple((str(a), int(s)) for a, s in shape.items())
    return tuple((str(a), int(s)) for a, s in shape)


def trace_mesh(mesh_shape):
    """A mesh to trace over: real meshes pass through, shapes become
    ``AbstractMesh`` (no devices needed — only axis spans drive planning)."""
    import jax
    if isinstance(mesh_shape, jax.sharding.Mesh):
        return mesh_shape
    abstract = getattr(jax.sharding, "AbstractMesh", None)
    if abstract is None:                      # pragma: no cover - old jax
        raise RuntimeError("planning without a concrete mesh needs "
                           "jax.sharding.AbstractMesh")
    return abstract(normalize_mesh(mesh_shape))


def phase_shape(phase: str, shape: Optional[ShapeConfig] = None,
                ) -> ShapeConfig:
    if shape is not None:
        return shape
    if phase not in PHASE_SHAPES:
        raise ValueError(f"unknown phase {phase!r}; pick from {PHASES}")
    return SHAPES[PHASE_SHAPES[phase]]


def collect_psum_sites(cfg: ModelConfig, mesh, shape: ShapeConfig,
                       pctx=None) -> list:
    """Abstract-trace one phase and return its recorded ``PsumSite`` list."""
    import jax
    from repro.core.collectives import record_psum_sites
    from repro.models.api import get_model
    from repro.parallel.tp import ParallelCtx

    model = get_model(cfg)
    if pctx is None:
        pctx = ParallelCtx(mesh=mesh, psum_mode="auto")
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = model.input_specs(shape)
    with record_psum_sites() as sites:
        if shape.kind == "train":
            jax.eval_shape(lambda p, b: model.loss(p, b, pctx),
                           pshapes, batch)
        elif shape.kind == "prefill":
            jax.eval_shape(lambda p, b: model.forward(p, b, pctx),
                           pshapes, batch)
        else:
            cshapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            jax.eval_shape(
                lambda p, b, c: model.decode_step(p, b, c, pctx),
                pshapes, batch, cshapes)
    return sites


def resolve_sites(sites: Sequence, objective: str = "latency",
                  noc_cfg: NocConfig = NocConfig(), *,
                  chips: int = 1, package: str = "mesh",
                  ) -> tuple[PsumDecision, ...]:
    """Dedup recorded sites and cost each distinct shape exactly once.

    Resolution calls the same ``choose_psum_mode`` the planless fallback
    uses (same defaults, same tie-breaks), so a plan-driven run picks
    bit-identical strategies to today's per-call-site auto path.  With
    ``chips`` > 1 the TP axis spans chips and every site is priced
    through the hierarchical facade (DESIGN.md S14): intra-chip rows plus
    a package-level allreduce, same candidate set and tie-breaks.
    """
    from repro.core.noc.collective.cost import AUTO_CANDIDATES
    if chips > 1:
        from repro.core.noc.hierarchy import (choose_hier_psum_mode,
                                              hier_psum_mode_costs)

        def _costs(p, nbytes):
            return hier_psum_mode_costs(p, nbytes, noc_cfg, chips=chips,
                                        package=package)

        def _choose(p, nbytes):
            return choose_hier_psum_mode(p, nbytes, noc_cfg, chips=chips,
                                         package=package,
                                         objective=objective)
    else:
        from repro.core.noc.collective.cost import (choose_psum_mode,
                                                    psum_mode_costs)

        def _costs(p, nbytes):
            return psum_mode_costs(p, nbytes, noc_cfg)

        def _choose(p, nbytes):
            return choose_psum_mode(p, nbytes, noc_cfg, objective=objective)

    groups: dict[tuple[int, int], dict] = {}
    for s in sites:
        g = groups.setdefault((s.p, s.nbytes), {"count": 0, "ops": set()})
        g["count"] += 1
        g["ops"].add(s.op)
    out = []
    for (p, nbytes), g in sorted(groups.items()):
        costs = _costs(p, nbytes)
        mode = _choose(p, nbytes)
        out.append(PsumDecision(
            p=p, nbytes=nbytes, mode=mode,
            ops=tuple(sorted(g["ops"])), count=g["count"],
            costs=tuple((m, costs[m].latency_cycles, costs[m].energy_pj)
                        for m in AUTO_CANDIDATES)))
    return tuple(out)


#: (cfg, tokens, mapper_space) -> gemm_verdicts result.  Verdicts are a
#: pure function of those three (deterministic search; ``jobs`` only
#: parallelizes, PR-4's jobs-identity test), and train/prefill phases
#: share tokens=256 — without the memo every full plan sweep would run
#: the same search once per phase.
_GEMM_MEMO: dict = {}


def gemm_verdicts(cfg: ModelConfig, tokens: int, mapper_space: str = "quick",
                  jobs: int = 1,
                  ) -> tuple[tuple[GemmVerdict, ...],
                             Optional[tuple[int, int, int]]]:
    """Mapper search over the config's decoder-block GEMMs (PR-3 path)."""
    from repro.mapper import MapperConfig, QUICK_MAPPER, search_network
    from repro.models.api import get_model

    memo_key = (cfg, tokens, mapper_space)
    hit = _GEMM_MEMO.get(memo_key)
    if hit is not None:
        return hit
    layers = get_model(cfg).gemm_layers(tokens)
    mcfg = QUICK_MAPPER if mapper_space == "quick" else MapperConfig()
    out = search_network(f"{cfg.name}:gemm", layers, mcfg, jobs=jobs)
    by_name = {l.name: l for l in layers}
    verdicts = []
    for a, b in zip(out.best.assignments, out.baseline.assignments):
        layer = by_name[a.layer]
        verdicts.append(GemmVerdict(
            layer=a.layer, M=layer.M, K=layer.K, N=layer.N,
            mapping=a.mapping.label(), dataflow=a.mapping.dataflow,
            semantics=a.mapping.semantics,
            latency_cycles=a.latency_cycles, energy_pj=a.total_energy_pj,
            baseline_latency_cycles=b.latency_cycles,
            baseline_energy_pj=b.total_energy_pj))
    _GEMM_MEMO[memo_key] = (tuple(verdicts), out.best.hardware)
    return _GEMM_MEMO[memo_key]


def tile_choices(cfg: ModelConfig, tokens: int,
                 dtype: str) -> tuple[TileChoice, ...]:
    """Deduplicated pallas tile plan over the config's GEMM shapes."""
    from repro.models.api import get_model
    out, seen = [], set()
    for layer in get_model(cfg).gemm_layers(tokens):
        key = (layer.M, layer.K, layer.N, dtype)
        if key in seen:
            continue
        seen.add(key)
        bm, bn, bk = choose_tiles(layer.M, layer.K, layer.N, dtype)
        out.append(TileChoice(m=layer.M, k=layer.K, n=layer.N, dtype=dtype,
                              bm=bm, bn=bn, bk=bk))
    return tuple(sorted(out, key=lambda t: (t.m, t.k, t.n)))


def build_plan(cfg: ModelConfig, mesh_shape, phase: str, *,
               objective: str = "latency",
               mapper_space: str = "quick",
               gemm_search: bool = True,
               tokens: Optional[int] = None,
               shape: Optional[ShapeConfig] = None,
               noc_cfg: NocConfig = NocConfig(),
               jobs: int = 1,
               chips: int = 1,
               package: str = "mesh",
               pctx=None) -> ExecutionPlan:
    """One planning pass -> a frozen, serializable :class:`ExecutionPlan`.

    ``mesh_shape`` is a Mesh, AbstractMesh, dict, or (axis, span) pairs;
    ``tokens`` defaults to the mapper's 256-token M tile for train/prefill
    and the batch width for decode (a decode GEMM runs one token per
    sequence).  ``gemm_search=False`` skips the mapper verdicts (tile and
    psum planning keep working) for callers that only consume the runtime
    half.  ``chips`` > 1 prices every psum site on a mesh-of-meshes
    (``package`` selects the cross-chip fabric, DESIGN.md S14) and stamps
    the chip topology into the plan identity.
    """
    shape = phase_shape(phase, shape)
    mesh = normalize_mesh(mesh_shape)
    if tokens is None:
        tokens = shape.global_batch if shape.kind == "decode" else 256
    dtype = str(cfg.dtype)

    sites = collect_psum_sites(cfg, trace_mesh(mesh_shape), shape, pctx=pctx)
    psum = resolve_sites(sites, objective=objective, noc_cfg=noc_cfg,
                         chips=chips, package=package)
    if gemm_search:
        gemms, hardware = gemm_verdicts(cfg, tokens, mapper_space, jobs=jobs)
    else:
        gemms, hardware = (), None
    tiles = tile_choices(cfg, tokens, dtype)

    return ExecutionPlan(
        model=cfg.name, mesh=mesh, phase=phase, dtype=dtype,
        schema=plan_schema_hash(), objective=objective,
        psum=psum, gemms=gemms, tiles=tiles,
        mapper_hardware=hardware, mapper_space=mapper_space, tokens=tokens,
        noc=repr(noc_cfg), config=config_digest(cfg),
        chips=chips, package=package)
