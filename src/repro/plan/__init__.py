"""ExecutionPlan layer: one planning pass for the whole jax execution half.

This package inverts the control flow of the runtime (DESIGN.md S11):
instead of every ``psum_with_mode(mode="auto")`` call site consulting the
NoC cost model mid-trace, the mapper's verdicts dying in a report, and
pallas tiles being hardcoded, a single pass per (model config, mesh shape,
phase, dtype) — :func:`~.builder.build_plan` — decides all three and emits
a frozen, byte-deterministic, persistable :class:`~.plan.ExecutionPlan`.
Consumers (``ParallelCtx``, ``core.collectives``, ``kernels.ina_matmul``)
*read* the plan; the old trace-time path survives as the planless
fallback.

Produce/persist: :class:`~.store.PlanStore` (``results/.plans``).
Inspect: ``python -m repro.experiments --section plan`` (EXPERIMENTS.md).
"""
from .builder import (PHASES, PHASE_SHAPES, build_plan, collect_psum_sites,
                      gemm_verdicts, normalize_mesh, phase_shape,
                      resolve_sites, tile_choices, trace_mesh)
from .plan import (ExecutionPlan, GemmVerdict, PLAN_SCHEMA_VERSION,
                   PsumDecision, TileChoice, plan_key, plan_schema_hash)
from .store import (PLAN_DIR_ENV, PlanStore, add_plan_cli_args,
                    default_plan_dir, launch_phase, plan_for_launch)
from .tiles import choose_tiles

__all__ = [
    "ExecutionPlan", "PsumDecision", "GemmVerdict", "TileChoice",
    "PLAN_SCHEMA_VERSION", "plan_key", "plan_schema_hash",
    "PHASES", "PHASE_SHAPES", "build_plan", "collect_psum_sites",
    "gemm_verdicts", "normalize_mesh", "phase_shape", "resolve_sites",
    "tile_choices", "trace_mesh",
    "PlanStore", "PLAN_DIR_ENV", "add_plan_cli_args", "default_plan_dir",
    "launch_phase", "plan_for_launch",
    "choose_tiles",
]
