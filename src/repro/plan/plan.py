"""The :class:`ExecutionPlan` artifact: every launch-time decision, decided once.

Before this layer, the three decision mechanisms of the jax execution half
never talked to each other (DESIGN.md S11): ``psum_with_mode(mode="auto")``
re-consulted the NoC cost model per call site at trace time, the mapper's
:class:`~repro.mapper.NetworkSchedule` verdicts stopped at the experiments
report, and pallas tile sizes were constants in ``kernels/ina_matmul.py``.
An ``ExecutionPlan`` is the single artifact that carries all three:

* ``psum``   — per-site accumulation strategy (Fig. 4 in-network vs
  eject/inject), resolved through the collective cost model once per
  distinct (axis span, payload) shape;
* ``gemms``  — per-GEMM mapper verdicts (searched mapping vs the paper's
  fixed placement, riding the PR-3 search and the PR-2/PR-4 sim cache);
* ``tiles``  — per-kernel pallas block choices for ``ina_matmul``,
  consumed by the TPU fast path (``kernels/ops.matmul(plan=...)``; the
  CPU dry-run models trace plain einsums, so on this container the tiles
  section is exercised by tests and carried for the TPU deployment).

Plans are frozen, hashable, and serialize to *byte-deterministic* JSON, so
they are cacheable (``plan.store``), diffable in review, and safe to hand
to ``ParallelCtx`` (itself a frozen dataclass).  A schema hash over the
field layout plus the cost-model surface guards persisted plans the same
way the window store guards simulation rows.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from functools import cached_property
from typing import Optional

#: Bump when plan semantics change in a way field lists cannot see.
PLAN_SCHEMA_VERSION = 1


def plan_schema_hash() -> str:
    """Hash of everything a persisted plan structurally depends on.

    Covers the plan field layout, the auto-candidate set, the NoC config
    surface the decisions were costed under, the window-store schema
    (plans and simulation rows must invalidate together — a cost-model
    change re-keys both), the tile-policy constants, and the mapper
    search-space defaults (changing any of them changes plan *content*,
    so stale stores must go cold, never serve old decisions).
    """
    from repro.core.noc.collective.cost import (AUTO_CANDIDATES,
                                                PSUM_MODE_LOWERING)
    from repro.core.noc.router import NocConfig
    from repro.core.noc.simcache import schema_hash as sim_schema_hash
    from repro.mapper import MapperConfig, QUICK_MAPPER
    from .tiles import tile_policy_signature
    parts = (PLAN_SCHEMA_VERSION,
             tuple(PsumDecision.__dataclass_fields__),
             tuple(GemmVerdict.__dataclass_fields__),
             tuple(TileChoice.__dataclass_fields__),
             tuple(ExecutionPlan.__dataclass_fields__),
             AUTO_CANDIDATES,
             tuple(sorted(PSUM_MODE_LOWERING.items())),
             tuple(NocConfig.__dataclass_fields__),
             sim_schema_hash(),
             tile_policy_signature(),
             repr(MapperConfig()), repr(QUICK_MAPPER))
    return hashlib.sha1(repr(parts).encode()).hexdigest()[:16]


def config_digest(cfg) -> str:
    """Content digest of a ModelConfig (frozen dataclass: repr is total).

    Stored in the plan and checked by ``PlanStore._compatible``: editing a
    registry config (d_ff, n_heads, ...) changes every traced site, so the
    old plan must go cold — the filename key stays readable (model name),
    the digest carries the content identity.
    """
    return hashlib.sha1(repr(cfg).encode()).hexdigest()[:12]


def plan_key(model: str, mesh: tuple[tuple[str, int], ...], phase: str,
             dtype: str, chips: int = 1, package: str = "mesh") -> str:
    """Filesystem-safe identity of one plan's inputs (the store filename).

    Multi-chip plans get a ``__cN[e]`` suffix (``e`` = express package) so
    they store alongside — never shadow — the single-chip plan for the
    same (model, mesh, phase, dtype) cell; 1-chip keys are unchanged from
    pre-hierarchy stores.
    """
    mesh_s = "x".join(f"{a}{s}" for a, s in mesh)
    raw = f"{model}__{mesh_s}__{phase}__{dtype}"
    if chips > 1:
        raw += f"__c{chips}" + ("e" if package == "express" else "")
    return "".join(c if c.isalnum() or c in "._-" else "-" for c in raw)


@dataclass(frozen=True)
class PsumDecision:
    """Resolved strategy for one distinct psum-site shape.

    ``costs`` carries the full simulated candidate comparison —
    ``((mode, latency_cycles, energy_pj), ...)`` in candidate order — so a
    plan documents *why* a site chose its mode, not just the answer.
    """

    p: int                        # axis span
    nbytes: int                   # per-device payload
    mode: str                     # resolved PsumMode (pre divisibility guard)
    ops: tuple[str, ...]          # site kinds mapped here ("psum", ...)
    count: int                    # how many call sites share this shape
    costs: tuple[tuple[str, int, float], ...] = ()

    @property
    def cost_of(self) -> dict:
        return {m: (lat, en) for m, lat, en in self.costs}


@dataclass(frozen=True)
class GemmVerdict:
    """One GEMM's mapper verdict: searched mapping vs the paper placement."""

    layer: str
    M: int
    K: int
    N: int
    mapping: str                  # Mapping.label() of the chosen placement
    dataflow: str                 # "ws" | "os"
    semantics: str                # "ina" | "eject_inject"
    latency_cycles: float
    energy_pj: float
    baseline_latency_cycles: float
    baseline_energy_pj: float

    @property
    def latency_x(self) -> float:
        return self.baseline_latency_cycles / max(self.latency_cycles, 1.0)

    @property
    def energy_x(self) -> float:
        return self.baseline_energy_pj / max(self.energy_pj, 1.0)


@dataclass(frozen=True)
class TileChoice:
    """Pallas block sizes for one ``ina_matmul`` problem shape."""

    m: int
    k: int
    n: int
    dtype: str
    bm: int
    bn: int
    bk: int

    @property
    def tiles(self) -> tuple[int, int, int]:
        return (self.bm, self.bn, self.bk)


@dataclass(frozen=True)
class ExecutionPlan:
    """One planning pass over (model config, mesh shape, phase, dtype)."""

    model: str
    mesh: tuple[tuple[str, int], ...]      # ((axis, span), ...) in mesh order
    phase: str                             # "train" | "prefill" | "decode"
    dtype: str                             # activation/compute dtype
    schema: str = field(default_factory=plan_schema_hash)
    objective: str = "latency"
    psum: tuple[PsumDecision, ...] = ()
    gemms: tuple[GemmVerdict, ...] = ()
    tiles: tuple[TileChoice, ...] = ()
    mapper_hardware: Optional[tuple[int, int, int]] = None
    mapper_space: str = "quick"
    tokens: int = 256                      # GEMM M tile the verdicts/tiles use
    noc: str = ""                          # repr(NocConfig) decisions cost under
    config: str = ""                       # config_digest(cfg) traced from
    #: Chip topology the psum decisions were costed on (DESIGN.md S14):
    #: ``chips`` > 1 means every TP axis is split across that many chips
    #: and the decisions price intra-chip + package levels.
    chips: int = 1
    package: str = "mesh"                  # package variant ("mesh"|"express")

    # ------------------------------------------------------------------ #
    # Consumer lookups (the hot path: O(1) dict probes, indexes built once)
    # ------------------------------------------------------------------ #
    @cached_property
    def _psum_index(self) -> dict:
        return {(d.p, d.nbytes): d.mode for d in self.psum}

    @cached_property
    def _tile_index(self) -> dict:
        return {(t.m, t.k, t.n, t.dtype): t.tiles for t in self.tiles}

    def psum_mode(self, p: int, nbytes: int) -> Optional[str]:
        """Strategy for a (span, payload) site; None = site not planned
        (the caller falls back to trace-time resolution)."""
        return self._psum_index.get((p, int(nbytes)))

    def tile_for(self, m: int, k: int, n: int,
                 dtype: str) -> Optional[tuple[int, int, int]]:
        """(bm, bn, bk) for an ``ina_matmul`` shape; None = not planned."""
        return self._tile_index.get((m, k, n, str(dtype)))

    @property
    def key(self) -> str:
        """Filesystem-safe identity of this plan's inputs (store filename)."""
        return plan_key(self.model, self.mesh, self.phase, self.dtype,
                        self.chips, self.package)

    @property
    def site_count(self) -> int:
        return sum(d.count for d in self.psum)

    def psum_summary(self) -> dict:
        """Histogram + predicted deltas vs the Fig. 4(a) baseline.

        ``latency_delta_x`` / ``energy_delta_x`` weight each distinct site
        by its call-site count: what the whole model's accumulation traffic
        gains over running every site eject/inject.
        """
        modes: dict[str, int] = {}
        chosen_lat = base_lat = chosen_en = base_en = 0.0
        for d in self.psum:
            modes[d.mode] = modes.get(d.mode, 0) + d.count
            cost = d.cost_of
            if d.mode in cost and "eject_inject" in cost:
                chosen_lat += cost[d.mode][0] * d.count
                chosen_en += cost[d.mode][1] * d.count
                base_lat += cost["eject_inject"][0] * d.count
                base_en += cost["eject_inject"][1] * d.count
        return {
            "sites": self.site_count,
            "distinct": len(self.psum),
            "modes": dict(sorted(modes.items())),
            "latency_delta_x": base_lat / chosen_lat if chosen_lat else 1.0,
            "energy_delta_x": base_en / chosen_en if chosen_en else 1.0,
        }

    # ------------------------------------------------------------------ #
    # Serialization (byte-deterministic: sorted keys, fixed separators)
    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=1)

    @classmethod
    def from_dict(cls, d: dict) -> "ExecutionPlan":
        return cls(
            model=d["model"],
            mesh=tuple((a, s) for a, s in d["mesh"]),
            phase=d["phase"], dtype=d["dtype"], schema=d["schema"],
            objective=d["objective"],
            psum=tuple(PsumDecision(
                p=r["p"], nbytes=r["nbytes"], mode=r["mode"],
                ops=tuple(r["ops"]), count=r["count"],
                costs=tuple((m, lat, en) for m, lat, en in r["costs"]))
                for r in d["psum"]),
            gemms=tuple(GemmVerdict(**r) for r in d["gemms"]),
            tiles=tuple(TileChoice(**r) for r in d["tiles"]),
            mapper_hardware=tuple(d["mapper_hardware"])
            if d.get("mapper_hardware") else None,
            mapper_space=d["mapper_space"], tokens=d["tokens"],
            noc=d.get("noc", ""), config=d.get("config", ""),
            chips=d.get("chips", 1), package=d.get("package", "mesh"))

    @classmethod
    def from_json(cls, text: str) -> "ExecutionPlan":
        return cls.from_dict(json.loads(text))
