"""Version-compat shims for the jax API surface this repo uses.

``shard_map`` moved twice across jax releases:

* old:  ``jax.experimental.shard_map.shard_map(f, mesh, in_specs, out_specs,
        check_rep=..., auto=...)`` — manual axes are *all* mesh axes except
        ``auto``.
* new:  ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
        axis_names=..., check_vma=...)`` — manual axes are exactly
        ``axis_names``.

Every module in this repo imports ``shard_map`` from here and uses the *new*
keyword surface (``axis_names`` / ``check_vma``); this shim translates to
whichever implementation the installed jax provides.  ``axis_size`` (missing
from old ``jax.lax``) is shimmed the same way.
"""
from __future__ import annotations

from typing import Any, Optional

import jax


def compiled_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions.

    Old jax returns a one-element list of per-module dicts; new jax
    returns the dict directly.  Callers always want the dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def pallas_tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` across its rename from ``TPUCompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:  # pragma: no cover - depends on installed jax
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name) -> int:
        """``jax.lax.axis_size`` fallback: psum of a concrete 1 is evaluated
        statically, so this returns a Python int even under tracing."""
        return jax.lax.psum(1, axis_name)

try:  # jax >= 0.6-style top-level export
    from jax import shard_map as _new_shard_map  # type: ignore[attr-defined]
    _OLD_SHARD_MAP = None
except ImportError:  # pragma: no cover - depends on installed jax
    _new_shard_map = None
    from jax.experimental.shard_map import shard_map as _OLD_SHARD_MAP


def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
              axis_names: Optional[Any] = None,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None,
              **kwargs):
    """Portable ``shard_map`` accepting the new-API keyword surface."""
    if _new_shard_map is not None:
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        elif check_rep is not None:
            kwargs["check_vma"] = check_rep
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)

    # Old jax: partial-manual regions (``auto=`` axes) miscompile
    # ``axis_index``/``ppermute`` bodies (PartitionId rejected by the SPMD
    # partitioner).  Fall back to a fully-manual region instead: axes the
    # specs never mention are treated as replicated, which is numerically
    # identical (the boundary reshard gathers/re-scatters them).
    kwargs.pop("auto", None)
    rep = True
    if check_vma is not None:
        rep = check_vma
    elif check_rep is not None:
        rep = check_rep
    return _OLD_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=rep,
                          **kwargs)
