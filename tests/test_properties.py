"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import assume, given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.ina_model import ConvLayer, ina_rounds, needs_ina, p_num
from repro.core.collectives import per_link_bytes
from repro.core.noc import NocConfig, NocSim
from repro.parallel.sharding import fit_spec

# --------------------------------------------------------------------------- #
# INA analytical model invariants
# --------------------------------------------------------------------------- #
layer_st = st.builds(
    ConvLayer,
    name=st.just("L"),
    R=st.sampled_from([1, 3, 5, 7, 11]),
    C=st.integers(1, 2048),
    F=st.integers(1, 2048),
    O=st.integers(1, 256),
)


@settings(max_examples=200, deadline=None)
@given(layer_st)
def test_pnum_consistent_with_eq1(layer):
    """P# > 1 exactly when Eq. (1) says INA is needed."""
    assert (p_num(layer) > 1) == needs_ina(layer)


@settings(max_examples=200, deadline=None)
@given(layer_st, st.sampled_from([4, 8, 16]))
def test_ina_rounds_monotonic_in_mesh(layer, n):
    """A bigger mesh never needs more rounds."""
    assume(needs_ina(layer))
    assume(p_num(layer) <= n)
    r_small = ina_rounds(layer, n)
    r_big = ina_rounds(layer, 2 * n)
    assert r_big <= r_small


@settings(max_examples=100, deadline=None)
@given(layer_st, st.sampled_from([1, 2, 4, 8]))
def test_more_pes_fewer_rounds(layer, e):
    assume(needs_ina(layer) and p_num(layer) <= 8)
    r1 = ina_rounds(layer, 8, 1)
    re = ina_rounds(layer, 8, e)
    assert re <= r1
    assert re >= r1 / e - 1          # cannot be better than linear scaling


# --------------------------------------------------------------------------- #
# NoC simulator invariants
# --------------------------------------------------------------------------- #
@settings(max_examples=50, deadline=None)
@given(st.integers(0, 7), st.integers(0, 7), st.integers(0, 7),
       st.integers(0, 7), st.integers(1, 9))
def test_packet_latency_lower_bound(x1, y1, x2, y2, flits):
    """head latency >= hops*(router+link) + endpoints; tail adds flits-1."""
    cfg = NocConfig()
    sim = NocSim(cfg)
    done = {}
    sim.enqueue(0, (x1, y1), (x2, y2), flits,
                on_done=lambda t: done.setdefault("t", t))
    sim.run()
    hops = abs(x2 - x1) + abs(y2 - y1)
    lower = 2 * cfg.ni_cycles + hops * (cfg.router_cycles + cfg.link_cycles) \
        + cfg.router_cycles + flits - 1
    assert done["t"] >= lower
    # uncontended: exact
    assert done["t"] == lower


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(1, 9))
def test_contention_monotone_in_load(n_pkts, flits):
    """More packets on the same path never reduce the makespan."""
    cfg = NocConfig()
    def makespan(n):
        sim = NocSim(cfg)
        for _ in range(n):
            sim.enqueue(0, (0, 0), (0, 7), flits)
        return sim.run()
    assert makespan(n_pkts + 1) >= makespan(n_pkts)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.integers(2, 9))
def test_ina_chain_never_slower(p, flits):
    """Relay (eject/inject) is never faster than a single riding packet."""
    cfg = NocConfig()
    chain = [(0, y) for y in range(p)]
    sim1 = NocSim(cfg)
    done = {}
    sim1.chain_eject_inject(0, chain, flits,
                            on_done=lambda t: done.setdefault("relay", t))
    sim1.run()
    sim2 = NocSim(cfg)
    sim2.enqueue(0, chain[0], chain[-1], flits,
                 on_done=lambda t: done.setdefault("ina", t))
    sim2.run()
    assert done["ina"] <= done["relay"]


# --------------------------------------------------------------------------- #
# collective traffic model invariants
# --------------------------------------------------------------------------- #
@settings(max_examples=100, deadline=None)
@given(st.integers(2, 512), st.integers(1, 2 ** 24))
def test_ina_traffic_always_wins(p, nbytes):
    ej = per_link_bytes("eject_inject", p, nbytes)
    ina_full = per_link_bytes("ina", p, nbytes, need_full=True)
    ina_rs = per_link_bytes("ina", p, nbytes, need_full=False)
    assert ina_rs <= ina_full <= ej
    if p > 2:
        assert ina_full < ej


# --------------------------------------------------------------------------- #
# sharding fitter invariants
# --------------------------------------------------------------------------- #
@settings(max_examples=100, deadline=None)
@given(st.lists(st.sampled_from([1, 2, 3, 8, 15, 16, 64, 128, 524288]),
                min_size=1, max_size=5))
def test_fit_spec_always_valid(dims):
    """Fitted specs always divide their dims; axes never duplicated."""
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = Mesh(devs, ("data", "model"))
    spec = P(*(["data", "model"] * 3)[:len(dims)])
    fitted = fit_spec(spec, tuple(dims), mesh)
    seen = []
    for size, entry in zip(dims, tuple(fitted) + (None,) * len(dims)):
        axes = entry if isinstance(entry, tuple) else (
            () if entry is None else (entry,))
        span = 1
        for a in axes:
            assert a not in seen
            seen.append(a)
            span *= mesh.shape[a]
        assert size % span == 0
