"""Sweep subsystem: headline-ratio pins, cache transparency, CLI artifacts.

Three layers of guarantees for ``repro.experiments``:

1. Reproduction pins — the Figs 7-9 / 10-12 improvement ratios for all three
   workloads at (e_pes=1, sim_rounds=16, default cfg) are pinned exactly, so
   refactors cannot silently drift the paper reproduction.
2. Cache transparency — the plan-keyed window cache
   (:mod:`repro.core.noc.simcache`) returns bit-identical
   :class:`LayerResult` fields to a cache-disabled ground-truth run, across
   workloads, modes and E values, and actually collapses repeated plan
   shapes (hits >> misses on ResNet-50).
3. Artifact contract — ``run_all`` writes per-figure JSON, ``summary.md``
   and the legacy ``benchmarks.csv`` the CI sweep-smoke job uploads.
"""
import dataclasses
import json

import pytest

from repro.core.noc import NocConfig, SIM_CACHE, sim_cache_disabled
from repro.core.noc.power import ws_ina_improvement, ws_vs_os_improvement
from repro.core.noc.traffic import MODES, simulate_layer, simulate_network
from repro.core.workloads import ALEXNET, RESNET50, VGG16, WORKLOADS
from repro.experiments import SweepConfig, run_all, run_fig7_9
from repro.experiments.sweeps import (fig7_9_csv_lines, fig10_12_csv_lines,
                                      tables_csv_lines)

CFG = NocConfig()

# --------------------------------------------------------------------------- #
# 1. Headline-ratio pins: (latency_x, power_x, energy_x) per workload at
#    e_pes=1, sim_rounds=16, default cfg.  fig7_9 values equal the seed pins
#    in tests/test_noc_collective.py by construction (cache transparency).
# --------------------------------------------------------------------------- #
FIG7_9_PINS = {
    "alexnet": (1.3174422192115254, 1.5607175433789333, 2.056155183911502),
    "vgg16": (1.7419385086187669, 1.1141116323217497, 1.9407139552413686),
    "resnet50": (1.1205548873901459, 1.095398960338809, 1.227454658649737),
}
FIG10_12_PINS = {
    "alexnet": (1.092087802270031, 1.718684924481257, 1.876954841971371),
    "vgg16": (1.445953875070858, 1.111861273869205, 1.607700117492398),
    "resnet50": (0.7179804315656954, 1.853857557221294, 1.33103344899507),
}


@pytest.mark.parametrize("workload", sorted(FIG7_9_PINS), ids=str)
def test_fig7_9_headline_pins(workload):
    imp = ws_ina_improvement(workload, WORKLOADS[workload], 1, CFG,
                             sim_rounds=16)
    lat, pwr, en = FIG7_9_PINS[workload]
    assert imp.latency_x == pytest.approx(lat, rel=1e-9)
    assert imp.power_x == pytest.approx(pwr, rel=1e-9)
    assert imp.energy_x == pytest.approx(en, rel=1e-9)


@pytest.mark.parametrize("workload", sorted(FIG10_12_PINS), ids=str)
def test_fig10_12_headline_pins(workload):
    imp = ws_vs_os_improvement(workload, WORKLOADS[workload], 1, CFG,
                               sim_rounds=16)
    lat, pwr, en = FIG10_12_PINS[workload]
    assert imp.latency_x == pytest.approx(lat, rel=1e-9)
    assert imp.power_x == pytest.approx(pwr, rel=1e-9)
    assert imp.energy_x == pytest.approx(en, rel=1e-9)


def test_sweep_rows_match_power_helpers():
    """The sweep engine reports exactly what the power helpers compute."""
    sweep = SweepConfig(e_list=(1,), sim_rounds=16)
    rows = {r["workload"]: r for r in run_fig7_9(sweep)["rows"]}
    for name, (lat, pwr, en) in FIG7_9_PINS.items():
        assert rows[name]["latency_x"] == pytest.approx(lat, rel=1e-9)
        assert rows[name]["power_x"] == pytest.approx(pwr, rel=1e-9)
        assert rows[name]["energy_x"] == pytest.approx(en, rel=1e-9)


# --------------------------------------------------------------------------- #
# 2. Cache transparency + effectiveness
# --------------------------------------------------------------------------- #
# A cross-section of plan shapes: split chains (P#>1), the P#=1 degenerate
# gather, and a ResNet bottleneck layer, per workload.
SAMPLE_LAYERS = [ALEXNET[0], ALEXNET[3], VGG16[8], RESNET50[0], RESNET50[5]]


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("layer", SAMPLE_LAYERS, ids=lambda l: l.name)
def test_cache_transparency_bit_identical(layer, mode):
    """Cached and ground-truth runs agree on every LayerResult field."""
    e_pes = 2
    with sim_cache_disabled():
        truth = simulate_layer(layer, mode, CFG, e_pes, sim_rounds=8)
    SIM_CACHE.clear()
    cold = simulate_layer(layer, mode, CFG, e_pes, sim_rounds=8)   # fills
    warm = simulate_layer(layer, mode, CFG, e_pes, sim_rounds=8)   # hits
    for r in (cold, warm):
        assert dataclasses.asdict(r) == dataclasses.asdict(truth), mode


def test_cache_collapses_resnet50_to_distinct_plan_shapes():
    """~53 ResNet-50 layers share a handful of window programs."""
    SIM_CACHE.clear()
    simulate_network(RESNET50, "ws_ina", CFG, 1, sim_rounds=8)
    stats = SIM_CACHE.stats()
    assert stats["misses"] < 2 * len(RESNET50) / 3   # distinct shapes only
    assert stats["hits"] > stats["misses"]           # repeats were collapsed
    # Ledger copies: mutating a returned ledger must not corrupt the cache.
    r1 = simulate_layer(RESNET50[0], "ws_ina", CFG, 1, sim_rounds=8)
    r2 = simulate_layer(RESNET50[0], "ws_ina", CFG, 1, sim_rounds=8)
    assert r1.noc_energy_pj == r2.noc_energy_pj


def test_cache_key_includes_config():
    """A NocConfig change is a different key — no stale entries served."""
    SIM_CACHE.clear()
    small = dataclasses.replace(CFG, n=4)
    a = simulate_layer(ALEXNET[1], "ws_ina", CFG, 1, sim_rounds=4)
    b = simulate_layer(ALEXNET[1], "ws_ina", small, 1, sim_rounds=4)
    assert a.latency_cycles != b.latency_cycles
    assert SIM_CACHE.stats()["hits"] == 0


# --------------------------------------------------------------------------- #
# 3. Artifact contract (run_all + legacy CSV wrappers)
# --------------------------------------------------------------------------- #
def test_run_all_writes_figures_and_summary(tmp_path):
    sweep = SweepConfig(e_list=(1,), n_list=(4,), table_n_list=(8,),
                        sim_rounds=4, workloads=("alexnet",))
    # The plan section is jax-backed and has its own artifact tests
    # (tests/test_plan.py); this contract covers the simulation sections.
    results = run_all(sweep, out_dir=tmp_path,
                      sections=("tables", "fig7_9", "fig10_12",
                                "mesh_scaling", "mapper"))
    for section in ("tables", "fig7_9", "fig10_12", "mesh_scaling"):
        fig = json.loads((tmp_path / f"{section}.json").read_text())
        assert fig["figure"] == section and fig["rows"]
    assert "fig7_9" in (tmp_path / "summary.md").read_text()
    csv = (tmp_path / "benchmarks.csv").read_text().splitlines()
    assert csv[0] == "name,us_per_call,derived"
    assert any(l.startswith("fig7_9_alexnet_E1,") for l in csv)
    assert results["_meta"]["cache"]["entries"] > 0


def test_csv_lines_keep_legacy_format():
    sweep = SweepConfig(e_list=(1,), sim_rounds=4, workloads=("alexnet",))
    for lines, tag in ((fig7_9_csv_lines(sweep), "fig7_9"),
                       (fig10_12_csv_lines(sweep), "fig10_12")):
        assert lines[0].startswith(f"{tag}_alexnet_E1,")
        assert "latency_x=" in lines[0] and "power_x=" in lines[0]
        assert lines[-1].startswith(f"{tag}_")          # average/note row
    t = tables_csv_lines()
    assert t[0].startswith("table_alexnet_N8,CONV1,P#=1,INA#=NA")
