"""INA collectives: numerical equivalence to psum on 8 host devices.

These tests need >1 device, so they spawn a subprocess with
``--xla_force_host_platform_device_count=8`` (the main test process keeps the
default single CPU device, per the dry-run isolation rule).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from functools import partial

from repro.compat import shard_map

from repro.core.collectives import (per_link_bytes, psum_ina, psum_with_mode,
                                    reduce_scatter_with_mode,
                                    ring_all_gather, ring_psum_eject_inject,
                                    ring_reduce_scatter_ina)

devs = jax.devices()
assert len(devs) == 8, devs
mesh = Mesh(np.array(devs), ("model",))

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 16, 32), jnp.float32)   # leading dim = P
ref = x.sum(axis=0)                                     # psum over the axis

def run(fn, out_spec):
    f = shard_map(fn, mesh=mesh, in_specs=P("model"), out_specs=out_spec)
    return jax.jit(f)(x)

# Each device holds x[i] (leading dim sharded); collective reduces over axis.
body = lambda xs: xs[0]

# eject/inject all-reduce == psum
out = run(lambda xs: ring_psum_eject_inject(xs[0], "model")[None], P("model"))
np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref), rtol=1e-4, atol=1e-4)

# INA ring reduce-scatter: device i holds reduced chunk i (scatter axis 0)
out = run(lambda xs: ring_reduce_scatter_ina(xs[0], "model", 0), P("model"))
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)

# INA RS on a non-leading axis, verified through a gather round-trip
def rs_then_gather(xs):
    rs = ring_reduce_scatter_ina(xs[0], "model", 1)
    return ring_all_gather(rs, "model", 1)[None]
out = run(rs_then_gather, P("model"))
np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref), rtol=1e-4, atol=1e-4)

# psum_ina (RS + AG) == psum
out = run(lambda xs: psum_ina(xs[0], "model", 0)[None], P("model"))
np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref), rtol=1e-4, atol=1e-4)

# mode dispatch: all modes agree with the reference
for mode in ("ina", "ina_ring", "eject_inject", "xla"):
    out = run(lambda xs, m=mode: psum_with_mode(xs[0], "model", m)[None],
              P("model"))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref), rtol=1e-4, atol=1e-4)
    out = run(lambda xs, m=mode: reduce_scatter_with_mode(xs[0], "model", m, 0),
              P("model"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)

# bf16 path
xb = x.astype(jnp.bfloat16)
fb = shard_map(lambda xs: ring_reduce_scatter_ina(xs[0], "model", 0),
               mesh=mesh, in_specs=P("model"), out_specs=P("model"))
outb = jax.jit(fb)(xb)
np.testing.assert_allclose(np.asarray(outb, dtype=np.float32),
                           np.asarray(xb.astype(jnp.float32).sum(axis=0)),
                           rtol=5e-2, atol=0.5)

# traffic model sanity: INA beats eject/inject by ~P/2 when full result needed
assert per_link_bytes("eject_inject", 8, 1024) == 7 * 1024
assert per_link_bytes("ina", 8, 1024) == 2 * (7 / 8) * 1024
assert per_link_bytes("ina", 8, 1024, need_full=False) == (7 / 8) * 1024

# HLO check: eject/inject lowers to P-1 full collective-permutes, INA ring to
# P-1 chunked ones (1/P size each)
lowered = jax.jit(shard_map(lambda xs: ring_psum_eject_inject(xs[0], "model"),
                            mesh=mesh, in_specs=P("model"),
                            out_specs=P(), check_vma=False)).lower(x)
txt = lowered.as_text()
n_cp = txt.count("collective_permute") + txt.count("collective-permute")
assert n_cp >= 7, n_cp

print("COLLECTIVES_OK")
"""


@pytest.mark.slow
def test_collectives_on_8_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "COLLECTIVES_OK" in proc.stdout
