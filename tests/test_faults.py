"""Fault model, detour routing, collective tree repair, faulted
verification, and degradation-aware serving (DESIGN.md S15).

The two load-bearing contracts:

* **zero-fault degenerate equivalence** — an empty FaultModel takes the
  exact clean code path: identical programs, identical latency + full
  energy ledger on both engines, identical cluster metrics;
* **seeded-mutation coverage** — each fault class of
  :func:`repro.analysis.verify.verify_faulted` fires on exactly its
  class of corruption.
"""
import dataclasses

import pytest

from repro.analysis.corpus import FAULT_MESH_N, faulted_collective_programs
from repro.analysis.verify import verify_faulted
from repro.core.noc.collective.cost import collective_cost
from repro.core.noc.collective.engine import run_program
from repro.core.noc.collective.schedule import plan_collective
from repro.core.noc.faults import (EMPTY_FAULTS, FaultModel, UnroutableError,
                                   detour_route,
                                   path_is_updown, path_is_west_first,
                                   remap_participants, remap_root,
                                   repair_multicast_tree,
                                   repair_reduction_tree, seeded_faults,
                                   updown_keys)
from repro.core.noc.router import NocConfig
from repro.core.noc.topology import Mesh

N = 6
CFG = NocConfig(n=N)
FULL = [(x, y) for x in range(N) for y in range(N)]
FAULTS = seeded_faults(N, N, link_rate=0.08, router_rate=0.02,
                       pe_rate=0.05, seed=11)


# --------------------------------------------------------------------------- #
# fault model
# --------------------------------------------------------------------------- #
def test_seeded_faults_deterministic():
    a = seeded_faults(N, N, link_rate=0.1, router_rate=0.05, seed=7)
    b = seeded_faults(N, N, link_rate=0.1, router_rate=0.05, seed=7)
    c = seeded_faults(N, N, link_rate=0.1, router_rate=0.05, seed=8)
    assert a == b and a.key() == b.key()
    assert a != c
    assert Mesh(N, N).seeded_faults(link_rate=0.1, router_rate=0.05,
                                    seed=7) == a


def test_empty_fault_model():
    assert EMPTY_FAULTS.empty
    assert seeded_faults(N, N).empty
    assert not FAULTS.empty
    assert EMPTY_FAULTS.link_ok((0, 0), (1, 0))
    assert EMPTY_FAULTS.path_clear(FULL)


def test_transient_at_window():
    f = FaultModel(transient=((0, ((0, 0), (1, 0))), (2, ((1, 0), (2, 0)))))
    assert not f.at_window(0).link_ok((0, 0), (1, 0))
    assert f.at_window(0).link_ok((1, 0), (2, 0))
    assert f.at_window(1).empty
    assert not f.at_window(2).link_ok((1, 0), (2, 0))
    # permanent faults persist across windows
    g = FaultModel(links=frozenset({((0, 0), (0, 1))}),
                   transient=((0, ((0, 0), (1, 0))),))
    assert not g.at_window(5).link_ok((0, 0), (0, 1))
    assert g.at_window(5).link_ok((0, 0), (1, 0))


def test_router_failure_kills_its_paths_and_pe():
    f = FaultModel(routers=frozenset({(2, 2)}))
    assert not f.router_ok((2, 2))
    assert not f.path_clear([(1, 2), (2, 2), (3, 2)])
    assert not f.pe_ok((2, 2))          # PE unreachable through dead router


# --------------------------------------------------------------------------- #
# detour routing
# --------------------------------------------------------------------------- #
def test_detour_routes_avoid_faults_and_respect_rules():
    for rule in ("west_first", "updown"):
        for dst in [(5, 5), (0, 5), (3, 2)]:
            try:
                path = detour_route((0, 0), dst, FAULTS, N, N, rule=rule)
            except UnroutableError:
                continue
            assert FAULTS.path_clear(path)
            if rule == "west_first":
                assert path_is_west_first(path)
            else:
                assert path_is_updown(path, FAULTS, N, N)


def test_updown_routes_entire_healthy_component():
    keys = updown_keys(FAULTS, N, N)
    nodes = sorted(keys)
    for s in nodes[:8]:
        for d in nodes[-8:]:
            path = detour_route(s, d, FAULTS, N, N, rule="updown")
            assert path[0] == s and path[-1] == d
            assert FAULTS.path_clear(path)
    # degenerate: src == dst
    assert detour_route(nodes[0], nodes[0], FAULTS, N, N,
                        rule="updown") == (nodes[0],)


def test_zero_fault_routing_is_pure_xy():
    from repro.core.noc.topology import xy_route_tuple
    assert detour_route((0, 0), (4, 3), EMPTY_FAULTS, N, N) == \
        xy_route_tuple((0, 0), (4, 3))


def test_route_to_failed_router_raises():
    f = FaultModel(routers=frozenset({(3, 3)}))
    with pytest.raises(UnroutableError):
        detour_route((0, 0), (3, 3), f, N, N)


# --------------------------------------------------------------------------- #
# tree repair + remap
# --------------------------------------------------------------------------- #
def test_repair_trees_span_healthy_participants():
    healthy, moved = remap_participants(FULL, FAULTS, N, N)
    root = remap_root((0, 0), healthy, FAULTS)
    for builder in (repair_reduction_tree, repair_multicast_tree):
        for rule in ("west_first", "updown"):
            try:
                tree = builder(root, healthy, FAULTS, N, N, rule=rule)
            except UnroutableError:
                assert rule == "west_first"    # updown must always work
                continue
            assert set(healthy) <= set(tree.nodes)
            assert not (set(tree.nodes) & set(FAULTS.routers))


def test_remap_moves_dead_and_stranded_pes_to_nearest_healthy():
    healthy, moved = remap_participants(FULL, FAULTS, N, N)
    keys = updown_keys(FAULTS, N, N)
    for p in FULL:
        usable = FAULTS.pe_ok(p) and p in keys
        assert (p in healthy) == usable
        if not usable:
            assert moved[p] in healthy
    assert not moved or all(m != p for p, m in moved.items())


def test_remap_all_dead_raises():
    f = FaultModel(pes=frozenset(FULL))
    with pytest.raises(UnroutableError):
        remap_participants(FULL, f, N, N)


# --------------------------------------------------------------------------- #
# zero-fault degenerate equivalence
# --------------------------------------------------------------------------- #
def test_empty_faults_bit_identical_programs_and_costs():
    for op, algorithm in (("reduce", "reduce_bcast"),
                          ("broadcast", "reduce_bcast"),
                          ("allreduce", "rs_ag")):
        for semantics in ("ina", "eject_inject"):
            clean = plan_collective(op, FULL, 512.0, CFG,
                                    algorithm=algorithm,
                                    semantics=semantics)
            empty = plan_collective(op, FULL, 512.0, CFG,
                                    algorithm=algorithm,
                                    semantics=semantics,
                                    faults=EMPTY_FAULTS)
            assert clean == empty
            for engine in ("heap", "compiled"):
                a = run_program(list(clean), CFG, engine=engine)
                b = run_program(list(empty), CFG, engine=engine)
                assert a.latency_cycles == b.latency_cycles
                assert a.ledger == b.ledger
            ca = collective_cost(op, 512.0, CFG, algorithm=algorithm,
                                 semantics=semantics)
            cb = collective_cost(op, 512.0, CFG, algorithm=algorithm,
                                 semantics=semantics, faults=EMPTY_FAULTS)
            assert ca == cb


def test_faulted_plan_deterministic_and_clear():
    a = plan_collective("allreduce", FULL, 512.0, CFG, faults=FAULTS)
    b = plan_collective("allreduce", FULL, 512.0, CFG, faults=FAULTS)
    assert a == b
    for o in a:
        if o.flits and o.src != o.dst:
            assert o.path is not None
            assert FAULTS.path_clear(o.path)


def test_faulted_cost_reports_same_engine_results():
    c = collective_cost("allreduce", 512.0, CFG, faults=FAULTS)
    prog = plan_collective("allreduce", FULL, 512.0, CFG, faults=FAULTS)
    for engine in ("heap", "compiled"):
        r = run_program(list(prog), CFG, engine=engine)
        assert r.latency_cycles == c.latency_cycles
        assert r.ledger.network_energy_pj(CFG) == pytest.approx(c.energy_pj)


# --------------------------------------------------------------------------- #
# verifier fault classes: each fires on exactly its corruption
# --------------------------------------------------------------------------- #
def _first_routed(prog):
    for i, o in enumerate(prog):
        if o.flits and o.path is not None and len(o.path) > 2:
            return i, o
    raise AssertionError("no routed op in program")


def _classes(findings):
    return {f.check for f in findings}


def test_faulted_corpus_clean():
    for case, cfg, faults, prog in faulted_collective_programs(quick=True):
        assert verify_faulted(
            prog, faults, cfg, op=case["op"],
            participants=case["participants"],
            algorithm=case["algorithm"],
            semantics=case["semantics"]) == []


def test_mutation_route_through_failed_link():
    prog = list(plan_collective("reduce", FULL, 512.0, CFG, faults=FAULTS))
    i, o = _first_routed(prog)
    # send a packet straight across a failed link
    a, b = sorted(FAULTS.links)[0]
    prog[i] = dataclasses.replace(o, src=a, dst=b, path=(a, b))
    found = _classes(verify_faulted(prog, FAULTS, CFG))
    assert "fault-route" in found


def test_mutation_illegal_turn():
    prog = list(plan_collective("reduce", FULL, 512.0, CFG, faults=FAULTS))
    i, o = _first_routed(prog)
    x, y = o.path[0]
    # an east-then-west U-turn is illegal under both detour rules
    detour = (o.path[0], (x + 1, y), o.path[0], *o.path[1:]) \
        if x + 1 < N else (o.path[0], (x - 1, y), o.path[0], *o.path[1:])
    prog[i] = dataclasses.replace(o, path=detour)
    found = _classes(verify_faulted(prog, FAULTS, CFG))
    assert "fault-turn" in found
    assert "fault-remap" not in found


def test_mutation_dead_pe_contribution():
    prog = list(plan_collective("reduce", FULL, 512.0, CFG, faults=FAULTS))
    dead = sorted(FAULTS.pes)[0]
    i, o = _first_routed(prog)
    prog[i] = dataclasses.replace(
        o, contribs=frozenset(o.contribs) | {dead})
    found = _classes(verify_faulted(prog, FAULTS, CFG, op="reduce",
                                    participants=FULL))
    assert "fault-remap" in found


def test_transient_faults_rejected_by_verifier():
    f = FaultModel(transient=((0, ((0, 0), (1, 0))),))
    prog = list(plan_collective("reduce", FULL, 512.0, CFG))
    found = verify_faulted(prog, f, CFG)
    assert any(x.check == "fault-route" and "transient" in x.message
               for x in found)


# --------------------------------------------------------------------------- #
# whole-program rule fallback
# --------------------------------------------------------------------------- #
def test_planner_falls_back_to_updown_when_west_first_cannot():
    # seed 0 at 12% link faults: the greedy west-first tree repair raises
    # UnroutableError, so the planner must replan the whole program under
    # the up*/down* rule — and the result still verifies clean
    f = seeded_faults(N, N, link_rate=0.12, seed=0)
    healthy, _ = remap_participants(FULL, f, N, N)
    root = remap_root((0, 0), healthy, f)
    with pytest.raises(UnroutableError):
        repair_reduction_tree(root, healthy, f, N, N, rule="west_first")
    prog = plan_collective("reduce", FULL, 512.0, CFG, faults=f)
    assert any(o.path is not None and path_is_updown(o.path, f, N, N)
               for o in prog if o.flits and o.path and len(o.path) > 2)
    assert verify_faulted(prog, f, CFG, op="reduce",
                          participants=FULL) == []


# --------------------------------------------------------------------------- #
# degradation-aware serving
# --------------------------------------------------------------------------- #
def test_cluster_zero_trace_equivalence():
    from repro.serve.cluster import ClusterSimulator
    from repro.serve.costs import DegradedCostModel, SyntheticCostModel
    from repro.serve.traffic import make_workload

    reqs = make_workload(40, 1.0, "uniform:32:64", "uniform:8:16", seed=0)
    base = ClusterSimulator(2, slots=4, block_size=16, max_seq=256,
                            prefill_chunk=32,
                            cost=SyntheticCostModel()).run(reqs)
    degr = ClusterSimulator(2, slots=4, block_size=16, max_seq=256,
                            prefill_chunk=32,
                            cost=DegradedCostModel(SyntheticCostModel(),
                                                   1.0),
                            failures=[]).run(reqs)
    assert base == degr
    assert degr["goodput"] == 1.0 and degr["retries"] == 0
    assert degr["failed_requests"] == 0 and degr["downtime_events"] == 0


def test_cluster_degradation_deterministic_and_accounted():
    from repro.serve.cluster import ClusterSimulator, replica_failure_trace
    from repro.serve.costs import SyntheticCostModel
    from repro.serve.traffic import make_workload

    reqs = make_workload(60, 1.0, "uniform:32:64", "uniform:8:16", seed=0)
    horizon = max(r.arrival for r in reqs)
    trace = replica_failure_trace(2, horizon, mtbf_s=horizon * 0.2,
                                  mttr_s=horizon * 0.05, seed=3)
    assert trace == replica_failure_trace(2, horizon, mtbf_s=horizon * 0.2,
                                          mttr_s=horizon * 0.05, seed=3)
    assert trace and all(k in ("down", "up") for _, _, k in trace)

    def run():
        return ClusterSimulator(2, slots=4, block_size=16, max_seq=256,
                                prefill_chunk=32,
                                cost=SyntheticCostModel(),
                                failures=trace).run(reqs)

    a, b = run(), run()
    assert a == b
    assert a["downtime_events"] == sum(1 for _, _, k in trace
                                       if k == "down")
    # conservation: everything submitted either completed or failed out
    done = round(a["goodput"] * len(reqs))
    assert done + a["failed_requests"] == len(reqs)


def test_degraded_p99_never_beats_clean():
    from repro.serve.cluster import ClusterSimulator, replica_failure_trace
    from repro.serve.costs import DegradedCostModel, SyntheticCostModel
    from repro.serve.traffic import make_workload

    reqs = make_workload(60, 2.0, "uniform:32:64", "uniform:8:16", seed=0)
    horizon = max(r.arrival for r in reqs)
    clean = ClusterSimulator(2, slots=4, block_size=16, max_seq=256,
                             prefill_chunk=32,
                             cost=SyntheticCostModel()).run(reqs)
    trace = replica_failure_trace(2, horizon, mtbf_s=horizon * 0.3,
                                  mttr_s=horizon * 0.1, seed=1)
    degr = ClusterSimulator(2, slots=4, block_size=16, max_seq=256,
                            prefill_chunk=32,
                            cost=DegradedCostModel(SyntheticCostModel(),
                                                   1.3),
                            failures=trace).run(reqs)
    assert degr["e2e_s"]["p99"] >= clean["e2e_s"]["p99"]
    assert degr["goodput"] <= 1.0


def test_fault_slowdown_scalar():
    from repro.serve.costs import (DegradedCostModel, SyntheticCostModel,
                                   fault_slowdown)
    assert fault_slowdown(None) == 1.0
    assert fault_slowdown(EMPTY_FAULTS) == 1.0
    s = fault_slowdown(FAULTS, CFG)
    assert s >= 1.0
    base = SyntheticCostModel()
    d = DegradedCostModel(base, 2.0)
    assert d.prefill_chunk_seconds() == 2.0 * base.prefill_chunk_seconds()
    assert d.decode_iter_seconds(3) == 2.0 * base.decode_iter_seconds(3)


# --------------------------------------------------------------------------- #
# hierarchy
# --------------------------------------------------------------------------- #
def test_hier_failed_chip_excluded_end_to_end():
    from repro.core.noc.hierarchy import HierarchicalMesh, \
        plan_hier_collective

    hmesh = HierarchicalMesh(chip_w=FAULT_MESH_N, chip_h=FAULT_MESH_N,
                             chips_x=2, chips_y=2)
    sched = plan_hier_collective("allreduce", hmesh, 4096.0,
                                 failed_chips=(3,))
    chips = {lane.chip for _lvl, lane in sched.all_lanes()
             if lane.scope == "chip"}
    assert chips and 3 not in chips


def test_hier_zero_faults_identical():
    from repro.core.noc.hierarchy import HierarchicalMesh, \
        plan_hier_collective

    hmesh = HierarchicalMesh(chips_x=2, chips_y=1)
    clean = plan_hier_collective("allreduce", hmesh, 4096.0)
    empty = plan_hier_collective("allreduce", hmesh, 4096.0,
                                 faults=EMPTY_FAULTS, failed_chips=())
    assert clean == empty
