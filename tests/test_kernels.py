"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps +
hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# Degrades gracefully (pytest.importorskip-style) when hypothesis is absent:
# property tests are skipped, the parametrized oracle tests still run.
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ina_matmul import ina_matmul
from repro.kernels.wkv6 import wkv6

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------- #
# ina_matmul
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (128, 1024, 256), (384, 256, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ina_matmul_shapes(m, k, n, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (m, k), dtype)
    w = jax.random.normal(k2, (k, n), dtype)
    got = ina_matmul(x, w, bm=128, bn=128, bk=128, interpret=True)
    want = ref.matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_ina_matmul_equals_eject_inject():
    """Both accumulation strategies are numerically identical (fp32)."""
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (128, 512), jnp.float32)
    w = jax.random.normal(k2, (512, 128), jnp.float32)
    a = ina_matmul(x, w, bm=128, bn=128, bk=128, interpret=True)
    b = ref.matmul_eject_inject(x, w, bk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-6,
                               atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(mb=st.integers(1, 3), kb=st.integers(1, 4), nb=st.integers(1, 3))
def test_ina_matmul_property(mb, kb, nb):
    """Property: any block-divisible shape matches the oracle."""
    m, k, n = 128 * mb, 128 * kb, 128 * nb
    x = jax.random.normal(jax.random.PRNGKey(m + k), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(n), (k, n), jnp.float32)
    got = ina_matmul(x, w, bm=128, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(x, w)),
                               rtol=2e-6, atol=1e-4)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("s,d,causal", [(256, 64, True), (256, 64, False),
                                        (512, 128, True), (1024, 64, True)])
def test_flash_attention(s, d, causal):
    k1, k2, k3 = jax.random.split(KEY, 3)
    bh = 4
    q = jax.random.normal(k1, (bh, s, d), jnp.float32)
    k = jax.random.normal(k2, (bh, s, d), jnp.float32)
    v = jax.random.normal(k3, (bh, s, d), jnp.float32)
    got = flash_attention(q, k, v, bq=128, bkv=128, causal=causal,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_bf16():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 256, 64), jnp.bfloat16)
    k = jax.random.normal(k2, (2, 256, 64), jnp.bfloat16)
    v = jax.random.normal(k3, (2, 256, 64), jnp.bfloat16)
    got = flash_attention(q, k, v, bq=128, bkv=128, interpret=True)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@settings(max_examples=6, deadline=None)
@given(nq=st.integers(1, 4), nk=st.integers(1, 4))
def test_flash_attention_property(nq, nk):
    """Rectangular Sq x Sk with causal masking matches the oracle."""
    sq, sk = 128 * nq, 128 * nk
    ks = jax.random.split(jax.random.PRNGKey(nq * 7 + nk), 3)
    q = jax.random.normal(ks[0], (2, sq, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, sk, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, sk, 64), jnp.float32)
    got = flash_attention(q, k, v, bq=128, bkv=128, causal=False,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


# --------------------------------------------------------------------------- #
# wkv6
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("s,hd,chunk", [(128, 64, 32), (256, 64, 64),
                                        (256, 128, 128)])
def test_wkv6(s, hd, chunk):
    ks = jax.random.split(KEY, 5)
    bh = 3
    r = jax.random.normal(ks[0], (bh, s, hd), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (bh, s, hd), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (bh, s, hd), jnp.float32)
    logw = -jnp.exp(jax.random.normal(ks[3], (bh, s, hd)) * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (bh, hd), jnp.float32) * 0.3
    got = wkv6(r, k, v, logw, u, chunk=chunk, interpret=True)
    want = ref.wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_wkv6_decay_extremes():
    """Strong decay -> state forgets; near-zero decay -> state accumulates."""
    bh, s, hd = 1, 64, 64
    ks = jax.random.split(KEY, 3)
    r = jnp.ones((bh, s, hd)) * 0.1
    k = jax.random.normal(ks[0], (bh, s, hd)) * 0.3
    v = jax.random.normal(ks[1], (bh, s, hd))
    u = jnp.zeros((bh, hd))
    # saturated decay needs chunk*|logw| <= 80 for the factorized form to
    # stay exact (kernels/wkv6.py note)
    for logw_val, chunk in ((-8.0, 8), (-1e-3, 32), (-0.5, 32)):
        logw = jnp.full((bh, s, hd), logw_val)
        got = wkv6(r, k, v, logw, u, chunk=chunk, interpret=True)
        want = ref.wkv6_ref(r, k, v, logw, u)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
