"""Integration: sharded train/serve steps on an 8-device mesh (subprocess).

Verifies (1) training runs and reduces loss under every psum mode,
(2) INA and eject/inject modes are numerically equivalent,
(3) the serve step decodes under a sharded cache,
(4) elastic restore onto a different mesh shape.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models.api import get_model
from repro.optim.adamw import adamw_init
from repro.parallel.steps import build_serve_step, build_train_step
from repro.parallel.tp import ParallelCtx
from repro.data.pipeline import DataConfig, TokenPipeline

cfg = ARCHS["qwen2-1.5b"].reduced()
model = get_model(cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = ShapeConfig("t", 64, 4, "train")
pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))
batch = pipe.batch(0)

losses = {}
for mode in ("xla_spmd", "ina", "ina_ring", "eject_inject"):
    pctx = ParallelCtx(mesh=mesh, psum_mode=mode)
    ts = build_train_step(model, mesh, shape, pctx, donate=False)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            ts.param_sharding)
    opt = jax.device_put(adamw_init(params), ts.opt_sharding)
    b = {k: jax.device_put(v, ts.batch_sharding[k]) for k, v in batch.items()}
    seq = []
    for step in range(3):
        params, opt, stats = ts.fn(params, opt, b)
        seq.append(float(stats["loss"]))
    losses[mode] = seq
    assert seq[-1] < seq[0], (mode, seq)

# all accumulation strategies agree numerically
for mode in ("ina", "ina_ring", "eject_inject"):
    np.testing.assert_allclose(losses[mode], losses["xla_spmd"], rtol=2e-3,
                               atol=2e-3)
print("TRAIN_MODES_OK", losses["ina"][0], "->", losses["ina"][-1])

# serve step with sharded cache
sshape = ShapeConfig("d", 64, 4, "decode")
ss = build_serve_step(model, mesh, sshape,
                      ParallelCtx(mesh=mesh, psum_mode="ina"),
                      donate_cache=False)
params = jax.device_put(model.init(jax.random.PRNGKey(0)), ss.param_sharding)
cache = jax.device_put(model.init_cache(4, 64), ss.cache_sharding)
b = {"tokens": jnp.ones((4, 1), jnp.int32), "pos": jnp.asarray(63, jnp.int32)}
tok, cache2 = ss.fn(params, b, cache)
assert tok.shape == (4,) and int(tok.max()) < cfg.vocab
print("SERVE_OK")

# elastic restore: checkpoint from (2,4) mesh -> restore on (4,2) mesh
import tempfile
from repro.checkpoint.ckpt import save_pytree
from repro.runtime.fault_tolerance import elastic_restore
from repro.models.api import param_specs

d = tempfile.mkdtemp()
save_pytree(params, d, 5)
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
restored, step = elastic_restore(
    jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
    d, mesh2, lambda t, m: param_specs(t, m))
assert step == 5
ok = jax.tree.map(lambda a, b: np.allclose(np.asarray(a), np.asarray(b)),
                  restored, jax.device_get(params))
assert all(jax.tree.leaves(ok))
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_train_serve_elastic_on_mesh():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    for tag in ("TRAIN_MODES_OK", "SERVE_OK", "ELASTIC_OK"):
        assert tag in proc.stdout
