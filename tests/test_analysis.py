"""Static-analysis layer (DESIGN.md S13): artifact verifier + determinism lint.

Coverage map (ISSUE 7):

* the shared corpora verify clean — every tree collective (both semantics x
  both allreduce algorithms x three participant shapes) and every distinct
  quick fig7-12 WS plan shape, source + compiled;
* seeded-mutation property tests: one mutation per defect class on a valid
  program/plan and the verifier flags exactly that class — dropped dep edge
  / duplicated contrib -> ``collective-fold``, diagonal route step ->
  ``route``, forward dep -> ``dep-dag``, cyclic path-override ring ->
  ``cdg-deadlock`` (and the XY-routed twin stays clean), tampered energy ->
  ``ledger``, stale schema -> ``plan-schema``, non-argmin mode ->
  ``plan-mode``, free-list corruption -> ``kvcache``;
* the opt-in hooks: ``run_program(verify=True)``, ``PlanStore(verify=True)``
  raising on a tampered stored plan, ``search_network(debug=True)``,
  ``BlockAllocator.check()``;
* per-rule lint units on scoped snippets (incl. pragma suppression and the
  determinism-scope boundary) and the acceptance gate: ``lint src/`` has
  zero findings inside the pragma budget;
* CLI smoke for both subcommands and the findings-JSON artifact.
"""
import copy
import json
from pathlib import Path
from textwrap import dedent

import pytest

from repro.analysis import (VerificationError, check_program, lint_paths,
                            verify_allocator, verify_collective,
                            verify_compiled, verify_plan, verify_program)
from repro.analysis.corpus import collective_programs, ws_programs
from repro.analysis.lint import count_pragmas, lint_file
from repro.analysis.verify import _phase_of_tag
from repro.core.noc.collective.engine import run_program
from repro.core.noc.collective.schedule import PacketOp, plan_collective
from repro.core.noc.compiled import compile_program
from repro.core.noc.router import NocConfig
from repro.plan import ExecutionPlan, PlanStore, PsumDecision

SRC = Path(__file__).resolve().parent.parent / "src"
CFG4 = NocConfig(n=4)


def _checks(findings):
    return {f.check for f in findings}


def _allreduce():
    parts = [(x, y) for x in range(4) for y in range(4)]
    prog = plan_collective("allreduce", parts, 512.0, CFG4)
    return parts, copy.deepcopy(prog)


def _first_ws_program():
    shape, cfg, prog = next(iter(ws_programs(quick=True)))
    return cfg, copy.deepcopy(prog)


# --------------------------------------------------------------------------- #
# Valid corpora are clean
# --------------------------------------------------------------------------- #
def test_collective_corpus_verifies_clean():
    n = 0
    for case, cfg, prog in collective_programs():
        n += 1
        assert verify_program(prog, cfg) == [], case
        assert verify_collective(
            prog, op=case["op"], participants=case["participants"],
            algorithm=case["algorithm"], semantics=case["semantics"]) == [], \
            case
    assert n == 30          # 3 shapes x (3 ops + 2 allreduce algos) x 2 sems


def test_ws_corpus_verifies_clean_through_compile():
    for shape, cfg, prog in ws_programs(quick=True):
        assert verify_program(prog, cfg) == [], shape
        cp = compile_program(prog, cfg)
        assert verify_compiled(cp, prog, cfg) == [], shape


# --------------------------------------------------------------------------- #
# Seeded mutations: each defect class flagged, and only that class
# --------------------------------------------------------------------------- #
def test_mutation_dropped_dep_edge_flags_fold():
    parts, prog = _allreduce()
    pick = None
    for i, o in enumerate(prog):
        if _phase_of_tag(o.tag) != "reduce" or not o.deps:
            continue
        for d in o.deps:
            od = prog[d]
            if (_phase_of_tag(od.tag) == "reduce" and od.chunk == o.chunk
                    and od.contribs and od.contribs < o.contribs):
                pick = (i, d)
                break
        if pick:
            break
    assert pick, "corpus program has no droppable reduce dep"
    i, d = pick
    prog[i].deps = tuple(x for x in prog[i].deps if x != d)
    findings = verify_collective(prog, op="allreduce", participants=parts)
    assert _checks(findings) == {"collective-fold"}
    assert verify_program(prog, CFG4) == []      # DAG/routes still legal


def test_mutation_duplicated_contrib_flags_fold():
    parts, prog = _allreduce()
    reduce_ops = [i for i, o in enumerate(prog)
                  if _phase_of_tag(o.tag) == "reduce" and o.contribs]
    donor = next(i for i in reduce_ops if len(prog[i].contribs) >= 1)
    p = min(prog[donor].contribs)
    victim = next(i for i in reduce_ops
                  if i != donor and prog[i].chunk == prog[donor].chunk
                  and p not in prog[i].contribs)
    prog[victim].contribs = prog[victim].contribs | {p}
    findings = verify_collective(prog, op="allreduce", participants=parts)
    assert findings and _checks(findings) == {"collective-fold"}
    assert any(str(p) in f.message for f in findings)


def test_mutation_diagonal_route_step_flags_route():
    cfg, prog = _first_ws_program()
    i = next(i for i, o in enumerate(prog)
             if o.flits > 0 and abs(o.src[0] - o.dst[0])
             + abs(o.src[1] - o.dst[1]) >= 2)
    prog[i].path = [tuple(prog[i].src), tuple(prog[i].dst)]   # non-unit step
    findings = verify_program(prog, cfg)
    assert _checks(findings) == {"route"}
    assert f"op {i}" in findings[0].where


def test_mutation_forward_dep_flags_dag_and_hook_raises():
    cfg, prog = _first_ws_program()
    prog[0].deps = (len(prog) - 1,)               # forward edge: not a DAG
    assert "dep-dag" in _checks(verify_program(prog, cfg))
    with pytest.raises(VerificationError) as exc:
        check_program(prog, cfg)
    assert any(f.check == "dep-dag" for f in exc.value.findings)
    with pytest.raises(VerificationError):
        run_program(prog, cfg, verify=True)


def test_mutation_tampered_energy_flags_ledger():
    parts, prog = _allreduce()
    cp = compile_program(prog, CFG4)
    i = next(i for i, o in enumerate(prog) if o.flits > 0)
    prog[i].pe_adds += 1                          # compiled ledger now stale
    findings = verify_compiled(cp, prog, CFG4)
    assert findings and _checks(findings) == {"ledger"}


def _ring_ops(paths):
    return [PacketOp(src=p[0], dst=p[-1], flits=2, path=list(p), tag="mut")
            for p in paths]


def test_mutation_cyclic_overrides_flag_cdg_deadlock():
    # Four turning path overrides on one vc whose channel dependencies form
    # a ring around a 2x2 block: E(0,0) -> N(1,0) -> W(1,1) -> S(0,1) -> E.
    ring = _ring_ops([
        [(0, 0), (1, 0), (1, 1)],
        [(1, 0), (1, 1), (0, 1)],
        [(1, 1), (0, 1), (0, 0)],
        [(0, 1), (0, 0), (1, 0)],
    ])
    cfg = NocConfig(n=2)
    findings = verify_program(ring, cfg)
    assert _checks(findings) == {"cdg-deadlock"}
    assert "cycle" in findings[0].message
    # The same src->dst pairs under plain XY routing are acyclic (the
    # Dally/Seitz turn restriction XY embodies): no finding.
    for op in ring:
        op.path = None
    assert verify_program(ring, cfg) == []


def test_valid_program_runs_with_verify_hook():
    parts, prog = _allreduce()
    res = run_program(prog, CFG4, verify=True)
    assert res.latency_cycles > 0


# --------------------------------------------------------------------------- #
# Plan mutations + the PlanStore verify-on-load hook
# --------------------------------------------------------------------------- #
def _tiny_plan(**over):
    psum = (PsumDecision(
        p=4, nbytes=1024, mode="ina", ops=("psum",), count=3,
        costs=(("ina", 100, 50.0), ("ina_ring", 120, 40.0),
               ("eject_inject", 130, 60.0))),)
    base = dict(model="qwen2-1.5b", mesh=(("data", 4), ("model", 4)),
                phase="decode", dtype="bfloat16", objective="latency",
                psum=psum)
    base.update(over)
    return ExecutionPlan(**base)


def test_mutation_stale_schema_flags_plan_schema():
    import dataclasses
    assert verify_plan(_tiny_plan()) == []
    stale = dataclasses.replace(_tiny_plan(), schema="0" * 16)
    assert _checks(verify_plan(stale)) == {"plan-schema"}


def test_mutation_non_argmin_mode_flags_plan_mode():
    import dataclasses
    plan = _tiny_plan()
    bad = dataclasses.replace(
        plan, psum=(dataclasses.replace(plan.psum[0],
                                        mode="eject_inject"),))
    assert "plan-mode" in _checks(verify_plan(bad))


def test_plan_store_verify_on_load(tmp_path):
    store = PlanStore(tmp_path, verify=True)
    plan = _tiny_plan()
    path = store.save(plan)
    assert store.load(plan.key) == plan           # valid plan loads verified
    doc = json.loads(path.read_text())
    doc["psum"][0]["mode"] = "eject_inject"       # not the costed argmin
    path.write_text(json.dumps(doc))
    with pytest.raises(VerificationError):
        store.load(plan.key)
    assert PlanStore(tmp_path).load(plan.key) is not None   # opt-in only


def test_search_debug_hook_verifies_winning_schedule():
    from repro.core.workloads import mapper_workloads
    from repro.mapper.search import search_network
    from repro.mapper.space import QUICK_MAPPER
    layers = mapper_workloads(conv=("alexnet",), transformers=())["alexnet"]
    outcome = search_network("alexnet", layers, QUICK_MAPPER, debug=True)
    assert outcome.best.latency_cycles <= outcome.baseline.latency_cycles


# --------------------------------------------------------------------------- #
# KV-cache free-list invariants
# --------------------------------------------------------------------------- #
def test_kvcache_mutations_flagged_and_check_raises():
    from repro.serve.kvcache import BlockAllocator
    alloc = BlockAllocator(8)
    alloc.alloc("a", 3)
    assert verify_allocator(alloc) == []
    alloc.check()                                 # clean: no raise

    aliased = BlockAllocator(8)
    aliased.alloc("a", 3)
    aliased.tables["b"] = [aliased.tables["a"][0]]     # cross-table alias
    findings = verify_allocator(aliased)
    assert findings and _checks(findings) == {"kvcache"}
    with pytest.raises(AssertionError):
        aliased.check()

    leaked = BlockAllocator(8)
    leaked.alloc("a", 3)
    leaked._free.append(leaked.tables["a"][0])         # free AND mapped
    assert "kvcache" in _checks(verify_allocator(leaked))

    ranged = BlockAllocator(8)
    ranged._free.append(99)                            # out-of-range id
    assert "kvcache" in _checks(verify_allocator(ranged))


def test_kvcache_failed_extend_does_not_leak():
    from repro.serve.kvcache import BlockAllocator
    alloc = BlockAllocator(4)
    alloc.alloc("a", 2)
    for bad in (-1, 99):
        with pytest.raises(MemoryError):
            alloc.extend("a", bad)
        assert verify_allocator(alloc) == []      # invariants survive failure


# --------------------------------------------------------------------------- #
# Determinism lint: per-rule units, pragma + scope mechanics
# --------------------------------------------------------------------------- #
def _lint_snippet(tmp_path, code, rel="repro/plan/mod.py"):
    f = tmp_path / rel
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(dedent(code))
    return lint_file(f)


def test_lint_unseeded_random(tmp_path):
    findings = _lint_snippet(tmp_path, """\
        import random
        import numpy as np
        x = random.random()
        r = random.Random(7)
        g = np.random.default_rng(0)
        h = np.random.default_rng()
        """)
    assert [(f.check, int(f.where.rsplit(":", 1)[1])) for f in findings] == \
        [("unseeded-random", 3), ("unseeded-random", 6)]


def test_lint_wall_clock_and_scope(tmp_path):
    code = """\
        import time
        from time import perf_counter
        t0 = time.time()
        t1 = perf_counter()
        """
    hits = _lint_snippet(tmp_path, code)
    assert [f.check for f in hits] == ["wall-clock", "wall-clock"]
    # experiments/ report wall time by design: outside the rule's scope.
    assert _lint_snippet(tmp_path, code, rel="repro/experiments/m.py") == []


def test_lint_set_iteration(tmp_path):
    findings = _lint_snippet(tmp_path, """\
        s = {1, 2, 3}
        for x in s:                  # flagged
            print(x)
        for x in sorted(s):          # sorted: fine
            print(x)
        items = list(s)              # flagged
        keep = {x for x in s}        # set comprehension: set in, set out
        total = sum(x for x in s)    # order-insensitive reducer
        """, rel="anywhere/mod.py")
    assert [(f.check, int(f.where.rsplit(":", 1)[1])) for f in findings] == \
        [("set-iteration", 2), ("set-iteration", 6)]


def test_lint_mutable_default_and_non_atomic_write(tmp_path):
    findings = _lint_snippet(tmp_path, """\
        from pathlib import Path
        def f(acc=[]):
            return acc
        def g(acc=None):
            return acc
        def dump(p, text):
            with open(p, "w") as fh:
                fh.write(text)
            Path(p).write_text(text)
        data = open("x").read()
        """)
    assert [(f.check, int(f.where.rsplit(":", 1)[1])) for f in findings] == \
        [("mutable-default", 2), ("non-atomic-write", 7),
         ("non-atomic-write", 9)]


def test_lint_pragma_suppresses_only_named_rule(tmp_path):
    assert _lint_snippet(tmp_path, """\
        import time
        t = time.time()   # lint: allow(wall-clock)
        """) == []
    wrong = _lint_snippet(tmp_path, """\
        import time
        t = time.time()   # lint: allow(set-iteration)
        """)
    assert [f.check for f in wrong] == ["wall-clock"]


def test_lint_src_zero_findings_within_pragma_budget():
    assert lint_paths([SRC]) == []
    assert count_pragmas([SRC]) <= 5


# --------------------------------------------------------------------------- #
# CLI smoke
# --------------------------------------------------------------------------- #
def test_cli_verify_and_lint(tmp_path, capsys):
    from repro.analysis.__main__ import main
    out = tmp_path / "findings.json"
    assert main(["verify", "--sections", "kvcache",
                 "--json", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["count"] == 0 and doc["command"] == "verify"

    bad = tmp_path / "repro" / "plan" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt = time.time()\n")
    assert main(["lint", str(bad), "--json", str(out)]) == 1
    doc = json.loads(out.read_text())
    assert doc["count"] == 1
    assert doc["findings"][0]["check"] == "wall-clock"
    assert main(["lint", str(SRC)]) == 0
    capsys.readouterr()
