"""Exact reproduction of the paper's Tables I & II (INA analytical model)."""
import math

import pytest

from repro.core.ina_model import (ConvLayer, ina_rounds, ina_table, needs_ina,
                                  p_num, total_ina_rounds)
from repro.core.workloads import ALEXNET, RESNET50, VGG16

# (layer, P#, INA# @ N=8, INA# @ N=16) — paper Table I.
TABLE_I = {
    "CONV1": (1, None, None),
    "CONV2": (2, 4374, 1094),
    "CONV3": (2, 2028, 507),
    "CONV4": (4, 2704, 676),
    "CONV5": (3, 2704, 541),
}

# Paper Table II.  CONV3 is the paper's anomalous row (P#=1 yet INA# listed);
# per Eq. (1) it is NA — we check the paper's value under force=True below.
TABLE_II = {
    "CONV1":  (1, None, None),
    "CONV2":  (1, None, None),
    "CONV3":  (1, None, None),          # paper lists 25088/6272, see DESIGN.md S7
    "CONV4":  (2, 50176, 12544),
    "CONV5":  (2, 25088, 6272),
    "CONV6":  (3, 50176, 10036),
    "CONV7":  (3, 50176, 10036),
    "CONV8":  (3, 25088, 5018),
    "CONV9":  (5, 50176, 8363),
    "CONV10": (5, 50176, 8363),
    "CONV11": (5, 12544, 2091),
    "CONV12": (5, 12544, 2091),
    "CONV13": (5, 12544, 2091),
}


@pytest.mark.parametrize("layer", ALEXNET, ids=lambda l: l.name)
def test_table_i(layer):
    p_ref, ina8, ina16 = TABLE_I[layer.name]
    assert p_num(layer) == p_ref
    assert ina_rounds(layer, n=8) == ina8
    assert ina_rounds(layer, n=16) == ina16


@pytest.mark.parametrize("layer", VGG16, ids=lambda l: l.name)
def test_table_ii(layer):
    p_ref, ina8, ina16 = TABLE_II[layer.name]
    assert p_num(layer) == p_ref
    assert ina_rounds(layer, n=8) == ina8
    assert ina_rounds(layer, n=16) == ina16


def test_vgg_conv3_paper_anomaly():
    """The paper's CONV3 row reproduces under force=True (Eq. 3 applied at P#=1)."""
    conv3 = VGG16[2]
    assert not needs_ina(conv3)
    assert ina_rounds(conv3, n=8, force=True) == 25088
    assert ina_rounds(conv3, n=16, force=True) == 6272


def test_eq1_threshold_is_exact():
    """Eq. (1) is a strict inequality at the memory boundary."""
    at_boundary = ConvLayer("b", R=1, C=1024, F=8, O=4)      # 1024*32 = 32768 = M
    over = ConvLayer("o", R=1, C=1025, F=8, O=4)
    assert not needs_ina(at_boundary)
    assert needs_ina(over)
    assert p_num(at_boundary) == 1 and p_num(over) == 2


def test_eq4_multiple_pes_per_router():
    """Eq. (4): E PEs/router divides the filter term."""
    conv2 = ALEXNET[1]
    assert ina_rounds(conv2, n=8, e_pes_per_router=2) == 2187
    assert ina_rounds(conv2, n=8, e_pes_per_router=4) == 1094   # ceil(4373.99../4)... ceil(6*729/4)

    # Consistency: E=1 matches Eq. (3).
    for layer in ALEXNET + VGG16:
        assert ina_rounds(layer, 8, 1) == ina_rounds(layer, 8)


def test_resnet50_mostly_fits():
    """Paper SIV.B: 'most of ResNet-50 does not need to split the weights'."""
    split = [l for l in RESNET50 if needs_ina(l)]
    assert 0 < len(split) < len(RESNET50) / 2
    # Aggregate rounds ordering the paper relies on: VGG-16 >> AlexNet, ResNet low.
    assert total_ina_rounds(VGG16, 8) > total_ina_rounds(RESNET50, 8)
    assert total_ina_rounds(VGG16, 8) > total_ina_rounds(ALEXNET, 8)


def test_total_ina_rounds_forwards_q_bits():
    """Regression: total_ina_rounds silently dropped q_bits — q=8 must flip
    Eq. (1) for every AlexNet layer (C*R*R*8 < 32768 throughout) and shrink
    the VGG-16 total (only the C=512 layers still split)."""
    assert total_ina_rounds(ALEXNET, 8, q_bits=8) != total_ina_rounds(ALEXNET, 8)
    assert total_ina_rounds(ALEXNET, 8, q_bits=8) == 0
    assert not needs_ina(ALEXNET[1], q_bits=8)          # Eq. (1) flipped
    assert 0 < total_ina_rounds(VGG16, 8, q_bits=8) < total_ina_rounds(VGG16, 8)
    # Default q matches the explicit 32-bit call (consistency with ina_rounds).
    assert total_ina_rounds(VGG16, 8) == total_ina_rounds(VGG16, 8, q_bits=32)


def test_multi_row_chain_rounds_not_clamped():
    """Regression: P# > N must not silently clamp to one group per column.

    A filter whose chain is taller than the mesh accumulates in ceil(P#/N)
    sequential passes; the old ``groups = 1`` fallback ignored the extra
    passes and undercounted rounds by that factor.  The paper's tables never
    hit this case — the mapper's search space (GEMM reductions on short
    columns) does.
    """
    big = ConvLayer("big", R=1, C=6 * 1024, F=16, O=4)
    assert p_num(big) == 6
    clamped = math.ceil((big.F / 4) * big.O * big.O)   # the old one-group model
    assert ina_rounds(big, n=4) == 2 * clamped          # ceil(6/4) = 2 passes
    assert ina_rounds(big, n=4) > clamped
    # One-pass meshes are untouched (N=8 holds the whole chain: groups=1).
    assert ina_rounds(big, n=8) == math.ceil((big.F / 8) * big.O * big.O)
    # E PEs per router still divide the filter term inside each pass.
    assert ina_rounds(big, n=4, e_pes_per_router=2) == \
        2 * math.ceil((big.F / 8) * big.O * big.O)


def test_table_shape():
    rows = ina_table(ALEXNET, n=8)
    assert [r["layer"] for r in rows] == [l.name for l in ALEXNET]
    assert rows[1]["INA#"] == 4374 and rows[0]["INA#"] is None
