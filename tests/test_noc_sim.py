"""NoC simulator invariants + paper Figs 7-12 reproduction bands."""
import pytest

from repro.core.ina_model import ConvLayer
from repro.core.noc import NocConfig, NocSim, simulate_layer, simulate_network
from repro.core.noc.power import ws_ina_improvement, ws_vs_os_improvement
from repro.core.noc.traffic import _plan, _sim_rounds_window
from repro.core.workloads import ALEXNET, VGG16

CFG = NocConfig()


# --------------------------------------------------------------------------- #
# Simulator micro-invariants
# --------------------------------------------------------------------------- #
def test_uncontended_packet_latency():
    """head latency = NI + hops*(router+link) + router + NI; tail += flits-1."""
    sim = NocSim(CFG)
    done = {}
    sim.enqueue(0, (0, 0), (0, 3), 4, on_done=lambda t: done.setdefault("t", t))
    sim.run()
    hops = 3
    expect_head = CFG.ni_cycles + hops * (CFG.router_cycles + CFG.link_cycles) \
        + CFG.router_cycles + CFG.ni_cycles
    assert done["t"] == expect_head + 4 - 1


def test_xy_route_no_link_sharing_between_columns():
    """Packets in different columns never contend."""
    sim = NocSim(CFG)
    times = []
    for x in range(4):
        sim.enqueue(0, (x, 0), (x, 7), 3, on_done=times.append)
    sim.run()
    assert len(set(times)) == 1          # perfectly parallel


def test_same_link_serializes():
    sim = NocSim(CFG)
    times = []
    sim.enqueue(0, (0, 0), (0, 1), 4, on_done=times.append)
    sim.enqueue(0, (0, 0), (0, 1), 4, on_done=times.append)
    sim.run()
    assert max(times) >= min(times) + 4  # injection port + link occupancy


def test_wormhole_serialization_in_tail():
    sim = NocSim(CFG)
    done = {}
    sim.enqueue(0, (0, 0), (1, 0), 1, on_done=lambda t: done.setdefault("f1", t))
    sim2 = NocSim(CFG)
    sim2.enqueue(0, (0, 0), (1, 0), 9, on_done=lambda t: done.setdefault("f9", t))
    sim.run(), sim2.run()
    assert done["f9"] == done["f1"] + 8


def test_chain_eject_inject_is_serial():
    """Relay over P nodes costs ~(P-1) x full packet latencies."""
    sim = NocSim(CFG)
    done = {}
    sim.chain_eject_inject(0, [(0, y) for y in range(5)], 2,
                           on_done=lambda t: done.setdefault("t", t))
    sim.run()
    one_hop = 2 * CFG.ni_cycles + CFG.router_cycles + CFG.link_cycles \
        + CFG.router_cycles + 1 + CFG.pe_add_cycles   # + tail flit
    assert done["t"] >= 4 * one_hop


def test_energy_linear_in_rounds():
    plan = _plan(ALEXNET[1], CFG, 1, "ws_ina")
    _, led8 = _sim_rounds_window(plan, CFG, "ws_ina", 8)
    _, led16 = _sim_rounds_window(plan, CFG, "ws_ina", 16)
    assert led16.network_energy_pj(CFG) == pytest.approx(
        2 * led8.network_energy_pj(CFG))


# --------------------------------------------------------------------------- #
# INA semantics
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("layer", [l for l in ALEXNET if l.name != "CONV1"],
                         ids=lambda l: l.name)
def test_ina_always_helps_when_split(layer):
    base = simulate_layer(layer, "ws_noina", CFG, 1, sim_rounds=16)
    ina = simulate_layer(layer, "ws_ina", CFG, 1, sim_rounds=16)
    assert ina.latency_cycles < base.latency_cycles
    assert ina.noc_energy_pj < base.noc_energy_pj


def test_no_split_no_difference():
    """P#=1 layers (no INA per Eq. 1) behave identically in both modes."""
    conv1 = ALEXNET[0]
    base = simulate_layer(conv1, "ws_noina", CFG, 1, sim_rounds=16)
    ina = simulate_layer(conv1, "ws_ina", CFG, 1, sim_rounds=16)
    assert base.latency_cycles == ina.latency_cycles
    assert base.noc_energy_pj == ina.noc_energy_pj


def test_gather_flit_sizes_match_table_iii():
    """Table III: 3/5/9(/17)-flit gather packets for 1/2/4(/8) PEs/router at
    the full-column (P#=1) collection the paper sizes against."""
    assert [CFG.gather_flits(8 * e) for e in (1, 2, 4, 8)] == [3, 5, 9, 17]
    assert [CFG.unicast_flits(e) for e in (1, 2, 4)] == [2, 2, 2]
    assert CFG.unicast_flits(8) == 3


def test_payload_flits_ceils_fractional_bits():
    """Regression: fractional payloads (reuse-scaled floats) must ceil on the
    float, not truncate first — 128.5 bits needs 2 flits of 128."""
    assert CFG.payload_flits(128.5) == 2
    assert CFG.payload_flits(128.0) == 1
    assert CFG.payload_flits(129) == 2
    assert CFG.payload_flits(0.25) == 1          # max(1, ...) floor survives
    assert CFG.payload_flits(0) == 1
    assert CFG.payload_flits(256) == 2


def test_single_window_extrapolation_sim_rounds_1():
    """Regression: sim_rounds=1 on a multi-round layer used to divide by
    zero in _accum_phase (w_small == w_big == 1); the single window's period
    now serves as the marginal."""
    conv2 = ALEXNET[1]                            # plan.rounds = 4374 >> 1
    r1 = simulate_layer(conv2, "ws_ina", CFG, 1, sim_rounds=1)
    assert r1.latency_cycles > 0
    # The one-window marginal includes the full pipeline fill (no overlap
    # between rounds is observable from one round), so it can only
    # overestimate the steady-state extrapolation — never under.
    r16 = simulate_layer(conv2, "ws_ina", CFG, 1, sim_rounds=16)
    assert r16.latency_cycles <= r1.latency_cycles
    # Exact contract of the fallback: marginal = t_window / 1, so the accum
    # phase extrapolates to rounds * t_window on top of the fill barrier.
    plan = _plan(conv2, CFG, 1, "ws_ina")
    t_window, _ = _sim_rounds_window(plan, CFG, "ws_ina", 1)
    assert r1.latency_cycles == pytest.approx(
        r1.fill_cycles + plan.rounds * t_window)
    # sim_rounds=0 clamps to one simulated round instead of dividing by zero.
    r0 = simulate_layer(conv2, "ws_ina", CFG, 1, sim_rounds=0)
    assert r0.latency_cycles == r1.latency_cycles


# --------------------------------------------------------------------------- #
# Paper headline bands (Figs 7-9 / 10-12); see EXPERIMENTS.md for calibration.
# --------------------------------------------------------------------------- #
def test_fig7_alexnet_bands():
    imp = ws_ina_improvement("alexnet", ALEXNET, 1, CFG, sim_rounds=16)
    assert 1.1 <= imp.latency_x <= 1.6          # paper: up to 1.17x
    assert 1.8 <= imp.energy_x <= 2.4           # paper: up to 2.1x


def test_fig9_vgg_bands():
    imp = ws_ina_improvement("vgg16", VGG16, 1, CFG, sim_rounds=16)
    assert 1.3 <= imp.latency_x <= 2.0
    assert 1.7 <= imp.energy_x <= 2.4           # paper: 2.16x


def test_power_improvement_decreases_with_pes():
    """Paper SIV.B: smaller number of PEs shows the highest power improvement."""
    imps = [ws_ina_improvement("alexnet", ALEXNET, e, CFG, sim_rounds=16)
            for e in (1, 2, 4, 8)]
    assert imps[0].energy_x > imps[1].energy_x > imps[2].energy_x


def test_ws_vs_os_degrades_with_pes():
    """Paper SIV.B: WS latency advantage over OS degrades as PEs/router grow."""
    imps = [ws_vs_os_improvement("alexnet", ALEXNET, e, CFG, sim_rounds=16)
            for e in (1, 2, 4, 8)]
    assert imps[0].latency_x > imps[-1].latency_x
    assert imps[0].latency_x > 1.0              # paper: up to 1.19x at E=1


def test_network_totals_aggregate():
    net = simulate_network(ALEXNET, "ws_ina", CFG, 1, sim_rounds=8)
    assert net["latency_cycles"] == pytest.approx(
        sum(l.latency_cycles for l in net["layers"]))
    assert net["total_energy_pj"] > 0
