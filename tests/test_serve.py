"""Serving engine + paged KV cache (DESIGN.md S12).

Coverage map (ISSUE 6):

* the engine's tokens match the legacy one-batch loop exactly — per
  request, with fewer slots than requests (continuous batching cannot
  change what any request computes);
* per-token loop prefill and chunked batched prefill agree;
* paged==monolithic: property tests on the :class:`BlockAllocator`
  (no aliasing, no leaks under random alloc/extend/free) and on
  :class:`PagedKVCache` round-trips (interleaved writes, bit-identical
  gathers, zeros past the covered length);
* scheduler admission: head-of-line blocking, priority order, slot and
  block release on finish;
* request validation (engine needs prompts; footprint must fit).
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCHS
from repro.serve import (BlockAllocator, PagedKVCache, Request, Scheduler,
                         ServingEngine)
from repro.serve.cluster import SimKV

ARCH = ARCHS["qwen2-1.5b"].reduced()
PROMPT_LEN, GEN, BATCH = 6, 5, 3
MAX_SEQ = PROMPT_LEN + GEN + 1      # engine feeds one token past the prompt


def _prompts():
    import jax
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(7), (BATCH, PROMPT_LEN), 3, ARCH.vocab))


@pytest.fixture(scope="module")
def reference_tokens():
    """The legacy launch/serve.py loop: one fixed batch, per-token
    prefill through the monolithic serve step.  Returns [B, GEN+1] —
    the first generated token plus GEN greedy continuations."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models.api import get_model
    from repro.parallel.steps import build_serve_step
    from repro.parallel.tp import ParallelCtx

    model = get_model(ARCH)
    mesh = make_host_mesh(1)
    shape = ShapeConfig("test", MAX_SEQ, BATCH, "decode")
    pctx = ParallelCtx(mesh=mesh, psum_mode="ina")
    ss = build_serve_step(model, mesh, shape, pctx, donate_cache=True)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            ss.param_sharding)
    cache = jax.device_put(model.init_cache(BATCH, MAX_SEQ),
                           ss.cache_sharding)
    prompts = _prompts()
    for pos in range(PROMPT_LEN):
        nxt, cache = ss.fn(
            params, {"tokens": jnp.asarray(prompts[:, pos:pos + 1]),
                     "pos": jnp.asarray(pos, jnp.int32)}, cache)
    out = [np.asarray(nxt)]
    tok = nxt[:, None]
    for i in range(GEN):
        nxt, cache = ss.fn(
            params, {"tokens": tok,
                     "pos": jnp.asarray(PROMPT_LEN + i, jnp.int32)}, cache)
        out.append(np.asarray(nxt))
        tok = nxt[:, None]
    return prompts, np.stack(out, axis=1)


def _requests(prompts):
    return [Request(rid=f"r{i}", prompt_len=PROMPT_LEN, max_new=GEN + 1,
                    prompt=tuple(int(t) for t in prompts[i]))
            for i in range(BATCH)]


@pytest.fixture(scope="module")
def engine_report(reference_tokens):
    prompts, _ = reference_tokens
    eng = ServingEngine(ARCH, slots=2, max_seq=MAX_SEQ, block_size=4,
                        prefill_chunk=4, check=True)
    return eng.run(_requests(prompts))


# --------------------------------------------------------------------------- #
# Engine == legacy loop
# --------------------------------------------------------------------------- #
def test_engine_matches_legacy_loop(reference_tokens, engine_report):
    """Continuous batching on 2 slots (< 3 requests) reproduces the
    one-batch loop token-for-token — the serving-engine contract."""
    _, ref = reference_tokens
    tokens = engine_report.tokens()
    assert set(tokens) == {f"r{i}" for i in range(BATCH)}
    for i in range(BATCH):
        assert tokens[f"r{i}"] == ref[i].tolist(), f"r{i} diverged"


def test_engine_report_shape(engine_report):
    rep = engine_report
    assert rep.checks == BATCH               # every retire verified paged KV
    assert rep.decode_steps >= GEN           # slots < requests => extra iters
    assert {r["slot"] for r in rep.requests} <= {0, 1}
    # 2 slots run concurrently; the third request waits for a retirement
    admits = sorted(r["admit_iter"] for r in rep.requests)
    assert admits[0] == admits[1] == 0 and admits[2] > 0


def test_loop_prefill_matches_batched(reference_tokens):
    """batched_prefill=False (per-token decode loop) produces the same
    tokens as the chunked batched prefill path."""
    prompts, ref = reference_tokens
    eng = ServingEngine(ARCH, slots=1, max_seq=MAX_SEQ, block_size=4,
                        prefill_chunk=4, batched_prefill=False, check=True)
    rep = eng.run(_requests(prompts)[:1])
    assert rep.tokens()["r0"] == ref[0].tolist()


def test_engine_rejects_promptless_and_oversized():
    eng = ServingEngine(ARCH, slots=1, max_seq=MAX_SEQ, block_size=4,
                        prefill_chunk=4)
    with pytest.raises(ValueError, match="need tokens"):
        eng.run([Request(rid="x", prompt_len=4, max_new=2)])
    with pytest.raises(ValueError, match="max_seq"):
        eng.run([Request(rid="y", prompt_len=MAX_SEQ, max_new=2,
                         prompt=tuple(range(3, 3 + MAX_SEQ)))])


# --------------------------------------------------------------------------- #
# BlockAllocator properties
# --------------------------------------------------------------------------- #
@given(st.lists(st.tuples(st.sampled_from(["alloc", "extend", "free"]),
                          st.integers(0, 5), st.integers(0, 4)),
                max_size=60))
@settings(max_examples=60, deadline=None)
def test_allocator_never_aliases_or_leaks(ops):
    """Random alloc/extend/free interleavings: every block is free or
    owned by exactly one request, and free + live == total, always."""
    alloc = BlockAllocator(12)
    owned: dict[int, int] = {}
    for op, rid, n in ops:
        try:
            if op == "alloc":
                blocks = alloc.alloc(rid, n)
                assert len(blocks) == n
                owned[rid] = n
            elif op == "extend":
                alloc.extend(rid, n)
                owned[rid] += n
            else:
                freed = alloc.free(rid)
                assert freed == owned.pop(rid)
        except (KeyError, MemoryError):
            pass                              # rejected ops must not mutate
        alloc.check()
        assert alloc.live_blocks == sum(owned.values())
        assert alloc.free_blocks == 12 - alloc.live_blocks
    for rid in list(owned):
        alloc.free(rid)
    assert alloc.free_blocks == 12


def test_allocator_deterministic_order():
    a = BlockAllocator(6)
    assert a.alloc("a", 2) == [0, 1]
    assert a.alloc("b", 2) == [2, 3]
    a.free("a")
    assert a.alloc("c", 3) == [0, 1, 4]       # reuses lowest ids first


# --------------------------------------------------------------------------- #
# PagedKVCache round-trips
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def kv():
    return PagedKVCache(ARCH, max_seq=16, block_size=4, num_blocks=12)


def _random_row(kv, rng):
    leaves = []
    for meta in kv.leaves:
        if np.issubdtype(meta.dtype, np.integer):
            leaves.append(rng.integers(0, 7, size=meta.row_shape)
                          .astype(meta.dtype))
        else:
            leaves.append(rng.standard_normal(size=meta.row_shape)
                          .astype(meta.dtype))
    return kv._treedef.unflatten(leaves)


@given(st.integers(0, 2 ** 16), st.integers(1, 16), st.integers(1, 16))
@settings(max_examples=25, deadline=None)
def test_paged_roundtrip_bit_identical(kv, seed, len_a, len_b):
    """Two requests' rows written interleaved, chunk by chunk: each
    gathers back bit-identical to its source, zeros past its length,
    and releasing one leaves the other untouched."""
    rng = np.random.default_rng(seed)
    kv.admit("a", len_a)
    kv.admit("b", len_b)
    try:
        row_a, row_b = _random_row(kv, rng), _random_row(kv, rng)
        pos_a = pos_b = 0
        while pos_a < len_a or pos_b < len_b:
            if pos_a < len_a:
                n = min(int(rng.integers(1, 5)), len_a - pos_a)
                kv.write_range("a", pos_a, row_a, n)
                pos_a += n
            if pos_b < len_b:
                n = min(int(rng.integers(1, 5)), len_b - pos_b)
                kv.write_range("b", pos_b, row_b, n)
                pos_b += n
        kv.assert_matches("a", row_a, len_a)
        kv.assert_matches("b", row_b, len_b)
        kv.check()
        # zeros past the covered length on every paged leaf
        got = kv._treedef.flatten_up_to(kv.gather_row("a", len_a))
        for meta, leaf in zip(kv.leaves, got):
            if not meta.paged:
                continue
            tail = np.moveaxis(leaf, meta.batch_axis, 0)[len_a:]
            assert not np.any(tail.astype(np.float32))
        kv.release("b")
        kv.check()
        kv.assert_matches("a", row_a, len_a)
    finally:
        for rid in list(kv.allocator.tables):
            kv.release(rid)
    assert kv.allocator.free_blocks == 12


def test_kvcache_block_size_must_divide():
    with pytest.raises(ValueError, match="divide"):
        PagedKVCache(ARCH, max_seq=10, block_size=4, num_blocks=4)


def test_kvcache_admission_accounting(kv):
    assert kv.blocks_for(1) == 1 and kv.blocks_for(5) == 2
    kv.admit("x", 16)                         # 4 blocks
    kv.admit("y", 16)
    kv.admit("z", 16)
    assert not kv.can_admit(1)                # 12 blocks all reserved
    assert kv.release("y") == 4
    assert kv.can_admit(16)
    kv.release("x")
    kv.release("z")


# --------------------------------------------------------------------------- #
# Scheduler admission
# --------------------------------------------------------------------------- #
def test_scheduler_head_of_line_blocking():
    """A too-big head request must not be overtaken by smaller ones."""
    sched = Scheduler(4, SimKV(block_size=4, num_blocks=4))
    sched.submit(Request(rid="big", prompt_len=12, max_new=4, arrival=0.0))
    sched.submit(Request(rid="small", prompt_len=2, max_new=2, arrival=1.0))
    assert [st.req.rid for st in sched.admit(now=2.0)] == ["big"]
    assert sched.admit(now=2.0) == []         # small waits for blocks
    sched.finish(0, now=3.0)
    assert [st.req.rid for st in sched.admit(now=3.0)] == ["small"]


def test_scheduler_priority_policy():
    sched = Scheduler(1, SimKV(block_size=4, num_blocks=64),
                      policy="priority")
    sched.submit(Request(rid="late-hi", prompt_len=2, max_new=1,
                         arrival=0.0, priority=0))
    sched.submit(Request(rid="early-lo", prompt_len=2, max_new=1,
                         arrival=0.0, priority=5))
    assert sched.admit(now=0.0)[0].req.rid == "late-hi"


def test_scheduler_releases_slot_and_blocks():
    kv = SimKV(block_size=4, num_blocks=8)
    sched = Scheduler(2, kv)
    sched.submit(Request(rid="a", prompt_len=8, max_new=8))   # 4 blocks
    sched.submit(Request(rid="b", prompt_len=8, max_new=8))
    assert len(sched.admit()) == 2 and kv.allocator.free_blocks == 0
    st = sched.finish(0, now=1.0)
    assert st.req.rid == "a" and st.finish_time == 1.0
    assert kv.allocator.free_blocks == 4
    assert sched.n_active == 1 and sched.has_work


def test_request_validation():
    with pytest.raises(ValueError, match="positive"):
        Request(rid="r", prompt_len=0, max_new=1)
    with pytest.raises(ValueError, match="mismatch"):
        Request(rid="r", prompt_len=3, max_new=1, prompt=(1, 2))
    assert Request(rid="r", prompt_len=3, max_new=2).total_positions == 5
