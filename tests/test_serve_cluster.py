"""Request-level cluster simulator + serving metrics (DESIGN.md S12).

Coverage map (ISSUE 6):

* seeded determinism: same workload, same simulator shape — byte-identical
  metrics JSON (the CI serve-smoke contract);
* pinned p50/p99 + throughput for one fixed scenario (drift alarm);
* Little's law: L == lambda * W within finite-horizon tolerance on a long
  Poisson run;
* edge pair: zero traffic (empty metrics, ratio 1.0) and overload (tiny
  fleet still finishes everything, queueing dominates, more capacity
  shrinks it);
* fleet search returns the smallest SLO-meeting size, with monotone
  improvement along the sizes searched;
* workload generation: seeded reproducibility, qps<=0 batch arrivals,
  distribution specs, trace round-trip;
* nearest-rank percentiles; never-admissible requests raise.
"""
import json

import pytest

from repro.serve import (ClusterSimulator, Request, SyntheticCostModel,
                         load_trace, make_workload, percentile,
                         poisson_arrivals, search_fleet, summarize)
from repro.serve.traffic import parse_length_dist

COST = SyntheticCostModel()


def _pinned_scenario():
    reqs = make_workload(80, qps=2.0, prompt_dist="uniform:16:128",
                         gen_dist="uniform:8:64", seed=42)
    sim = ClusterSimulator(2, slots=4, block_size=16, max_seq=256,
                           prefill_chunk=32, cost=COST)
    return sim.run(reqs)


# --------------------------------------------------------------------------- #
# Determinism + pinned values
# --------------------------------------------------------------------------- #
def test_metrics_byte_identical_across_runs():
    a = json.dumps(_pinned_scenario(), sort_keys=True)
    b = json.dumps(_pinned_scenario(), sort_keys=True)
    assert a == b


def test_pinned_metrics():
    """Exact values for one seeded scenario: any event-loop, admission,
    or cost-model change that shifts behaviour must touch these."""
    m = _pinned_scenario()
    assert m["requests"] == 80
    assert m["tokens_out"] == 2858
    assert m["iterations"] == 2762
    assert m["events"] == 2842
    assert m["throughput_rps"] == pytest.approx(2.251301079)
    assert m["e2e_s"]["p50"] == pytest.approx(0.168)
    assert m["e2e_s"]["p99"] == pytest.approx(0.2915)
    assert m["ttft_s"]["p50"] == pytest.approx(0.0105)
    assert m["littles_law_ratio"] == pytest.approx(0.993131517)


def test_littles_law_on_long_poisson_run():
    reqs = make_workload(400, qps=5.0, prompt_dist="lognormal:64:0.5:256",
                         gen_dist="uniform:16:64", seed=7)
    m = ClusterSimulator(4, slots=8, block_size=16, max_seq=512,
                         prefill_chunk=32, cost=COST).run(reqs)
    assert m["requests"] == 400
    assert 0.9 < m["littles_law_ratio"] < 1.1


# --------------------------------------------------------------------------- #
# Edge pair: zero traffic / overload
# --------------------------------------------------------------------------- #
def test_zero_traffic():
    m = ClusterSimulator(2, cost=COST).run([])
    assert m["requests"] == 0 and m["events"] == 0
    assert m["throughput_rps"] == 0.0
    assert m["littles_law_ratio"] == 1.0
    assert m["e2e_s"]["p99"] == 0.0


def test_overload_finishes_and_capacity_helps():
    """A single saturated instance still completes every request; the
    backlog shows up as queueing delay that more instances shrink."""
    reqs = make_workload(120, qps=1000.0, prompt_dist="uniform:32:64",
                         gen_dist="uniform:16:32", seed=3)
    small = ClusterSimulator(1, slots=2, block_size=16, max_seq=128,
                             prefill_chunk=32, cost=COST).run(reqs)
    big = ClusterSimulator(8, slots=8, block_size=16, max_seq=128,
                           prefill_chunk=32, cost=COST).run(reqs)
    assert small["requests"] == big["requests"] == 120
    assert small["queueing_s"]["p99"] > 10 * big["queueing_s"]["p99"]
    assert big["e2e_s"]["p99"] < small["e2e_s"]["p99"]


def test_never_admissible_request_raises():
    sim = ClusterSimulator(1, slots=2, block_size=16, num_blocks=2,
                           max_seq=1024, cost=COST)
    with pytest.raises(RuntimeError, match="never be admitted"):
        sim.run([Request(rid="huge", prompt_len=512, max_new=64)])


# --------------------------------------------------------------------------- #
# Fleet search
# --------------------------------------------------------------------------- #
def test_search_fleet_returns_smallest_meeting_size():
    reqs = make_workload(120, qps=50.0, prompt_dist="uniform:32:64",
                         gen_dist="uniform:16:32", seed=3)
    kw = dict(slots=4, block_size=16, max_seq=128, prefill_chunk=32,
              cost=COST)
    ans = search_fleet(reqs, slo_s=0.5, metric="queueing_s", max_fleet=16,
                       **kw)
    n = ans["fleet"]
    assert n is not None and ans["metrics"]["queueing_s"]["p99"] <= 0.5
    assert ans["searched"][-1]["fleet"] == n
    if n > 1:       # every smaller size was tried and missed
        assert all(s["p99_s"] > 0.5 for s in ans["searched"][:-1])
        p99s = [s["p99_s"] for s in ans["searched"]]
        assert p99s == sorted(p99s, reverse=True)   # capacity is monotone
    unmet = search_fleet(reqs, slo_s=0.0, metric="queueing_s", max_fleet=2,
                         **kw)
    assert unmet["fleet"] is None and unmet["metrics"] is None
    assert len(unmet["searched"]) == 2


# --------------------------------------------------------------------------- #
# Traffic generation
# --------------------------------------------------------------------------- #
def test_workload_seeded_and_distribution_bounds():
    a = make_workload(50, 3.0, "uniform:10:20", "uniform:5:9", seed=11)
    b = make_workload(50, 3.0, "uniform:10:20", "uniform:5:9", seed=11)
    c = make_workload(50, 3.0, "uniform:10:20", "uniform:5:9", seed=12)
    assert a == b and a != c
    assert all(10 <= r.prompt_len <= 20 and 5 <= r.max_new <= 9 for r in a)
    arrivals = [r.arrival for r in a]
    assert arrivals == sorted(arrivals) and arrivals[-1] > 0.0


def test_batch_arrivals_and_dists():
    reqs = make_workload(10, qps=0.0, prompt_dist="fixed:8",
                         gen_dist="fixed:4", seed=0)
    assert all(r.arrival == 0.0 and r.prompt_len == 8 and r.max_new == 4
               for r in reqs)
    assert poisson_arrivals(0.0, 5, None) == [0.0] * 5
    import random
    draw = parse_length_dist("lognormal:100:0.5:150")
    rng = random.Random(0)
    vals = [draw(rng) for _ in range(200)]
    assert all(1 <= v <= 150 for v in vals)
    with pytest.raises(ValueError):
        parse_length_dist("zipf:3")


def test_trace_round_trip(tmp_path):
    trace = [{"t": 0.5, "prompt_len": 8, "max_new": 4},
             {"t": 0.0, "prompt_len": 16, "max_new": 2, "rid": "z",
              "priority": 1}]
    p = tmp_path / "trace.json"
    p.write_text(json.dumps(trace))
    reqs = load_trace(str(p))
    assert [r.arrival for r in reqs] == [0.0, 0.5]   # sorted by arrival
    assert reqs[0].rid == "z" and reqs[0].priority == 1
    assert reqs[1].prompt_len == 8


# --------------------------------------------------------------------------- #
# Metrics primitives
# --------------------------------------------------------------------------- #
def test_nearest_rank_percentile():
    xs = [float(i) for i in range(1, 101)]
    assert percentile(xs, 50) == 50.0
    assert percentile(xs, 99) == 99.0
    assert percentile(xs, 100) == 100.0
    assert percentile([7.0], 99) == 7.0
    assert percentile([], 50) == 0.0


def test_summarize_batch_arrivals_degenerate_ratio():
    records = [{"arrival": 0.0, "admit": 0.0, "first_token": 0.1,
                "finish": 1.0, "prompt_len": 4, "max_new": 3},
               {"arrival": 0.0, "admit": 0.5, "first_token": 0.6,
                "finish": 2.0, "prompt_len": 4, "max_new": 5}]
    m = summarize(records)
    assert m["requests"] == 2 and m["tokens_out"] == 8
    assert m["littles_law_ratio"] == 1.0      # zero arrival span
    assert m["queueing_s"]["max"] == 0.5
