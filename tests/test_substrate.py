"""Optimizer, data pipeline, checkpointing, fault tolerance, compression."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   restore_pytree, save_pytree)
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.runtime.compression import (CompressionState, int8_decode,
                                       int8_encode, topk_encode)
from repro.runtime.fault_tolerance import FTConfig, StragglerWatch, run_training


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #
def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, stats = adamw_update(params, g, opt, 0.05,
                                          weight_decay=0.0)
    assert float(loss(params)) < 1e-2
    assert int(opt.step) == 300


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(jnp.asarray(5))) < 1e-3


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #
def test_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=7)
    pipe = TokenPipeline(cfg)
    b1, b2 = pipe.batch(3), pipe.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(pipe.batch(4)["tokens"], b1["tokens"])
    # host shards tile the global batch exactly
    h0 = pipe.host_batch(3, 0, 2)["tokens"]
    h1 = pipe.host_batch(3, 1, 2)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert int(b1["tokens"].max()) < 1000


# --------------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    save_pytree(tree, str(tmp_path), 42)
    assert latest_step(str(tmp_path)) == 42
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = restore_pytree(like, str(tmp_path))
    assert step == 42
    np.testing.assert_array_equal(restored["w"], tree["w"])
    assert restored["nested"]["b"].dtype == np.asarray(
        tree["nested"]["b"]).dtype


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=10)
    tree = {"x": jnp.zeros(3)}
    for s in (10, 20, 30, 40):
        assert mgr.maybe_save(tree, s)
    assert not mgr.maybe_save(tree, 41)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000030", "step_00000040"]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save_pytree({"x": jnp.zeros((3,))}, str(tmp_path), 1)
    with pytest.raises(ValueError):
        restore_pytree({"x": jnp.zeros((4,))}, str(tmp_path))


# --------------------------------------------------------------------------- #
# fault-tolerant loop
# --------------------------------------------------------------------------- #
def test_run_training_resumes(tmp_path):
    calls = []

    def step_fn(state, batch):
        calls.append(int(state["step"]))
        return {"step": state["step"] + 1}, {"loss": 0.0}

    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
    state = {"step": jnp.asarray(0)}
    state, last, _ = run_training(step_fn, state, lambda s: {}, ft=ft,
                                  num_steps=5)
    assert int(state["step"]) == 5
    # simulate a crash + restart: resumes from the newest checkpoint (step 4)
    state2 = {"step": jnp.asarray(0)}
    calls.clear()
    state2, last2, _ = run_training(step_fn, state2, lambda s: {}, ft=ft,
                                    num_steps=8)
    assert calls[0] == 5         # resumed state, not from scratch
    assert int(state2["step"]) == 8


def test_straggler_watch():
    w = StragglerWatch(factor=3.0)
    for s in range(6):
        assert not w.observe(s, 1.0)
    assert w.observe(6, 10.0)
    assert len(w.events) == 1


# --------------------------------------------------------------------------- #
# compression
# --------------------------------------------------------------------------- #
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_int8_error_feedback_unbiased(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 0.1
    err = jnp.zeros_like(g)
    # accumulated decoded signal over steps approaches accumulated true signal
    acc_true, acc_dec = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(20):
        q, scale, err = int8_encode(g, err)
        acc_dec = acc_dec + int8_decode(q, scale)
        acc_true = acc_true + g
    resid = jnp.max(jnp.abs(acc_dec - acc_true))
    assert float(resid) <= float(jnp.max(jnp.abs(g))) * 2 / 127 + 1e-5


def test_topk_error_feedback_recovers_everything():
    g = jax.random.normal(jax.random.PRNGKey(0), (128,))
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(40):
        sparse, err = topk_encode(g, err, frac=0.1)
        acc = acc + sparse
    # over many steps even the smallest coords get transmitted (err feedback)
    np.testing.assert_allclose(np.asarray(acc / 40), np.asarray(g), atol=0.3)


def test_compressed_psum_multidevice():
    """int8/topk compressed psum ~= exact psum on an 8-device pod axis."""
    import subprocess, sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.runtime.compression import CompressionState, compressed_psum

mesh = jax.make_mesh((8,), ("pod",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32)) * 0.01
ref = g.mean(axis=0)

# int8 tol: the wire format sums int8 payloads and decodes with the mean
# scale, so one-shot error grows with cross-device scale spread (error
# feedback absorbs it across steps); 2e-3 covers the observed 1.15e-3.
for codec, tol in (("none", 1e-6), ("int8", 2e-3), ("topk", 0.02)):
    def f(gs):
        grads = {"w": gs[0]}
        st = CompressionState.init(grads)
        red, _ = compressed_psum(grads, st, "pod", codec=codec)
        return red["w"][None]
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                            out_specs=P("pod"), check_vma=False))(g)
    err = float(jnp.max(jnp.abs(out[0] - ref)))
    assert err < tol, (codec, err)
print("COMPRESS_OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COMPRESS_OK" in proc.stdout
