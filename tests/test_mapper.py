"""Mapper subsystem: GEMM front-end, determinism, cache reuse, pins.

Guarantees, mirroring the test_experiments.py layering:

1. GEMM front-end — :class:`GemmLayer` satisfies the Eq. (1)-(4) shape
   interface; :func:`im2col` is an exact WS-mapping equivalent of a CONV
   layer; FC/transformer workloads materialize with the right reductions.
2. Search semantics — deterministic (same config -> identical
   ``NetworkSchedule``), baseline-dominating (auto latency *and* energy <=
   the paper's fixed mapping, per acceptance), Pareto fronts non-dominated,
   and the whole-network search rides the plan-keyed sim cache (distinct
   event-driven runs << scored candidates on ResNet-50).
3. Pins — best-mapping ratios for one workload per family (CNN: AlexNet,
   transformer: qwen2 GEMM block) under the quick space, so search/space
   refactors cannot silently drift the subsystem's headline result.
4. Schedules are artifacts — JSON roundtrip and replay of the emitted
   packet programs on the collective engine.
"""
import json

import pytest

from repro.core.ina_model import ina_rounds, p_num
from repro.core.noc import SIM_CACHE, NocConfig
from repro.core.noc.collective.engine import run_program
from repro.core.ops import GemmLayer, im2col, transformer_gemms
from repro.core.workloads import (ALEXNET, ALEXNET_FC, VGG16_FC,
                                  full_workload, mapper_workloads)
from repro.mapper import (Mapping, MapperConfig, NetworkSchedule,
                          PAPER_MAPPING, QUICK_MAPPER, hardware_candidates,
                          layer_candidates, search_network)
from repro.mapper.space import group_choices

CFG = NocConfig()

# --------------------------------------------------------------------------- #
# 1. GEMM front-end
# --------------------------------------------------------------------------- #
def test_gemm_layer_shape_interface():
    g = GemmLayer("g", M=49, K=1152, N=256)
    assert (g.R, g.C, g.F, g.outputs) == (1, 1152, 256, 49)
    assert g.macs == 49 * 1152 * 256
    assert p_num(g) == 2                       # ceil(1152*32 / 32768)


@pytest.mark.parametrize("conv", ALEXNET[1:], ids=lambda l: l.name)
def test_im2col_preserves_mapping(conv):
    """im2col is WS-mapping-exact: same MACs, P#, and INA rounds."""
    g = im2col(conv)
    assert g.macs == conv.macs
    assert p_num(g) == p_num(conv)
    for n in (8, 16):
        assert ina_rounds(g, n=n) == ina_rounds(conv, n=n)


def test_fc_layers_present_and_split():
    """The FC tails the paper omits: present, and FC6/FC14 need INA."""
    assert [l.name for l in ALEXNET_FC] == ["FC6", "FC7", "FC8"]
    assert p_num(ALEXNET_FC[0]) == 9           # 9216*32/32768
    assert p_num(VGG16_FC[0]) == 25            # 25088*32/32768
    assert len(full_workload("alexnet")) == len(ALEXNET) + 3
    assert full_workload("resnet50")[-1].name.startswith("conv5")


def test_transformer_gemms_from_configs():
    from repro.configs import ARCHS
    gemms = transformer_gemms(ARCHS["llama3-8b"], tokens=128)
    by_name = {g.name.split(".")[-1]: g for g in gemms}
    assert set(by_name) == {"wq", "wk", "wv", "wo", "w_gate", "w_up",
                            "w_down"}
    assert by_name["wq"].K == 4096 and by_name["wq"].M == 128
    assert by_name["wk"].N == 8 * 128          # GQA: n_kv_heads * head_dim
    assert by_name["w_down"].K == 14336        # widest reduction: P# = 14
    assert p_num(by_name["w_down"]) == 14


# --------------------------------------------------------------------------- #
# 2. Space + search semantics
# --------------------------------------------------------------------------- #
def test_hardware_candidates_respect_budget():
    mcfg = MapperConfig()
    hw = hardware_candidates(mcfg)
    assert PAPER_MAPPING.hardware in hw
    for w, h, e in hw:
        assert mcfg.pe_budget * mcfg.min_pe_fill <= w * h * e \
            <= mcfg.pe_budget
        assert max(w, h) <= mcfg.max_aspect * min(w, h)
    assert hw == sorted(hw)                    # deterministic order


def test_group_choices_feasible():
    assert group_choices(p_req=1, height=8, k=3) == [None, 4, 1]
    assert group_choices(p_req=3, height=8, k=3) == [None, 1]
    assert group_choices(p_req=9, height=8, k=3) == [None]   # multi-pass only


def test_layer_candidates_modes_and_order():
    cands = layer_candidates(ALEXNET[1], (8, 8, 1), QUICK_MAPPER)
    modes = {m.mode for m in cands}
    assert modes == {"ws_ina", "ws_noina", "os_gather"}
    assert cands == sorted(cands, key=lambda m: m.sort_key)
    for m in cands:                            # all simulate under one budget
        assert m.hardware == (8, 8, 1)


def test_search_deterministic():
    layers = full_workload("alexnet")
    a = search_network("alexnet", layers, QUICK_MAPPER)
    b = search_network("alexnet", layers, QUICK_MAPPER)
    assert a.best.to_dict() == b.best.to_dict()
    assert [s.to_dict() for s in a.pareto] == [s.to_dict() for s in b.pareto]


@pytest.mark.parametrize("workload", ["alexnet", "resnet50",
                                      "llama3-8b:gemm"])
def test_search_dominates_paper_mapping(workload):
    """Acceptance: auto latency AND energy <= the paper's fixed mapping."""
    wl = mapper_workloads(conv=("alexnet", "resnet50"),
                          transformers=("llama3-8b",))
    out = search_network(workload, wl[workload], QUICK_MAPPER)
    assert out.best.latency_cycles <= out.baseline.latency_cycles
    assert out.best.total_energy_pj <= out.baseline.total_energy_pj
    assert out.latency_x >= 1.0 and out.energy_x >= 1.0
    # Pareto front is non-dominated and sorted by latency.
    front = out.pareto
    for s, t in zip(front, front[1:]):
        assert s.latency_cycles <= t.latency_cycles
        assert s.total_energy_pj > t.total_energy_pj
    for a in out.best.assignments:             # utilization is a fraction
        assert 0.0 < a.utilization <= 1.0


def test_search_rides_the_sim_cache():
    """ResNet-50 search: distinct event-driven runs << scored candidates."""
    SIM_CACHE.clear()
    out = search_network("resnet50", full_workload("resnet50"), QUICK_MAPPER)
    stats = out.stats
    assert stats["simulated"] > 1000           # the space is genuinely large
    assert stats["sim_misses"] < stats["simulated"] / 5
    assert stats["sim_hits"] > stats["sim_misses"]
    # Re-searching is pure cache replay: no new window programs at all.
    again = search_network("resnet50", full_workload("resnet50"),
                           QUICK_MAPPER)
    assert again.stats["sim_misses"] == 0
    assert again.best.to_dict() == out.best.to_dict()


# --------------------------------------------------------------------------- #
# 3. Pinned best-mapping ratios (one workload per family, quick space)
# --------------------------------------------------------------------------- #
MAPPER_PINS = {
    # family: (workload, latency_x, energy_x, best hardware)
    "cnn": ("alexnet", 19.797776031469883, 4.254409151706931, (4, 16, 1)),
    "transformer": ("qwen2-1.5b:gemm", 1.254058722231493, 1.0076998172302678,
                    (4, 16, 1)),
}


@pytest.mark.parametrize("family", sorted(MAPPER_PINS), ids=str)
def test_best_mapping_pins(family):
    workload, lat, en, hw = MAPPER_PINS[family]
    wl = mapper_workloads(conv=("alexnet",), transformers=("qwen2-1.5b",))
    out = search_network(workload, wl[workload], QUICK_MAPPER)
    assert out.best.hardware == hw
    assert out.latency_x == pytest.approx(lat, rel=1e-9)
    assert out.energy_x == pytest.approx(en, rel=1e-9)


# --------------------------------------------------------------------------- #
# 4. Schedules as artifacts: JSON roundtrip + engine replay
# --------------------------------------------------------------------------- #
def test_network_schedule_roundtrip_and_replay():
    layers = full_workload("alexnet")
    out = search_network("alexnet", layers, QUICK_MAPPER)
    blob = json.dumps(out.best.to_dict())
    assert NetworkSchedule.from_dict(json.loads(blob)) == out.best
    replayed = 0
    for name, cfg, prog in out.best.programs(layers, window=2):
        res = run_program(prog, cfg)
        assert res.latency_cycles > 0, name
        replayed += 1
    assert replayed == len(layers)


def test_paper_mapping_is_identity_choice():
    """A space collapsed to the paper's axes returns the paper's numbers."""
    mcfg = MapperConfig(e_list=(1,), min_dim=8, min_pe_fill=1.0,
                        dataflows=("ws",), semantics=("ina",),
                        group_options=1, sim_rounds=4)
    assert hardware_candidates(mcfg) == [(8, 8, 1)]
    out = search_network("alexnet", list(ALEXNET), mcfg)
    assert out.best.to_dict() == out.baseline.to_dict()
    assert out.latency_x == 1.0 and out.energy_x == 1.0
