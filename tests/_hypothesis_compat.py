"""Graceful degradation when ``hypothesis`` is not installed.

``from _hypothesis_compat import given, settings, strategies, assume`` gives
the real hypothesis API when available.  Otherwise the stand-ins below let
the module *collect*: ``@given(...)``-decorated tests are marked skipped
(``pytest.importorskip``-style) while every non-property test in the module
keeps running.
"""
try:
    from hypothesis import HealthCheck, assume, given, settings, strategies
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def assume(_condition):
        return True

    class _Strategy:
        """Inert placeholder: composes like a strategy, never runs."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    strategies = _Strategies()

    class _HealthCheckMeta(type):
        def __getattr__(cls, name):   # class-attribute access, as hypothesis
            return name               # uses it (HealthCheck.too_slow)

    class HealthCheck(metaclass=_HealthCheckMeta):
        pass
