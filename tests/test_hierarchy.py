"""Multi-chip hierarchy (DESIGN.md S14): mesh-of-meshes topology,
hierarchical collectives, and the layers threaded on top.

Coverage map (ISSUE 8):

* degenerate equivalence — a 1-chip hierarchy replays every collective
  corpus case and every quick fig7-12 WS plan shape bit-identically to
  the flat engines (latency + the full energy ledger, both engines), and
  the 1-chip lowering *is* the flat ``plan_collective`` program;
* hierarchy verifier — the whole mesh-of-meshes corpus verifies clean,
  and one seeded mutation per finding class is flagged: chip-boundary
  escape / bad express channel -> ``hier-route``, dropped chip lane /
  dropped contribution -> ``hier-fold``, cyclic path-override ring in
  one lane -> ``cdg-deadlock`` (the same ring split across two chips is
  clean: channels are namespaced per chip);
* route-cache regression — a hierarchical sweep after a warm flat run
  derives no new flat-mesh routes, and replanning derives nothing at all;
* mapper package axis — ``chips_list`` adds deterministic ``(w, h, e,
  chips)`` points without disturbing the historical triples, and a
  2-chip evaluation is reproducible and dearer than its 1-chip shard;
* plan store — a multi-chip plan keys under ``__cN``, re-plans warm with
  0 collective engine runs, and never answers a flat request.
"""
import dataclasses

from repro.analysis.corpus import (collective_programs, hier_schedules,
                                   ws_programs)
from repro.analysis.verify import verify_hier_schedule
from repro.configs import ARCHS
from repro.configs.base import SHAPES
from repro.core.noc.collective.engine import run_program
from repro.core.noc.collective.schedule import PacketOp, plan_collective
from repro.core.noc.hierarchy import (HierLane, HierLevel,
                                      HierarchicalMesh,
                                      HierarchicalSchedule,
                                      flat_hier_schedule,
                                      plan_hier_collective,
                                      run_hier_schedule)
from repro.core.noc.router import NocConfig
from repro.core.noc.simcache import SIM_CACHE
from repro.core.noc.topology import ROUTE_STATS, clear_route_caches

CFG4 = NocConfig(n=4)
MESH = (("data", 16), ("model", 16))


def _checks(findings):
    return {f.check for f in findings}


# --------------------------------------------------------------------------- #
# 1. Degenerate equivalence: 1 chip == flat mesh, bit for bit
# --------------------------------------------------------------------------- #
def test_flat_wrapper_bit_identical_collective_corpus():
    for case, cfg, prog in collective_programs():
        hmesh = HierarchicalMesh(chip_w=cfg.width, chip_h=cfg.height)
        sched = flat_hier_schedule(hmesh, prog, cfg)
        for engine in ("auto", "heap"):
            res = run_hier_schedule(sched, engine=engine)
            ref = run_program(list(prog), cfg, engine=engine)
            label = (case["op"], case["semantics"], case["label"], engine)
            assert res.latency_cycles == ref.latency_cycles, label
            assert res.ledger == ref.ledger, label
            assert res.energy_pj == ref.network_energy_pj(cfg), label


def test_flat_wrapper_bit_identical_ws_shapes():
    for shape, cfg, prog in ws_programs(quick=True, window=2):
        hmesh = HierarchicalMesh(chip_w=cfg.width, chip_h=cfg.height)
        sched = flat_hier_schedule(hmesh, prog, cfg)
        for engine in ("auto", "heap"):
            res = run_hier_schedule(sched, engine=engine)
            ref = run_program(list(prog), cfg, engine=engine)
            label = (shape["layer"], shape["mode"], shape["e_pes"], engine)
            assert res.latency_cycles == ref.latency_cycles, label
            assert res.ledger == ref.ledger, label


def test_one_chip_lowering_is_the_flat_program():
    parts = [(x, y) for x in range(4) for y in range(4)]
    for op in ("reduce", "broadcast", "allreduce"):
        hmesh = HierarchicalMesh(chip_w=4, chip_h=4)
        sched = plan_hier_collective(op, hmesh, 2048.0, CFG4)
        assert [lvl.name for lvl in sched.levels] == ["flat"]
        (lane,) = sched.levels[0].lanes
        assert lane.cfg is CFG4          # same object: same cache keys
        flat = plan_collective(op, parts, 2048.0, CFG4, root=(0, 0))
        assert list(lane.prog) == flat


# --------------------------------------------------------------------------- #
# 2. Hierarchy verifier: corpus clean + one mutation per finding class
# --------------------------------------------------------------------------- #
def test_hier_corpus_verifies_clean():
    n = 0
    for case, sched in hier_schedules():
        n += 1
        assert not verify_hier_schedule(sched), case
    assert n == 32                       # 2 grids x 2 variants x op space


def _mutate_lane(sched, level_name, fn, lane_idx=0):
    levels = []
    for level in sched.levels:
        lanes = list(level.lanes)
        if level.name == level_name:
            lanes[lane_idx] = fn(lanes[lane_idx])
        levels.append(dataclasses.replace(level, lanes=tuple(lanes)))
    return dataclasses.replace(sched, levels=tuple(levels))


def _mutate_op(lane, idx, **changes):
    prog = list(lane.prog)
    prog[idx] = dataclasses.replace(prog[idx], **changes)
    return dataclasses.replace(lane, prog=tuple(prog))


def _first_routed(lane):
    for i, op in enumerate(lane.prog):
        if op.flits:
            return i
    raise AssertionError("lane has no routed op")


def _hier(op="reduce", package="mesh", chips_x=2, chips_y=1, **kw):
    hmesh = HierarchicalMesh(chip_w=4, chip_h=4, chips_x=chips_x,
                             chips_y=chips_y, package=package)
    return plan_hier_collective(op, hmesh, 2048.0, CFG4, **kw)


def test_mutation_chip_boundary_escape_is_hier_route():
    sched = _hier("reduce")
    lane = sched.levels[0].lanes[0]
    i = _first_routed(lane)
    bad = _mutate_lane(sched, "intra-reduce",
                       lambda ln: _mutate_op(ln, i, dst=(4, 0), path=None))
    assert "hier-route" in _checks(verify_hier_schedule(bad))


def test_mutation_express_channel_shape_is_hier_route():
    sched = _hier("reduce", package="express", chips_x=2, chips_y=2)
    pkg = next(lvl for lvl in sched.levels if lvl.name == "package")
    lane = pkg.lanes[0]
    i = _first_routed(lane)
    # a 3-hop path is not a dedicated chip-root channel
    op = lane.prog[i]
    detour = [tuple(op.src), (op.src[0], 1 - op.src[1]), tuple(op.dst)]
    bad = _mutate_lane(sched, "package",
                       lambda ln: _mutate_op(ln, i, path=detour))
    assert "hier-route" in _checks(verify_hier_schedule(bad))
    # ...and a non-chip coordinate is flagged even on a 2-node channel
    bad = _mutate_lane(sched, "package",
                       lambda ln: _mutate_op(ln, i, src=(5, 5),
                                             path=[(5, 5), tuple(op.dst)]))
    assert "hier-route" in _checks(verify_hier_schedule(bad))


def test_mutation_dropped_chip_lane_is_hier_fold():
    sched = _hier("reduce", chips_x=2, chips_y=2)
    intra = next(lvl for lvl in sched.levels if lvl.name == "intra-reduce")
    levels = tuple(dataclasses.replace(lvl, lanes=lvl.lanes[1:])
                   if lvl.name == "intra-reduce" else lvl
                   for lvl in sched.levels)
    bad = dataclasses.replace(sched, levels=levels)
    assert len(intra.lanes) == 4
    assert "hier-fold" in _checks(verify_hier_schedule(bad))


def test_mutation_dropped_contribution_is_hier_fold():
    sched = _hier("reduce")
    lane = sched.levels[0].lanes[0]
    # strip a leaf participant from the final op's accumulated contribs:
    # its operand arrives via the dep packets, so the merge now drops it
    last = len(lane.prog) - 1
    acc = sorted(lane.prog[last].contribs)
    bad = _mutate_lane(
        sched, "intra-reduce",
        lambda ln: _mutate_op(ln, last, contribs=frozenset(acc[:-1])))
    assert "hier-fold" in _checks(verify_hier_schedule(bad))


_RING = [
    [(0, 0), (1, 0), (1, 1)],            # ring links R1 -> R2
    [(1, 0), (1, 1), (0, 1)],            # R2 -> R3
    [(1, 1), (0, 1), (0, 0)],            # R3 -> R4
    [(0, 1), (0, 0), (1, 0)],            # R4 -> R1: closes the cycle
]


def _ring_ops(paths):
    return tuple(PacketOp(p[0], p[-1], 4, path=list(p), tag="ring")
                 for p in paths)


def test_mutation_turning_ring_is_cdg_deadlock():
    hmesh = HierarchicalMesh(chip_w=4, chip_h=4)
    sched = flat_hier_schedule(hmesh, _ring_ops(_RING), CFG4)
    assert "cdg-deadlock" in _checks(verify_hier_schedule(sched))


def test_cdg_channels_are_namespaced_per_chip():
    # The same four turning ops split across two chips share no physical
    # link, so the two-level CDG must NOT see a cycle.
    hmesh = HierarchicalMesh(chip_w=4, chip_h=4, chips_x=2)
    chip_cfg = hmesh.chip_cfg(CFG4)
    lanes = tuple(
        HierLane(label=f"chip{c}", scope="chip", cfg=chip_cfg,
                 prog=_ring_ops(_RING[c::2]), chip=c)
        for c in (0, 1))
    sched = HierarchicalSchedule(
        hmesh=hmesh, op="flat", semantics="ina", algorithm="reduce_bcast",
        payload_bits=0.0, levels=(HierLevel("flat", lanes),))
    assert "cdg-deadlock" not in _checks(verify_hier_schedule(sched))


# --------------------------------------------------------------------------- #
# 3. Route caches: hierarchical sweeps re-derive no flat-mesh routes
# --------------------------------------------------------------------------- #
def test_hier_sweep_reuses_warm_flat_routes():
    cfg = NocConfig()                    # the 8x8 flat mesh
    clear_route_caches()
    parts = [(x, y) for x in range(8) for y in range(8)]
    run_program(plan_collective("allreduce", parts, 4096.0, cfg,
                                root=(0, 0)), cfg)
    warm = ROUTE_STATS["derived"]
    assert warm > 0
    for package in ("mesh", "express"):
        hmesh = HierarchicalMesh(chip_w=8, chip_h=8, chips_x=2,
                                 package=package)
        run_hier_schedule(plan_hier_collective("allreduce", hmesh,
                                               4096.0, cfg))
    # chip lanes ride the warm flat routes; the 2x1 package grid's
    # root-to-root hops are coordinate pairs the 8x8 warm-up already
    # derived (xy_route is shape-independent) — nothing new.
    assert ROUTE_STATS["derived"] == warm
    run_hier_schedule(plan_hier_collective(
        "allreduce", HierarchicalMesh(chip_w=8, chip_h=8, chips_x=2),
        4096.0, cfg))
    assert ROUTE_STATS["derived"] == warm


# --------------------------------------------------------------------------- #
# 4. Mapper package axis
# --------------------------------------------------------------------------- #
def test_chips_axis_extends_hardware_space_deterministically():
    from repro.mapper import hardware_candidates
    from repro.mapper.space import QUICK_MAPPER
    mcfg = dataclasses.replace(QUICK_MAPPER, chips_list=(1, 2))
    flat = hardware_candidates(QUICK_MAPPER)
    multi = hardware_candidates(mcfg)
    assert set(flat) < set(multi)                    # strict superset
    added = sorted(set(multi) - set(flat))
    assert added and all(len(hw) == 4 and hw[3] == 2 for hw in added)
    assert {hw[:3] for hw in added} == set(flat)     # same chip shapes
    assert multi == hardware_candidates(mcfg)        # stable order


def test_multichip_evaluation_deterministic_and_dearer():
    from repro.core.workloads import WORKLOADS
    from repro.mapper import Mapping, evaluate_mapping
    layer = WORKLOADS["alexnet"][1]
    one = Mapping(4, 4, 1)
    two = dataclasses.replace(one, chips=2)
    a = evaluate_mapping(layer, two, CFG4, sim_rounds=4)
    b = evaluate_mapping(layer, two, CFG4, sim_rounds=4)
    assert a == b
    flat = evaluate_mapping(layer, one, CFG4, sim_rounds=4)
    # the package broadcast surcharge is real latency; replicated meshes
    # burn replicated NoC energy
    assert a.latency_cycles > 0 and a.noc_energy_pj > flat.noc_energy_pj


def test_search_with_chips_axis_is_reproducible():
    from repro.core.workloads import WORKLOADS
    from repro.mapper import search_network
    from repro.mapper.space import QUICK_MAPPER
    mcfg = dataclasses.replace(QUICK_MAPPER, e_list=(1,), min_dim=4,
                               group_options=1, prune_keep=2, sim_rounds=4,
                               chips_list=(1, 2))
    layers = list(WORKLOADS["alexnet"][:2])
    a = search_network("alexnet", layers, mcfg)
    b = search_network("alexnet", layers, mcfg)
    assert a.best.hardware == b.best.hardware
    assert [x.mapping for x in a.best.assignments] \
        == [x.mapping for x in b.best.assignments]
    assert a.best.latency_cycles == b.best.latency_cycles
    # the package axis was actually searched: every chip shape twice
    from repro.mapper import hardware_candidates
    hws = hardware_candidates(mcfg)
    assert a.stats["hardware_evaluated"] == len(hws)
    assert len(hws) == 2 * len(hardware_candidates(
        dataclasses.replace(mcfg, chips_list=(1,))))


# --------------------------------------------------------------------------- #
# 5. Plan store: __cN keys, warm multi-chip re-plan, no cross-answers
# --------------------------------------------------------------------------- #
def test_multichip_plan_store_warm_roundtrip(tmp_path, monkeypatch):
    from repro.plan import plan_for_launch
    monkeypatch.setattr(SIM_CACHE, "_persist_dir", tmp_path)
    cfg = ARCHS["qwen2-1.5b"]
    shape = SHAPES["decode_32k"]
    plan, info = plan_for_launch(cfg, MESH, shape, "auto",
                                 plan_dir=tmp_path, verbose=False,
                                 gemm_search=False, chips=2)
    assert plan.chips == 2 and plan.key.endswith("__c2")
    assert not info["from_store"]
    plan2, info2 = plan_for_launch(cfg, MESH, shape, "auto",
                                   plan_dir=tmp_path, verbose=False,
                                   gemm_search=False, chips=2)
    assert plan2 == plan
    assert info2["from_store"] and info2["collective_sims"] == 0
    # a flat request keys differently and never reads the __c2 plan
    flat, finfo = plan_for_launch(cfg, MESH, shape, "auto",
                                  plan_dir=tmp_path, verbose=False,
                                  gemm_search=False)
    assert flat.chips == 1 and flat.key != plan.key
    assert not finfo["from_store"]
    # express keys distinctly from mesh at the same chip count
    exp, _ = plan_for_launch(cfg, MESH, shape, "auto", plan_dir=tmp_path,
                             verbose=False, gemm_search=False, chips=2,
                             package="express")
    assert exp.key.endswith("__c2e") and exp.key != plan.key


# --------------------------------------------------------------------------- #
# 6. Experiments CLI: --section hierarchy + mapper --pe-budget/--chips
# --------------------------------------------------------------------------- #
def _cli(tmp_path, out, *extra):
    from repro.experiments.__main__ import main
    argv = ["--quick", "--no-persist", "--out", str(tmp_path / out),
            *extra]
    assert main(argv) == 0
    import json
    section = extra[extra.index("--sections") + 1]
    return json.loads((tmp_path / out / f"{section}.json").read_text())


def _no_elapsed(rows):
    """Rows minus wall-clock and cache-occupancy fields (sim_hits/misses
    describe what the process-wide SIM_CACHE already held, not results)."""
    out = []
    for r in rows:
        r = {k: v for k, v in r.items() if k != "elapsed_us"}
        if isinstance(r.get("search"), dict):
            r["search"] = {k: v for k, v in r["search"].items()
                           if k not in ("sim_hits", "sim_misses")}
        out.append(r)
    return out


def test_cli_hierarchy_section_deterministic(tmp_path):
    a = _cli(tmp_path, "a", "--sections", "hierarchy")
    b = _cli(tmp_path, "b", "--sections", "hierarchy")
    assert len(a["rows"]) == 3          # quick: (1 flat + 2x1 both fabrics)
    assert _no_elapsed(a["rows"]) == _no_elapsed(b["rows"])
    # the flat row is the paper mesh; multi-chip keeps an advantage > 1
    by_pkg = {r["package"]: r for r in a["rows"]}
    assert by_pkg["flat"]["chips"] == 1
    assert all(r["latency_x"] > 1.0 for r in a["rows"])
    # adding a package level cannot make the collective faster
    assert all(r["ina_latency_cycles"] >=
               by_pkg["flat"]["ina_latency_cycles"]
               for r in a["rows"] if r["chips"] > 1)


def test_cli_mapper_pe_budget_and_chips_flags(tmp_path):
    from repro.mapper import hardware_candidates
    from repro.mapper.space import QUICK_MAPPER
    args = ("--sections", "mapper", "--workloads", "alexnet",
            "--pe-budget", "32", "--chips", "1,2")
    a = _cli(tmp_path, "ma", *args)
    b = _cli(tmp_path, "mb", *args)
    assert a["pe_budget"] == 32 and a["chips_list"] == [1, 2]
    assert _no_elapsed(a["rows"]) == _no_elapsed(b["rows"])
    # the flags reach the searched space: candidate count matches the
    # constrained MapperConfig exactly
    mcfg = dataclasses.replace(QUICK_MAPPER, sim_rounds=4, pe_budget=32,
                               chips_list=(1, 2))
    expected = len(hardware_candidates(mcfg))
    row = next(r for r in a["rows"] if r["workload"] == "alexnet")
    assert row["search"]["hardware_evaluated"] == expected
    # narrower budget + package axis really is a different space: the
    # default quick space has no 4-tuple (chips) points and admits shapes
    # over 32 PEs
    default = hardware_candidates(
        dataclasses.replace(QUICK_MAPPER, sim_rounds=4))
    constrained = hardware_candidates(mcfg)
    assert set(constrained) != set(default)
    assert any(len(hw) == 4 for hw in constrained)
    assert all(len(hw) == 3 for hw in default)
